//! Figure/table drivers. Each function regenerates one evaluation
//! artifact of the paper and returns a [`BenchSuite`] whose table mirrors
//! the paper's axes (series = algorithms, x = min_sup / cores / size).
//!
//! Experiments are *declarative*: each driver is a roster of engine
//! names (resolved through the [`EngineRegistry`]) swept over an axis,
//! with every run going through one [`MiningSession`]. Registering a new
//! engine makes it sweepable here without touching any driver.

use crate::data::{Dataset, DatasetStats};
use crate::fim::engine::{EngineRegistry, MiningReport, MiningSession};
use crate::fim::types::abs_min_sup;
use crate::fim::Transaction;
use crate::sparklet::SparkletContext;
use crate::util::bench::BenchSuite;

use super::config::ExperimentConfig;

// ---------------------------------------------------------------- rosters

/// The paper's five Eclat variants (what the figures sweep), by registry
/// name.
pub fn eclat_roster() -> Vec<&'static str> {
    vec!["eclat-v1", "eclat-v2", "eclat-v3", "eclat-v4", "eclat-v5"]
}

/// The (a)-panel roster: RDD-Apriori plus the five Eclat variants.
pub fn roster_with_apriori() -> Vec<&'static str> {
    let mut v = vec!["apriori"];
    v.extend(eclat_roster());
    v
}

/// Extended roster: paper baselines + the §6 future-work fusion.
pub fn extended_roster() -> Vec<&'static str> {
    vec!["apriori", "fpgrowth", "eclat-v1", "eclat-v5", "eclat-v6"]
}

/// Every distributed engine currently registered (the `bench` command's
/// default sweep): the registry minus the driver-side sequential oracle.
pub fn registry_roster() -> Vec<&'static str> {
    EngineRegistry::names()
        .into_iter()
        .filter(|n| *n != "sequential")
        .collect()
}

/// Display label of a registered engine ("eclat-v4" -> "EclatV4").
/// Panics on unregistered names — rosters are code, not user input.
pub fn engine_label(name: &str) -> &'static str {
    EngineRegistry::get(name)
        .unwrap_or_else(|| panic!("engine {name:?} is not registered"))
        .label()
}

/// Run one registered engine once over an in-memory database, on a fresh
/// `cfg.cores`-wide context. Returns the full [`MiningReport`] (timings
/// + per-stage metrics included).
pub fn run_engine(
    engine: &str,
    txns: &[Transaction],
    min_sup: u32,
    tri_matrix: bool,
    cfg: &ExperimentConfig,
) -> MiningReport {
    let sc = SparkletContext::local(cfg.cores);
    MiningSession::new(engine)
        .min_sup(min_sup)
        .tri_matrix(tri_matrix)
        .p(cfg.p)
        .run_vec(&sc, txns)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Extension experiment (not a paper figure): baseline families +
/// future-work fusion across a min_sup sweep on T10.
pub fn extended_comparison(cfg: &ExperimentConfig) -> BenchSuite {
    let mut suite = BenchSuite::new(
        "ext_baselines",
        &format!(
            "Apriori vs FP-Growth vs Eclat V1/V5/V6-fused on T10I4D100K (scale {})",
            cfg.scale
        ),
    );
    let txns = Dataset::T10I4D100K.generate_scaled(cfg.seed, cfg.scale);
    for &frac in &[0.005f64, 0.003, 0.002] {
        let min_sup = abs_min_sup(frac, txns.len());
        for engine in extended_roster() {
            suite.measure(engine_label(engine), "min_sup", frac, || {
                let _ = run_engine(engine, &txns, min_sup, true, cfg);
            });
        }
    }
    suite
}

/// The paper's min_sup sweeps per dataset (relative supports; the (a)
/// figures' x axes).
pub fn minsup_sweep(dataset: Dataset) -> Vec<f64> {
    match dataset {
        // Figs 1–2: click-streams at sub-percent supports
        Dataset::Bms1 | Dataset::Bms2 => vec![0.002, 0.0015, 0.001, 0.0008, 0.0006],
        // Fig 3
        Dataset::T10I4D100K => vec![0.005, 0.004, 0.003, 0.002, 0.001],
        // Fig 4
        Dataset::T40I10D100K => vec![0.02, 0.0175, 0.015, 0.0125, 0.01],
    }
}

/// Figs 1–4: execution time vs min_sup on one dataset.
/// `with_apriori = true` regenerates the (a) panel, false the (b) panel.
pub fn fig_minsup(
    fig_no: usize,
    dataset: Dataset,
    with_apriori: bool,
    cfg: &ExperimentConfig,
) -> BenchSuite {
    let panel = if with_apriori { "a" } else { "b" };
    let mut suite = BenchSuite::new(
        &format!("fig{fig_no}{panel}_{}", dataset.name()),
        &format!(
            "Execution time vs min_sup on {} ({}; scale {})",
            dataset.name(),
            if with_apriori {
                "Eclat variants and Apriori"
            } else {
                "only Eclat variants"
            },
            cfg.scale
        ),
    );
    let txns = dataset.generate_scaled(cfg.seed, cfg.scale);
    let tri = dataset.tri_matrix_mode();
    let roster = if with_apriori {
        roster_with_apriori()
    } else {
        eclat_roster()
    };
    for &frac in &minsup_sweep(dataset) {
        let min_sup = abs_min_sup(frac, txns.len());
        for engine in &roster {
            suite.measure(engine_label(engine), "min_sup", frac, || {
                let _ = run_engine(engine, &txns, min_sup, tri, cfg);
            });
        }
    }
    suite
}

/// Fig 5: execution time vs executor cores.
/// (a) BMS2 @ 0.001, (b) T40 @ 0.01 — per the paper.
///
/// On a machine with ≥ 2 physical CPUs this measures real thread
/// scaling. On a single-CPU host (this container) a thread sweep cannot
/// show parallel speedup, so the run executes serially, records per-task
/// durations, and reports the LPT-modeled makespan for each core count —
/// the documented simulator substitution (DESIGN.md §3). Forced with
/// `REPRO_MODEL_CORES=1`, disabled with `=0`.
pub fn fig_cores(dataset: Dataset, min_sup_frac: f64, cfg: &ExperimentConfig) -> BenchSuite {
    let physical = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let model = match std::env::var("REPRO_MODEL_CORES").as_deref() {
        Ok("1") => true,
        Ok("0") => false,
        _ => physical < 4,
    };
    let mut suite = BenchSuite::new(
        &format!("fig5_{}", dataset.name()),
        &format!(
            "Execution time vs executor cores on {} at min_sup={} (scale {}; {})",
            dataset.name(),
            min_sup_frac,
            cfg.scale,
            if model {
                "LPT-modeled makespan from measured task times"
            } else {
                "real thread sweep"
            }
        ),
    );
    let txns = dataset.generate_scaled(cfg.seed, cfg.scale);
    let min_sup = abs_min_sup(min_sup_frac, txns.len());
    let tri = dataset.tri_matrix_mode();
    let core_sweep = [2usize, 4, 6, 8, 10];
    if model {
        for engine in eclat_roster() {
            // One serial run per variant; makespan modeled per core count.
            let sc = SparkletContext::local(1);
            let _ = MiningSession::new(engine)
                .min_sup(min_sup)
                .tri_matrix(tri)
                .p(cfg.p)
                .run_vec(&sc, &txns)
                .unwrap_or_else(|e| panic!("{e}"));
            for &cores in &core_sweep {
                let ms = sc.metrics().modeled_makespan_ms(cores);
                suite.record(engine_label(engine), "cores", cores as f64, vec![ms]);
            }
        }
    } else {
        for &cores in &core_sweep {
            let run_cfg = cfg.clone().with_cores(cores);
            for engine in eclat_roster() {
                suite.measure(engine_label(engine), "cores", cores as f64, || {
                    let _ = run_engine(engine, &txns, min_sup, tri, &run_cfg);
                });
            }
        }
    }
    suite
}

/// Fig 6: scalability on increasing dataset size (T10, min_sup = 0.05,
/// size doubled 100K → 1600K transactions — scaled by `cfg.scale`).
pub fn fig_scaling(cfg: &ExperimentConfig) -> BenchSuite {
    let mut suite = BenchSuite::new(
        "fig6_scaling",
        &format!(
            "Execution time vs dataset size, T10I4D100K x(1..16) at min_sup=0.05 (scale {})",
            cfg.scale
        ),
    );
    let base = Dataset::T10I4D100K.generate_scaled(cfg.seed, cfg.scale);
    for factor in crate::data::scale::fig6_factors() {
        let txns = crate::data::scale::replicate_shuffled(&base, factor, cfg.seed ^ 0xF16);
        let min_sup = abs_min_sup(0.05, txns.len());
        for engine in eclat_roster() {
            suite.measure(
                engine_label(engine),
                "transactions",
                txns.len() as f64,
                || {
                    let _ = run_engine(engine, &txns, min_sup, true, cfg);
                },
            );
        }
    }
    suite
}

/// Table 1: dataset properties (generated vs paper).
pub fn table1(cfg: &ExperimentConfig) -> String {
    let mut out = String::new();
    out.push_str("## Table 1 — datasets (generated at scale ");
    out.push_str(&format!("{})\n", cfg.scale));
    out.push_str(&format!(
        "{:<16}{:>14}{:>14}{:>12}{:>14}{:>14}{:>12}\n",
        "Dataset", "Txns(paper)", "Txns(gen)", "Items(p)", "Items(gen)", "Width(p)", "Width(gen)"
    ));
    for d in Dataset::all() {
        let (pt, pi, pw) = d.table1_row();
        let txns = d.generate_scaled(cfg.seed, cfg.scale);
        let s = DatasetStats::compute(&txns);
        out.push_str(&format!(
            "{:<16}{:>14}{:>14}{:>12}{:>14}{:>14.1}{:>12.2}\n",
            d.name(),
            pt,
            s.transactions,
            pi,
            s.distinct_items,
            pw,
            s.avg_width
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            seed: 7,
            scale: 0.01,
            cores: 2,
            p: 4,
        }
    }

    #[test]
    fn run_engine_returns_consistent_results() {
        let cfg = tiny_cfg();
        let txns = Dataset::T10I4D100K.generate_scaled(cfg.seed, cfg.scale);
        let min_sup = abs_min_sup(0.01, txns.len());
        let apriori = run_engine("apriori", &txns, min_sup, true, &cfg);
        for engine in eclat_roster() {
            let eclat = run_engine(engine, &txns, min_sup, true, &cfg);
            assert!(
                eclat.result.same_as(&apriori.result),
                "{engine} != apriori"
            );
        }
    }

    #[test]
    fn rosters_are_registered() {
        for name in roster_with_apriori()
            .into_iter()
            .chain(extended_roster())
            .chain(registry_roster())
        {
            assert!(EngineRegistry::get(name).is_some(), "{name}");
        }
        assert!(!registry_roster().contains(&"sequential"));
    }

    #[test]
    fn labels_match_the_paper_series_names() {
        assert_eq!(engine_label("eclat-v1"), "EclatV1");
        assert_eq!(engine_label("apriori"), "RDD-Apriori");
        assert_eq!(engine_label("fpgrowth"), "RDD-FPGrowth");
    }

    #[test]
    fn minsup_sweeps_descend() {
        for d in Dataset::all() {
            let sweep = minsup_sweep(d);
            assert!(sweep.windows(2).all(|w| w[0] > w[1]), "{:?}", d.name());
        }
    }

    #[test]
    fn table1_renders_all_rows() {
        let t = table1(&tiny_cfg());
        for d in Dataset::all() {
            assert!(t.contains(d.name()));
        }
    }
}
