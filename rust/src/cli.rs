//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `repro <command> [--flag value]...`. Flags may appear in any
//! order; `--flag=value` and `--flag value` both parse.
//!
//! Commands declare their accepted flags as a [`CommandSpec`] allowlist;
//! [`Args::validate`] rejects unknown/misspelled flags with an
//! edit-distance suggestion instead of silently running with defaults
//! (the old behaviour: `--min-supp 0.01` used to mine at the default
//! support). Every command also answers `--help` from its spec.
//!
//! One command never reaches this layer: `repro worker ...`, the hidden
//! entry point the multi-process executor backend execs for its worker
//! fleet, is intercepted in `main()` before spec validation — it is
//! machine-addressed (socket path, worker id) and not part of the
//! user-facing grammar, so it does not appear in help or suggestions.

use crate::util::text::closest;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    /// `(name, value)` pairs in command-line order. A flag may repeat
    /// (e.g. `--post closed --post top=5`): [`Args::get`] is last-wins,
    /// [`Args::get_all`] returns every occurrence.
    flags: Vec<(String, String)>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = Vec::new();
        let mut bools = Vec::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(format!("unexpected positional argument: {arg}"));
            };
            if let Some((k, v)) = name.split_once('=') {
                flags.push((k.to_string(), v.to_string()));
            } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                flags.push((name.to_string(), it.next().unwrap()));
            } else {
                bools.push(name.to_string());
            }
        }
        Ok(Self {
            command,
            flags,
            bools,
        })
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// Last occurrence wins, matching the usual CLI override idiom.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse {v:?}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.get(name) == Some("true")
    }

    /// `--help` anywhere after the command asks for the command's help.
    pub fn wants_help(&self) -> bool {
        self.flag("help")
    }

    /// Every flag name that appeared on the command line (repeats
    /// included), in no particular order.
    pub fn flag_names(&self) -> Vec<&str> {
        self.flags
            .iter()
            .map(|(k, _)| k.as_str())
            .chain(self.bools.iter().map(|s| s.as_str()))
            .collect()
    }

    /// Check every given flag against the command's allowlist. Unknown
    /// flags fail with a "did you mean" suggestion drawn from the spec
    /// (`--help` is always accepted).
    pub fn validate(&self, spec: &CommandSpec) -> Result<(), String> {
        for name in self.flag_names() {
            if name == "help" || spec.flags.iter().any(|f| f.name == name) {
                continue;
            }
            let mut msg = format!("unknown flag --{name} for `{}`", spec.name);
            if let Some(s) = closest(name, spec.flags.iter().map(|f| f.name.as_str()), 3) {
                msg.push_str(&format!(" — did you mean --{s}?"));
            }
            msg.push_str(&format!("\n\n{}", spec.render_help()));
            return Err(msg);
        }
        Ok(())
    }
}

/// One flag a command accepts.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: String,
    /// Value placeholder for help ("F", "N", "NAME"); empty for boolean
    /// flags.
    pub value: String,
    /// One-line description (may embed registry-derived value lists).
    pub help: String,
}

impl FlagSpec {
    pub fn new(name: &str, value: &str, help: impl Into<String>) -> Self {
        Self {
            name: name.to_string(),
            value: value.to_string(),
            help: help.into(),
        }
    }
}

/// A command's allowlist + help text.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: String,
    pub about: String,
    pub flags: Vec<FlagSpec>,
}

impl CommandSpec {
    pub fn new(name: &str, about: &str, flags: Vec<FlagSpec>) -> Self {
        Self {
            name: name.to_string(),
            about: about.to_string(),
            flags,
        }
    }

    /// `USAGE` + flag table for `repro <command> --help`.
    pub fn render_help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE: repro {}", self.name, self.about, self.name);
        if !self.flags.is_empty() {
            out.push_str(" [flags]\n\nFLAGS:\n");
            for f in &self.flags {
                let lhs = if f.value.is_empty() {
                    format!("--{}", f.name)
                } else {
                    format!("--{} {}", f.name, f.value)
                };
                out.push_str(&format!("  {lhs:<24} {}\n", f.help));
            }
        } else {
            out.push('\n');
        }
        out
    }
}

/// Find the spec for a command, or a "did you mean" error drawn from the
/// full command list.
pub fn find_command<'a>(specs: &'a [CommandSpec], command: &str) -> Result<&'a CommandSpec, String> {
    specs.iter().find(|s| s.name == command).ok_or_else(|| {
        let mut msg = format!("unknown command {command:?}");
        if let Some(s) = closest(command, specs.iter().map(|s| s.name.as_str()), 3) {
            msg.push_str(&format!(" — did you mean `{s}`?"));
        }
        msg
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    fn mine_spec() -> CommandSpec {
        CommandSpec::new(
            "mine",
            "run one mining session",
            vec![
                FlagSpec::new("dataset", "D", "dataset name"),
                FlagSpec::new("min-sup", "F", "relative min support"),
                FlagSpec::new("engine", "NAME", "registered engine"),
                FlagSpec::new("tri-matrix", "", "enable the triangular matrix"),
            ],
        )
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("mine --dataset t10 --min-sup 0.01 --tri-matrix");
        assert_eq!(a.command, "mine");
        assert_eq!(a.get("dataset"), Some("t10"));
        assert_eq!(a.get("min-sup"), Some("0.01"));
        assert!(a.flag("tri-matrix"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn equals_form() {
        let a = parse("fig --id=3 --scale=0.5");
        assert_eq!(a.get_parse::<usize>("id").unwrap(), Some(3));
        assert_eq!(a.get_parse::<f64>("scale").unwrap(), Some(0.5));
    }

    #[test]
    fn repeated_flags_collect_in_order_and_get_is_last_wins() {
        let a = parse("query --post closed --post top=5 --min-sup 0.01 --min-sup 0.02");
        assert_eq!(a.get_all("post"), vec!["closed", "top=5"]);
        assert_eq!(a.get("min-sup"), Some("0.02"));
        assert_eq!(a.get_all("missing"), Vec::<&str>::new());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse("fig --id notanumber");
        assert!(a.get_parse::<usize>("id").is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(vec!["mine".into(), "stray".into()]).is_err());
    }

    #[test]
    fn empty_means_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn validate_accepts_known_flags() {
        let a = parse("mine --dataset t10 --min-sup 0.01 --tri-matrix --help");
        assert!(a.validate(&mine_spec()).is_ok());
        assert!(a.wants_help());
    }

    #[test]
    fn validate_rejects_misspelled_flag_with_suggestion() {
        // the motivating bug: --min-supp used to run silently at the
        // default support
        let a = parse("mine --min-supp 0.01");
        let err = a.validate(&mine_spec()).unwrap_err();
        assert!(err.contains("unknown flag --min-supp"), "{err}");
        assert!(err.contains("did you mean --min-sup?"), "{err}");
        assert!(err.contains("USAGE"), "{err}");
    }

    #[test]
    fn validate_rejects_unknown_bool_flag() {
        let a = parse("mine --dataset t10 --tri-matrx");
        let err = a.validate(&mine_spec()).unwrap_err();
        assert!(err.contains("--tri-matrx"), "{err}");
        assert!(err.contains("--tri-matrix"), "{err}");
    }

    #[test]
    fn help_renders_flag_table() {
        let h = mine_spec().render_help();
        assert!(h.contains("USAGE: repro mine"));
        assert!(h.contains("--min-sup F"));
        assert!(h.contains("--tri-matrix "));
    }

    #[test]
    fn find_command_suggests() {
        let specs = vec![mine_spec(), CommandSpec::new("stream", "stream", vec![])];
        assert!(find_command(&specs, "mine").is_ok());
        let err = find_command(&specs, "mien").unwrap_err();
        assert!(err.contains("did you mean `mine`?"), "{err}");
    }
}
