//! Equivalence classes and Zaki's Bottom-Up search (Algorithm 1 of the
//! paper, transcribed from [12] / the SPMF implementation).
//!
//! Itemsets sharing a (k-1)-length prefix form an equivalence class; each
//! class is an independent sub-lattice, which is precisely what the paper
//! partitions across executors in Phase-3/4. `bottom_up` recursively
//! decomposes a class, intersecting member tidsets pairwise and keeping
//! candidates that clear `min_sup`.

use super::tidset::TidOps;
use super::trimatrix::TriMatrix;
use super::types::{FrequentItemset, Item};

/// An equivalence class: all member itemsets share `prefix`; a member is
/// (last item, tidset of `prefix ∪ {item}`).
#[derive(Debug, Clone)]
pub struct EquivalenceClass<TS> {
    pub prefix: Vec<Item>,
    pub members: Vec<(Item, TS)>,
}

impl<TS> EquivalenceClass<TS> {
    /// Workload proxy used by the partitioner ablation: classes with more
    /// members generate more candidates (the paper's §4.4 measure).
    pub fn weight(&self) -> usize {
        self.members.len()
    }
}

/// Algorithm 1: Bottom-Up(EC_k). Appends every frequent itemset derived
/// from `class` (sizes `prefix.len() + 2` and deeper) to `out`.
pub fn bottom_up<TS: TidOps>(
    class: &EquivalenceClass<TS>,
    min_sup: u32,
    out: &mut Vec<FrequentItemset>,
) {
    for i in 0..class.members.len() {
        let (item_i, ref ts_i) = class.members[i];
        let mut next_prefix = class.prefix.clone();
        next_prefix.push(item_i);
        let mut next_members: Vec<(Item, TS)> = Vec::new();
        for (item_j, ts_j) in &class.members[i + 1..] {
            // §Perf O5+O6: bounded count-only probe first — failing
            // candidates (the majority at low min_sup) abort early and
            // never allocate a tidset.
            if let Some(sup) = ts_i.intersect_support_min(ts_j, min_sup) {
                let ts_ij = ts_i.intersect(ts_j);
                let mut items = next_prefix.clone();
                items.push(*item_j);
                out.push(FrequentItemset::new(items, sup));
                next_members.push((*item_j, ts_ij));
            }
        }
        if !next_members.is_empty() {
            let next = EquivalenceClass {
                prefix: next_prefix,
                members: next_members,
            };
            bottom_up(&next, min_sup, out);
        }
    }
}

/// Build the 1-length-prefix equivalence classes of frequent 2-itemsets
/// from the vertical dataset (Phase-3 of EclatV1, Algorithm 4 lines
/// 1–16). `vertical` must be sorted in the processing order (the paper
/// sorts by ascending support). Emits the frequent 2-itemsets into
/// `two_itemsets` as a side product.
///
/// `tri_matrix`: when present, prunes infrequent pairs *before* the
/// tidset intersection (`triMatrixMode = true`). Item ids in the matrix
/// are the positions in `vertical` (dense ranks), matching how the RDD
/// algorithms rank items.
pub fn build_classes<TS: TidOps>(
    vertical: &[(Item, TS)],
    min_sup: u32,
    tri_matrix: Option<&TriMatrix>,
    rank_of: impl Fn(Item) -> u32,
    two_itemsets: &mut Vec<FrequentItemset>,
) -> Vec<(usize, EquivalenceClass<TS>)> {
    let n = vertical.len();
    let mut classes = Vec::new();
    for i in 0..n.saturating_sub(1) {
        let (item_i, ref ts_i) = vertical[i];
        let mut members: Vec<(Item, TS)> = Vec::new();
        for (item_j, ts_j) in &vertical[i + 1..] {
            if let Some(m) = tri_matrix {
                // tri-matrix pre-filter: survivors are frequent by
                // construction, so materialize directly.
                if m.get_support(rank_of(item_i), rank_of(*item_j)) < min_sup {
                    continue;
                }
            } else {
                // §Perf O5+O6: no matrix (BMS mode) — bounded count-only
                // probe so infrequent pairs abort early, no allocation.
                if ts_i.intersect_support_min(ts_j, min_sup).is_none() {
                    continue;
                }
            }
            let ts_ij = ts_i.intersect(ts_j);
            let sup = ts_ij.support() as u32;
            if sup >= min_sup {
                two_itemsets.push(FrequentItemset::new(vec![item_i, *item_j], sup));
                members.push((*item_j, ts_ij));
            }
        }
        if !members.is_empty() {
            classes.push((
                i,
                EquivalenceClass {
                    prefix: vec![item_i],
                    members,
                },
            ));
        }
    }
    classes
}

/// Decompose 1-prefix classes one level further into 2-length-prefix
/// classes (the paper's §6 future-work: "the results can be explored for
/// the k-length prefixes where k >= 2"). Finer classes → more, smaller
/// parallel units → better balance at high skew. Emits the frequent
/// 3-itemsets discovered during decomposition into `three_itemsets`.
///
/// Returned keys are dense ranks in construction order (prefix-sorted),
/// ready for the same partitioners as the 1-prefix path.
pub fn decompose_to_prefix2<TS: TidOps>(
    classes: Vec<(usize, EquivalenceClass<TS>)>,
    min_sup: u32,
    three_itemsets: &mut Vec<FrequentItemset>,
) -> Vec<(usize, EquivalenceClass<TS>)> {
    let mut out = Vec::new();
    let mut rank = 0usize;
    for (_, class) in classes {
        for i in 0..class.members.len() {
            let (item_i, ref ts_i) = class.members[i];
            let mut prefix = class.prefix.clone();
            prefix.push(item_i);
            let mut members: Vec<(Item, TS)> = Vec::new();
            for (item_j, ts_j) in &class.members[i + 1..] {
                // §Perf O5+O6
                if let Some(sup) = ts_i.intersect_support_min(ts_j, min_sup) {
                    let ts_ij = ts_i.intersect(ts_j);
                    let mut items = prefix.clone();
                    items.push(*item_j);
                    three_itemsets.push(FrequentItemset::new(items, sup));
                    members.push((*item_j, ts_ij));
                }
            }
            if !members.is_empty() {
                out.push((
                    rank,
                    EquivalenceClass {
                        prefix: prefix.clone(),
                        members,
                    },
                ));
                rank += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::tidset::VecTidset;

    /// Tiny database from Zaki's paper style: items 0..4, 6 transactions.
    fn vertical_db() -> (Vec<(Item, VecTidset)>, usize) {
        // txns: 0:{0,1,2} 1:{1,2,3} 2:{0,1,3} 3:{0,1,2,3} 4:{1,2} 5:{0,3}
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 1, 3],
            vec![0, 1, 2, 3],
            vec![1, 2],
            vec![0, 3],
        ];
        let n = txns.len();
        let mut vertical = Vec::new();
        for item in 0..4u32 {
            let tids: Vec<u32> = txns
                .iter()
                .enumerate()
                .filter(|(_, t)| t.contains(&item))
                .map(|(i, _)| i as u32)
                .collect();
            vertical.push((item, VecTidset::from_tids(&tids, n)));
        }
        (vertical, n)
    }

    fn brute_force(txns: &[Vec<Item>], min_sup: u32) -> std::collections::BTreeSet<(Vec<Item>, u32)> {
        // enumerate all itemsets over items present
        let mut items: Vec<Item> = txns.iter().flatten().copied().collect();
        items.sort_unstable();
        items.dedup();
        let mut out = std::collections::BTreeSet::new();
        let m = items.len();
        for mask in 1u32..(1 << m) {
            let set: Vec<Item> = (0..m)
                .filter(|b| mask >> b & 1 == 1)
                .map(|b| items[b])
                .collect();
            let sup = txns
                .iter()
                .filter(|t| set.iter().all(|i| t.contains(i)))
                .count() as u32;
            if sup >= min_sup {
                out.insert((set, sup));
            }
        }
        out
    }

    #[test]
    fn classes_and_bottom_up_match_bruteforce() {
        let (vertical, _n) = vertical_db();
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 1, 3],
            vec![0, 1, 2, 3],
            vec![1, 2],
            vec![0, 3],
        ];
        for min_sup in 1..=4u32 {
            let mut twos = Vec::new();
            let classes = build_classes(&vertical, min_sup, None, |i| i, &mut twos);
            let mut all = Vec::new();
            // 1-itemsets
            for (item, ts) in &vertical {
                let sup = ts.support() as u32;
                if sup >= min_sup {
                    all.push(FrequentItemset::new(vec![*item], sup));
                }
            }
            all.extend(twos);
            for (_, c) in &classes {
                bottom_up(c, min_sup, &mut all);
            }
            let got: std::collections::BTreeSet<(Vec<Item>, u32)> =
                all.iter().map(|f| (f.items.clone(), f.support)).collect();
            assert_eq!(got, brute_force(&txns, min_sup), "min_sup={min_sup}");
            assert_eq!(got.len(), all.len(), "duplicates at min_sup={min_sup}");
        }
    }

    #[test]
    fn trimatrix_pruning_preserves_result() {
        let (vertical, _) = vertical_db();
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 1, 3],
            vec![0, 1, 2, 3],
            vec![1, 2],
            vec![0, 3],
        ];
        let mut tm = TriMatrix::new(4);
        for t in &txns {
            tm.update_transaction(t);
        }
        for min_sup in 1..=4u32 {
            let mut twos_pruned = Vec::new();
            let mut twos_plain = Vec::new();
            let c1 = build_classes(&vertical, min_sup, Some(&tm), |i| i, &mut twos_pruned);
            let c2 = build_classes(&vertical, min_sup, None, |i| i, &mut twos_plain);
            twos_pruned.sort();
            twos_plain.sort();
            assert_eq!(twos_pruned, twos_plain);
            assert_eq!(c1.len(), c2.len());
        }
    }

    #[test]
    fn prefix2_decomposition_preserves_itemsets() {
        let (vertical, _) = vertical_db();
        for min_sup in 1..=3u32 {
            // 1-prefix path
            let mut twos_a = Vec::new();
            let classes1 = build_classes(&vertical, min_sup, None, |i| i, &mut twos_a);
            let mut all_1p = twos_a.clone();
            for (_, c) in &classes1 {
                bottom_up(c, min_sup, &mut all_1p);
            }
            // 2-prefix path: decompose, then bottom-up from level 3
            let mut twos_b = Vec::new();
            let classes1b = build_classes(&vertical, min_sup, None, |i| i, &mut twos_b);
            let mut threes = Vec::new();
            let classes2 = decompose_to_prefix2(classes1b, min_sup, &mut threes);
            let mut all_2p = twos_b;
            all_2p.extend(threes);
            for (_, c) in &classes2 {
                bottom_up(c, min_sup, &mut all_2p);
            }
            let canon = |v: &[FrequentItemset]| -> std::collections::BTreeSet<_> {
                v.iter().map(|f| (f.items.clone(), f.support)).collect()
            };
            assert_eq!(canon(&all_1p), canon(&all_2p), "min_sup={min_sup}");
        }
    }

    #[test]
    fn prefix2_produces_more_finer_classes() {
        let (vertical, _) = vertical_db();
        let mut twos = Vec::new();
        let classes1 = build_classes(&vertical, 1, None, |i| i, &mut twos);
        let n1 = classes1.len();
        let max_w1 = classes1.iter().map(|(_, c)| c.weight()).max().unwrap();
        let mut threes = Vec::new();
        let classes2 = decompose_to_prefix2(classes1, 1, &mut threes);
        assert!(classes2.len() >= n1, "{} < {n1}", classes2.len());
        let max_w2 = classes2.iter().map(|(_, c)| c.weight()).max().unwrap();
        assert!(max_w2 <= max_w1);
        // prefixes are 2 items long
        assert!(classes2.iter().all(|(_, c)| c.prefix.len() == 2));
    }

    #[test]
    fn class_weight_is_member_count() {
        let (vertical, _) = vertical_db();
        let mut twos = Vec::new();
        let classes = build_classes(&vertical, 1, None, |i| i, &mut twos);
        for (_, c) in &classes {
            assert_eq!(c.weight(), c.members.len());
        }
    }
}
