//! The driver-side entry point — `SparkContext` analog.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::accumulator::{AccumValue, Accumulator};
use super::broadcast::{Broadcast, BroadcastRegistry};
use super::cache::CacheManager;
use super::conf::{ConfError, SparkletConf};
use super::events::{EventBus, EventLogWriter, MetricsListener, SparkletEvent};
use super::executor::{ExecutorBackend, ExecutorRegistry};
use super::faults::{FaultPlan, FaultPlane};
use super::metrics::MetricsRegistry;
use super::rdd::{Data, Rdd};
use super::shuffle::ShuffleManager;
use super::transforms::ParallelCollection;

struct ContextInner {
    conf: SparkletConf,
    executor: Arc<dyn ExecutorBackend>,
    shuffle: Arc<ShuffleManager>,
    cache: CacheManager,
    broadcasts: BroadcastRegistry,
    metrics: Arc<MetricsRegistry>,
    events: Arc<EventBus>,
    faults: Arc<FaultPlane>,
    next_rdd_id: AtomicUsize,
}

/// Cheap-to-clone handle on the engine. Dropping the last handle joins
/// the executor backend's workers.
#[derive(Clone)]
pub struct SparkletContext {
    inner: Arc<ContextInner>,
}

impl SparkletContext {
    /// Build a context, resolving `conf.executor_backend` against the
    /// `ExecutorRegistry`. Panics on an unknown backend — use
    /// [`SparkletContext::try_new`] (or the validating
    /// `SparkletConf::with_executor_backend` builder) for the error
    /// path.
    pub fn new(conf: SparkletConf) -> Self {
        Self::try_new(conf).unwrap_or_else(|e| panic!("invalid SparkletConf: {e}"))
    }

    /// `new`, with configuration problems surfaced as [`ConfError`].
    pub fn try_new(conf: SparkletConf) -> Result<Self, ConfError> {
        let executor = ExecutorRegistry::create(&conf.executor_backend, conf.executor_cores)
            .map_err(ConfError::Backend)?;
        let metrics = Arc::new(MetricsRegistry::new());
        {
            let ex = Arc::clone(&executor);
            metrics.set_active_source(move || ex.active());
        }
        // Every emission path goes through the bus; the registry is
        // just its first listener, so StageMetrics aggregation is a
        // pure derivation of the event stream. `collect_metrics: false`
        // now means "don't subscribe the registry", not "don't emit".
        let events = Arc::new(EventBus::new());
        if conf.collect_metrics {
            events.register(Arc::new(MetricsListener::new(Arc::clone(&metrics))));
        }
        if let Some(path) = &conf.event_log {
            let writer = EventLogWriter::with_rotation(path, conf.event_log_max_bytes).map_err(
                |e| ConfError::EventLog {
                    path: path.clone(),
                    reason: e.to_string(),
                },
            )?;
            events.register(Arc::new(writer));
        }
        // Arm the fault plane before the shuffle manager exists so the
        // block store's spill sites are live from the first block. The
        // plane is per-context: parallel tests each inject into their
        // own schedule.
        let faults = match conf.effective_fault_plan() {
            Some(spec) => {
                let plan =
                    FaultPlan::parse(&spec).map_err(|reason| ConfError::InvalidFaultPlan {
                        value: spec.clone(),
                        reason,
                    })?;
                Arc::new(FaultPlane::new(plan))
            }
            None => Arc::new(FaultPlane::disarmed()),
        };
        let shuffle = Arc::new(ShuffleManager::with_conf(
            conf.memory_budget,
            conf.shared_nothing,
        ));
        shuffle.set_fault_plane(Arc::clone(&faults));
        {
            let bus = Arc::clone(&events);
            shuffle.set_spill_hook(Arc::new(move |block, bytes, reloaded| {
                bus.emit(if reloaded {
                    SparkletEvent::ShuffleBlockReloaded { block, bytes }
                } else {
                    SparkletEvent::ShuffleBlockSpilled { block, bytes }
                });
            }));
        }
        // Hand the backend its runtime services. In-process backends
        // no-op; the multi-process backend binds its socket and spawns
        // workers here, so a failed spawn surfaces as a ConfError
        // before any job runs.
        executor
            .attach(super::executor::BackendServices {
                shuffle: Arc::clone(&shuffle),
                events: Arc::clone(&events),
                faults: Arc::clone(&faults),
                conf: conf.clone(),
            })
            .map_err(|reason| ConfError::BackendAttach {
                backend: conf.executor_backend.clone(),
                reason,
            })?;
        Ok(Self {
            inner: Arc::new(ContextInner {
                executor,
                shuffle,
                cache: CacheManager::new(),
                broadcasts: BroadcastRegistry::default(),
                metrics,
                events,
                faults,
                next_rdd_id: AtomicUsize::new(0),
                conf,
            }),
        })
    }

    /// Context with default configuration (all cores).
    pub fn default_local() -> Self {
        Self::new(SparkletConf::default())
    }

    /// Local context with `cores` executor threads (panics on 0 cores;
    /// the conf builder has the validating path).
    pub fn local(cores: usize) -> Self {
        let conf = SparkletConf::default()
            .with_cores(cores)
            .unwrap_or_else(|e| panic!("{e}"));
        Self::new(conf)
    }

    pub fn conf(&self) -> &SparkletConf {
        &self.inner.conf
    }

    /// `sc.defaultParallelism()` — worker parallelism of the executor
    /// backend (1 for `sequential`, regardless of configured cores).
    pub fn default_parallelism(&self) -> usize {
        self.inner.executor.cores().max(1)
    }

    /// The execution backend stages are submitted to.
    pub fn executor(&self) -> &Arc<dyn ExecutorBackend> {
        &self.inner.executor
    }

    pub fn shuffle_manager(&self) -> &ShuffleManager {
        &self.inner.shuffle
    }

    /// Owned handle on the shuffle manager (the described-task runner
    /// threads it into closures that outlive `&self`).
    pub(crate) fn shuffle_arc(&self) -> Arc<ShuffleManager> {
        Arc::clone(&self.inner.shuffle)
    }

    pub fn cache(&self) -> &CacheManager {
        &self.inner.cache
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.inner.metrics
    }

    /// The context's event bus — register listeners or emit directly.
    pub fn events(&self) -> &Arc<EventBus> {
        &self.inner.events
    }

    /// The armed fault-injection plane (disarmed unless the conf set a
    /// plan). Chaos tests read its injection counters to prove their
    /// schedule actually fired.
    pub fn faults(&self) -> &Arc<FaultPlane> {
        &self.inner.faults
    }

    pub(crate) fn new_rdd_id(&self) -> usize {
        self.inner.next_rdd_id.fetch_add(1, Ordering::Relaxed)
    }

    // ------------------------------------------------------------- sources

    /// Distribute a collection across `num_partitions` partitions.
    pub fn parallelize<T: Data>(&self, data: Vec<T>, num_partitions: usize) -> Rdd<T> {
        Rdd::from_base(Arc::new(ParallelCollection::new(
            self.clone(),
            data,
            num_partitions,
        )))
    }

    /// Distribute with default parallelism.
    pub fn parallelize_default<T: Data>(&self, data: Vec<T>) -> Rdd<T> {
        self.parallelize(data, self.default_parallelism())
    }

    /// Read a text file as an RDD of lines split into `min_partitions`
    /// partitions (the paper's `sc.textFile("db", 1)`).
    pub fn text_file(&self, path: &str, min_partitions: usize) -> std::io::Result<Rdd<String>> {
        let content = std::fs::read_to_string(path)?;
        let lines: Vec<String> = content.lines().map(|s| s.to_string()).collect();
        Ok(self.parallelize(lines, min_partitions.max(1)))
    }

    // ------------------------------------------------------ shared variables

    /// Create a broadcast variable.
    pub fn broadcast<T>(&self, value: T) -> Broadcast<T> {
        self.inner.broadcasts.create(value)
    }

    /// Create an accumulator sharded across the executor cores.
    pub fn accumulator<V: AccumValue>(
        &self,
        zero: impl Fn() -> V + Send + Sync + 'static,
    ) -> Accumulator<V> {
        Accumulator::new(self.inner.conf.executor_cores, zero)
    }

    // ------------------------------------------------------------------ jobs

    /// Run an action: apply `func` to every partition of `rdd`, returning
    /// per-partition results in partition order.
    pub fn run_job<T: Data, U: Send + 'static>(
        &self,
        rdd: &Rdd<T>,
        func: impl Fn(usize, Vec<T>) -> U + Send + Sync + 'static,
    ) -> Vec<U> {
        super::scheduler::run_job(self, rdd, func)
    }

    /// Free shuffle buckets and cached partitions (between experiments).
    pub fn reset_state(&self) {
        self.inner.shuffle.clear_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelize_preserves_order_and_count() {
        let sc = SparkletContext::local(4);
        let data: Vec<u32> = (0..1000).collect();
        let rdd = sc.parallelize(data.clone(), 7);
        assert_eq!(rdd.num_partitions(), 7);
        assert_eq!(rdd.collect(), data);
        assert_eq!(rdd.count(), 1000);
    }

    #[test]
    fn parallelize_more_partitions_than_elements() {
        let sc = SparkletContext::local(2);
        let rdd = sc.parallelize(vec![1, 2, 3], 10);
        assert_eq!(rdd.num_partitions(), 10);
        assert_eq!(rdd.count(), 3);
    }

    #[test]
    fn default_parallelism_is_cores() {
        let sc = SparkletContext::local(3);
        assert_eq!(sc.default_parallelism(), 3);
        assert_eq!(sc.executor().name(), "fifo");
    }

    #[test]
    fn try_new_rejects_unknown_backend() {
        // The field is public; a raw string bypassing the validating
        // builder still fails typed, not with a process abort.
        let conf = SparkletConf {
            executor_backend: "bogus".into(),
            ..Default::default()
        };
        let err = SparkletContext::try_new(conf).unwrap_err();
        assert!(
            err.to_string().contains("unknown executor backend"),
            "{err}"
        );
    }

    #[test]
    fn fault_plane_arms_from_conf_and_raw_garbage_fails_typed() {
        let sc = SparkletContext::local(2);
        assert!(!sc.faults().is_active(), "disarmed by default");
        let conf = SparkletConf::new("faulty")
            .with_cores(2)
            .unwrap()
            .with_fault_plan("seed=1; task_panic:nth=1")
            .unwrap();
        let sc = SparkletContext::new(conf);
        assert!(sc.faults().is_active());
        // The legacy worker_fault knob arms the plane too.
        let conf = SparkletConf::new("legacy")
            .with_cores(2)
            .unwrap()
            .with_worker_fault("w0:1");
        let sc = SparkletContext::new(conf);
        assert_eq!(sc.faults().worker_kill_after("w0"), Some(1));
        // A raw-field spec that bypassed the validating builder still
        // fails typed when the context arms it.
        let conf = SparkletConf {
            fault_plan: Some("bogus_site:always".into()),
            ..Default::default()
        };
        let err = SparkletContext::try_new(conf).unwrap_err();
        assert!(matches!(err, ConfError::InvalidFaultPlan { .. }), "{err}");
    }

    #[test]
    fn broadcast_and_accumulator() {
        let sc = SparkletContext::local(2);
        let b = sc.broadcast(vec![1u32, 2, 3]);
        let acc = sc.accumulator(|| 0u64);
        let rdd = sc.parallelize((0..100u32).collect(), 4);
        let acc2 = acc.clone();
        let b2 = b.clone();
        let total: usize = rdd
            .map(move |x| {
                acc2.add(1);
                x as usize + b2.value().len()
            })
            .collect()
            .iter()
            .sum();
        assert_eq!(total, (0..100).sum::<usize>() + 300);
        assert_eq!(acc.value(), 100);
    }
}
