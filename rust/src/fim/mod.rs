//! Frequent itemset mining: the paper's algorithm layer.
//!
//! Substrate types ([`types`], [`tidset`], [`trimatrix`], [`trie`],
//! [`eqclass`]), the sequential oracles ([`sequential`]), the five
//! RDD-Eclat variants ([`eclat`]) and the RDD-Apriori / YAFIM baseline
//! ([`apriori`]), the paper's equivalence-class partitioners
//! ([`partitioners`]), association-rule generation ([`rules`]), the
//! incremental sliding-window miner for the streaming layer
//! ([`streaming`]) — all composed behind the unified [`engine`] API:
//! [`engine::FimEngine`], the static [`engine::EngineRegistry`], and the
//! builder-driven [`engine::MiningSession`].

pub mod apriori;
pub mod distributed;
pub mod eclat;
pub mod engine;
pub mod eqclass;
pub mod fpgrowth;
pub mod postprocess;
pub mod partitioners;
pub mod rules;
pub mod sequential;
pub mod streaming;
pub mod tidset;
pub mod trie;
pub mod trimatrix;
pub mod types;

pub use eclat::{mine_eclat, EclatVariant};
pub use engine::{
    EngineRegistry, FimEngine, FimError, MiningConfig, MiningReport, MiningSession,
    PartitionStrategy, PostStage, TidsetRepr,
};
pub use streaming::{IncrementalEclat, StreamingEclatConfig, StreamingError};
pub use tidset::{
    kernel, BitmapTidset, DiffTidset, HybridTidset, KernelStats, TidOps, VecTidset,
};
pub use types::{FrequentItemset, Item, MiningResult, Transaction};
