//! The long-lived mining server.
//!
//! One [`Server`] holds one persistent [`SparkletContext`]; each client
//! connection gets a thread that decodes `Request` frames, runs
//! [`Server::handle`], and writes `Response` frames back. `handle` is
//! public and socket-free on purpose — the unit and property tests
//! drive the full admission/cache/mine pipeline through it without any
//! IO.
//!
//! Request lifecycle on the [`EventBus`](crate::sparklet::EventBus):
//! every mining request emits `RequestReceived`, then either
//! `RequestRejected` (reason `throttled` | `bad-request` |
//! `overloaded` | `internal`) or `RequestAdmitted` followed by a
//! terminal `RequestCompleted` (with its `cache_hit` label) — so
//! `--event-log` + `timeline` trace serving for free, and the CI smoke
//! validates span balance offline.

use std::collections::HashMap;
use std::net::Shutdown;
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::fim::engine::{EngineRegistry, MiningSession, PostStage, TidsetRepr};
use crate::fim::rules::generate_rules;
use crate::fim::types::{abs_min_sup, MiningResult, Transaction};
use crate::sparklet::faults::FaultSite;
use crate::sparklet::transport::{read_frame, write_frame};
use crate::sparklet::{SparkletContext, SparkletEvent};

use super::admission::{AdmissionGate, TenantShedder};
use super::cache::{CacheHit, ResultCache};
use super::protocol::{ServeError, ServeRequest, ServeResponse, ServeResult};

/// Maps a request's dataset ref to transactions. Injected so the serve
/// layer stays ignorant of dataset naming: the CLI wires the benchmark
/// generators in, tests wire synthetic data.
pub type DatasetResolver = Arc<dyn Fn(&str) -> Result<Vec<Transaction>, String> + Send + Sync>;

/// Rough working-set multiplier over the raw transaction bytes: vertical
/// tidsets + shuffle blocks + the result run several times the input.
const COST_EXPANSION: usize = 4;

/// Mining-as-a-service over one persistent context.
pub struct Server {
    sc: SparkletContext,
    resolver: DatasetResolver,
    cache: ResultCache,
    gate: AdmissionGate,
    shedder: TenantShedder,
    /// Resolved datasets, memoized — repeat queries skip regeneration.
    datasets: Mutex<HashMap<String, Arc<Vec<Transaction>>>>,
    next_request: AtomicU64,
    shutdown: AtomicBool,
    /// Set by `run` so the shutdown path can wake the acceptor.
    socket_path: Mutex<Option<String>>,
    /// Live connection streams, keyed by connection id. Shutdown must
    /// force these closed: an idle client blocked in `read_frame` would
    /// otherwise hold its connection thread — and the `run` loop joining
    /// it — forever.
    conns: Mutex<HashMap<u64, UnixStream>>,
    next_conn: AtomicU64,
}

impl Server {
    /// Build a server over `sc`, reading the serve knobs
    /// (`serve_queue_depth`, `serve_tenant_rate`, `serve_cache_budget`)
    /// from its conf.
    pub fn new(sc: SparkletContext, resolver: DatasetResolver) -> Self {
        let conf = sc.conf().clone();
        let cache = ResultCache::new(conf.serve_cache_budget, sc.shuffle_arc());
        Self {
            sc,
            resolver,
            cache,
            gate: AdmissionGate::new(conf.serve_queue_depth),
            shedder: TenantShedder::new(conf.serve_tenant_rate),
            datasets: Mutex::new(HashMap::new()),
            next_request: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            socket_path: Mutex::new(None),
            conns: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(0),
        }
    }

    /// The context the server mines on (tests inspect its events/conf).
    pub fn context(&self) -> &SparkletContext {
        &self.sc
    }

    /// Cached results currently held.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Bytes the result cache currently charges against the memory
    /// budget — after any request, the shuffle store's `used_bytes`
    /// must equal exactly this (the leak tests assert it).
    pub fn cache_bytes(&self) -> usize {
        self.cache.bytes()
    }

    /// Handle one request end to end: shed → validate → cache → admit →
    /// mine → cache-fill, emitting the request span on the event bus.
    /// Socket-free; the connection threads and the tests both call this.
    pub fn handle(&self, req: &ServeRequest) -> ServeResponse {
        if req.shutdown {
            // Control message, not a mining request: no span events.
            self.shutdown.store(true, Ordering::SeqCst);
            return ServeResponse::ShuttingDown;
        }
        let request = self.next_request.fetch_add(1, Ordering::Relaxed);
        let events = Arc::clone(self.sc.events());
        events.emit(SparkletEvent::RequestReceived {
            request,
            tenant: req.tenant.clone(),
        });
        let resp = match self.serve_one(request, req) {
            Ok(result) => {
                events.emit(SparkletEvent::RequestCompleted {
                    request,
                    cache_hit: result.cache_hit.clone(),
                    itemsets: result.itemsets.len() as u64,
                    wall_ms: result.wall_ms,
                });
                ServeResponse::Result(result)
            }
            Err(err) => {
                events.emit(SparkletEvent::RequestRejected {
                    request,
                    reason: reject_reason(&err).into(),
                });
                ServeResponse::Error(err)
            }
        };
        // Push the span out to the JSONL log promptly — the CI smoke
        // tails it while the server is still running.
        events.flush();
        resp
    }

    fn serve_one(&self, request: u64, req: &ServeRequest) -> Result<ServeResult, ServeError> {
        let started = Instant::now();
        let deadline = self.sc.conf().serve_deadline_ms;
        self.shedder.check(&req.tenant)?;

        // Validate everything before touching the queue: a malformed
        // request must not cost a slot.
        if !req.min_sup_frac.is_finite() || req.min_sup_frac <= 0.0 || req.min_sup_frac > 1.0 {
            return Err(ServeError::BadRequest {
                reason: format!("min_sup must be in (0, 1], got {}", req.min_sup_frac),
            });
        }
        let tidset =
            TidsetRepr::parse(&req.tidset).map_err(|reason| ServeError::BadRequest { reason })?;
        let post: Vec<PostStage> = req
            .post
            .iter()
            .map(|s| PostStage::parse(s))
            .collect::<Result<_, _>>()
            .map_err(|reason| ServeError::BadRequest { reason })?;
        if EngineRegistry::get(&req.engine).is_none() {
            return Err(ServeError::BadRequest {
                reason: format!(
                    "unknown engine {:?} (registered: {})",
                    req.engine,
                    EngineRegistry::names().join(", ")
                ),
            });
        }
        let txns = self.dataset(&req.dataset)?;
        let n = txns.len();
        let min_sup_abs = abs_min_sup(req.min_sup_frac, n);
        let events = self.sc.events();

        // Cache first: hits bypass the admission queue entirely (they
        // cost a filter, not a mine) but still count as admitted so the
        // request span stays uniform.
        if let Some((result, _, hit)) = self.cache.lookup(&req.dataset, min_sup_abs) {
            events.emit(SparkletEvent::RequestAdmitted {
                request,
                queued_ms: 0.0,
            });
            check_deadline(started, deadline)?;
            return Ok(self.render(result, hit, min_sup_abs, n, started, &post, req.min_conf));
        }

        let cost = txns.iter().map(|t| t.len()).sum::<usize>() * 4 * COST_EXPANSION;
        let ticket = self.gate.admit(cost, self.sc.shuffle_manager())?;
        let queued_ms = ticket.wait();
        events.emit(SparkletEvent::RequestAdmitted { request, queued_ms });
        // A request that queued past its budget must not start an
        // expensive mine; the `?` return drops the ticket, releasing
        // the admission slot to the next waiter.
        check_deadline(started, deadline)?;

        // Mine the FULL result — post-stages apply on the response path,
        // so the cache entry answers any future post-stage combination.
        let report = MiningSession::new(req.engine.as_str())
            .min_sup(min_sup_abs)
            .tidset(tidset)
            .run_vec(&self.sc, txns.as_slice())
            .map_err(|e| ServeError::Internal {
                reason: e.to_string(),
            })?;
        // Clear shuffle state while still holding the ticket: mining is
        // serialized through the gate, so no other request has blocks in
        // flight, and the persistent context must not leak artifacts
        // across requests.
        self.sc.reset_state();
        drop(ticket);

        // A mine that finished past the budget is refused too — the
        // client has already timed out, and returning a late answer
        // would let slow requests monopolize the response path. The
        // work is discarded, not cached (nothing may outlive a
        // rejected request).
        check_deadline(started, deadline)?;
        self.cache
            .insert(&req.dataset, min_sup_abs, report.result.clone(), n as u64);
        Ok(self.render(
            report.result,
            CacheHit::Miss,
            min_sup_abs,
            n,
            started,
            &post,
            req.min_conf,
        ))
    }

    /// Post-stages + rules on the full (or cache-filtered) result.
    #[allow(clippy::too_many_arguments)]
    fn render(
        &self,
        full: MiningResult,
        hit: CacheHit,
        min_sup_abs: u32,
        n_transactions: usize,
        started: Instant,
        post: &[PostStage],
        min_conf: f64,
    ) -> ServeResult {
        // Rules derive from the full result (as in MiningSession), not
        // the post-stage-condensed view.
        let rules = if min_conf > 0.0 {
            generate_rules(&full, min_conf, n_transactions)
                .iter()
                .map(|r| r.to_string())
                .collect()
        } else {
            Vec::new()
        };
        let mut shown = full;
        for stage in post {
            shown = stage.apply(&shown);
        }
        ServeResult {
            itemsets: shown.itemsets,
            cache_hit: hit.as_str().into(),
            min_sup_abs,
            n_transactions: n_transactions as u64,
            wall_ms: started.elapsed().as_secs_f64() * 1e3,
            rules,
        }
    }

    fn dataset(&self, name: &str) -> Result<Arc<Vec<Transaction>>, ServeError> {
        if let Some(t) = self.datasets.lock().unwrap().get(name) {
            return Ok(Arc::clone(t));
        }
        // Resolve outside the lock — generation can be slow. A racing
        // duplicate resolve is wasted work, not a correctness problem.
        let txns = (self.resolver)(name).map_err(|reason| ServeError::BadRequest { reason })?;
        let arc = Arc::new(txns);
        self.datasets
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::clone(&arc));
        Ok(arc)
    }

    /// Bind `socket_path` and serve until a shutdown request arrives.
    /// Each connection gets a thread; frames are length-prefixed
    /// transport messages carrying the serve protocol bodies.
    pub fn run(self: &Arc<Self>, socket_path: &str) -> Result<(), String> {
        let _ = std::fs::remove_file(socket_path);
        let listener = UnixListener::bind(socket_path)
            .map_err(|e| format!("cannot bind {socket_path}: {e}"))?;
        *self.socket_path.lock().unwrap() = Some(socket_path.to_string());
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            // Reap finished connection threads so a long-lived server
            // doesn't accumulate one handle per connection ever served.
            let mut i = 0;
            while i < handles.len() {
                if handles[i].is_finished() {
                    let _ = handles.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        break; // shutdown-time wakeup connection
                    }
                    let conn_id = self.next_conn.fetch_add(1, Ordering::Relaxed);
                    if let Ok(clone) = stream.try_clone() {
                        self.conns.lock().unwrap().insert(conn_id, clone);
                    }
                    let srv = Arc::clone(self);
                    let handle = std::thread::Builder::new()
                        .name("sparklet-serve-conn".into())
                        .spawn(move || {
                            srv.serve_connection(stream);
                            srv.conns.lock().unwrap().remove(&conn_id);
                        })
                        .map_err(|e| format!("spawn connection thread: {e}"))?;
                    handles.push(handle);
                }
                Err(_) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                }
            }
        }
        // Force-close every live connection before joining: an idle
        // client blocked in read_frame would never send EOF on its own,
        // and joining its thread without this would deadlock shutdown.
        // Queued response bytes still drain to the peer first.
        for (_, s) in self.conns.lock().unwrap().drain() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in handles {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(socket_path);
        self.sc.events().flush();
        Ok(())
    }

    /// Per-connection loop: requests in, responses out, until the peer
    /// hangs up, asks for shutdown, or `run`'s shutdown path closes the
    /// stream under us (read_frame then errors and we return).
    fn serve_connection(&self, stream: UnixStream) {
        let mut reader = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let mut writer = stream;
        loop {
            let msg = match read_frame(&mut reader) {
                Ok(m) => m,
                Err(_) => return, // peer closed (or spoke garbage)
            };
            let resp = match ServeRequest::from_message(&msg) {
                Ok(req) => self.handle(&req),
                Err(reason) => ServeResponse::Error(ServeError::BadRequest { reason }),
            };
            // Injected mid-request client disconnect: the request was
            // fully handled (ticket released, span emitted) but the
            // peer vanished before the response could be written. The
            // server must shrug — drop the connection, keep serving
            // others, leak nothing.
            if self.sc.faults().should_fail(FaultSite::ServeDisconnect) {
                let _ = writer.shutdown(Shutdown::Both);
                return;
            }
            let shutting_down = matches!(resp, ServeResponse::ShuttingDown);
            let write_ok = write_frame(&mut writer, &resp.to_message()).is_ok();
            if shutting_down {
                // Wake the acceptor out of accept() so it can observe
                // the shutdown flag (mirrors the remote backend's drop).
                if let Some(path) = self.socket_path.lock().unwrap().clone() {
                    let _ = UnixStream::connect(&path);
                }
                return;
            }
            if !write_ok {
                return;
            }
        }
    }
}

fn reject_reason(err: &ServeError) -> &'static str {
    match err {
        ServeError::Overloaded { .. } => "overloaded",
        ServeError::Throttled { .. } => "throttled",
        ServeError::BadRequest { .. } => "bad-request",
        ServeError::Internal { .. } => "internal",
        ServeError::DeadlineExceeded { .. } => "deadline",
    }
}

/// Reject a request whose service time has already blown its budget.
/// `None` (no configured deadline) never rejects.
fn check_deadline(started: Instant, deadline_ms: Option<u64>) -> Result<(), ServeError> {
    if let Some(budget) = deadline_ms {
        let elapsed = started.elapsed().as_millis() as u64;
        if elapsed >= budget {
            return Err(ServeError::DeadlineExceeded {
                elapsed_ms: elapsed,
                deadline_ms: budget,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use crate::fim::sequential::eclat_sequential;
    use crate::sparklet::{CollectingListener, SparkletConf};

    use super::*;

    /// Deterministic synthetic dataset: item i appears in every
    /// transaction whose index is a multiple of i+1, so supports are
    /// n/(i+1)-ish and subsumption thresholds are easy to pick.
    fn synthetic(n: usize, width: u32) -> Vec<Transaction> {
        (0..n)
            .map(|t| (0..width).filter(|&i| t % (i as usize + 1) == 0).collect())
            .collect()
    }

    fn test_server(conf: SparkletConf) -> (Arc<Server>, CollectingListener) {
        let sc = SparkletContext::new(conf);
        let listener = CollectingListener::new();
        sc.events().register(Arc::new(listener.clone()));
        let resolver: DatasetResolver = Arc::new(|name: &str| match name {
            "synth" => Ok(synthetic(64, 8)),
            "tiny" => Ok(synthetic(8, 3)),
            other => Err(format!("unknown dataset {other:?}")),
        });
        (Arc::new(Server::new(sc, resolver)), listener)
    }

    fn request(min_sup_frac: f64) -> ServeRequest {
        ServeRequest {
            dataset: "synth".into(),
            min_sup_frac,
            ..ServeRequest::default()
        }
    }

    fn expect_result(resp: ServeResponse) -> ServeResult {
        match resp {
            ServeResponse::Result(r) => r,
            other => panic!("expected a result, got {other:?}"),
        }
    }

    #[test]
    fn miss_then_exact_then_subsumed_all_match_the_oracle() {
        let conf = SparkletConf::new("serve-test").with_cores(2).unwrap();
        let (server, _) = test_server(conf);
        let txns = synthetic(64, 8);

        let first = expect_result(server.handle(&request(0.25)));
        assert_eq!(first.cache_hit, "miss");
        let oracle_lo = eclat_sequential(&txns, first.min_sup_abs);
        assert!(MiningResult::new(first.itemsets.clone()).same_as(&oracle_lo));
        assert_eq!(server.cache_len(), 1);

        let second = expect_result(server.handle(&request(0.25)));
        assert_eq!(second.cache_hit, "exact");
        assert_eq!(second.itemsets, first.itemsets);

        let third = expect_result(server.handle(&request(0.5)));
        assert_eq!(third.cache_hit, "subsumed");
        let oracle_hi = eclat_sequential(&txns, third.min_sup_abs);
        assert!(MiningResult::new(third.itemsets).same_as(&oracle_hi));
        // A subsumed answer does not create a new cache entry.
        assert_eq!(server.cache_len(), 1);
    }

    #[test]
    fn request_spans_are_balanced_on_the_event_bus() {
        let conf = SparkletConf::new("serve-events").with_cores(2).unwrap();
        let (server, listener) = test_server(conf);
        let _ = expect_result(server.handle(&request(0.25))); // miss
        let _ = expect_result(server.handle(&request(0.25))); // exact
        let bad = server.handle(&ServeRequest {
            dataset: "nope".into(),
            min_sup_frac: 0.25,
            ..ServeRequest::default()
        });
        assert!(matches!(
            bad,
            ServeResponse::Error(ServeError::BadRequest { .. })
        ));

        let mut received = Vec::new();
        let mut admitted = Vec::new();
        let mut completed = Vec::new();
        let mut rejected = Vec::new();
        for (_, ev) in listener.snapshot() {
            match ev {
                SparkletEvent::RequestReceived { request, .. } => received.push(request),
                SparkletEvent::RequestAdmitted { request, .. } => admitted.push(request),
                SparkletEvent::RequestCompleted {
                    request, cache_hit, ..
                } => completed.push((request, cache_hit)),
                SparkletEvent::RequestRejected { request, reason } => {
                    rejected.push((request, reason))
                }
                _ => {}
            }
        }
        assert_eq!(received, vec![0, 1, 2]);
        assert_eq!(admitted, vec![0, 1], "the bad request never admits");
        assert_eq!(
            completed,
            vec![(0, "miss".to_string()), (1, "exact".to_string())]
        );
        assert_eq!(rejected, vec![(2, "bad-request".to_string())]);
    }

    #[test]
    fn malformed_requests_reject_typed_without_mining() {
        let conf = SparkletConf::new("serve-bad").with_cores(2).unwrap();
        let (server, _) = test_server(conf);
        let cases = [
            ServeRequest {
                min_sup_frac: 0.0,
                ..request(0.0)
            },
            ServeRequest {
                min_sup_frac: 1.5,
                ..request(0.25)
            },
            ServeRequest {
                engine: "eclat-v99".into(),
                ..request(0.25)
            },
            ServeRequest {
                tidset: "trie".into(),
                ..request(0.25)
            },
            ServeRequest {
                post: vec!["open".into()],
                ..request(0.25)
            },
        ];
        for req in cases {
            let resp = server.handle(&req);
            assert!(
                matches!(resp, ServeResponse::Error(ServeError::BadRequest { .. })),
                "{req:?} -> {resp:?}"
            );
        }
        assert_eq!(server.cache_len(), 0, "nothing mined, nothing cached");
    }

    #[test]
    fn tenant_rate_throttles_but_cache_path_is_pre_shed() {
        let conf = SparkletConf::new("serve-shed")
            .with_cores(2)
            .unwrap()
            .with_serve_tenant_rate(1.0)
            .unwrap();
        let (server, _) = test_server(conf);
        let mut req = request(0.25);
        req.tenant = "acme".into();
        let _ = expect_result(server.handle(&req));
        // Burst of 1 at 1 req/s: the immediate repeat throttles even
        // though it would have been a cache hit (shedding is admission
        // of the request, not of the work).
        let resp = server.handle(&req);
        assert!(
            matches!(resp, ServeResponse::Error(ServeError::Throttled { ref tenant }) if tenant == "acme"),
            "{resp:?}"
        );
        // A different tenant is unaffected.
        req.tenant = "globex".into();
        let r = expect_result(server.handle(&req));
        assert_eq!(r.cache_hit, "exact");
    }

    #[test]
    fn post_stages_and_rules_apply_on_the_cached_path() {
        let conf = SparkletConf::new("serve-post").with_cores(2).unwrap();
        let (server, _) = test_server(conf);
        let full = expect_result(server.handle(&request(0.25)));
        let mut req = request(0.25);
        req.post = vec!["top=3".into()];
        req.min_conf = 0.5;
        let shaped = expect_result(server.handle(&req));
        assert_eq!(shaped.cache_hit, "exact", "post-stages don't fork the key");
        assert!(shaped.itemsets.len() <= 3);
        assert!(shaped.itemsets.len() < full.itemsets.len());
        assert!(
            !shaped.rules.is_empty(),
            "rules generate from the cached full result"
        );
        assert!(shaped.rules.iter().all(|r| r.contains("=>")), "{:?}", shaped.rules);
    }

    #[test]
    fn zero_deadline_rejects_typed_and_releases_the_slot() {
        // A raw 0 ms budget (the builder floor is 1 ms; the field is
        // public) makes every request blow its deadline at the first
        // check — deterministic, no sleeps.
        let conf = SparkletConf {
            serve_deadline_ms: Some(0),
            ..SparkletConf::new("serve-deadline").with_cores(2).unwrap()
        };
        let (server, listener) = test_server(conf);
        for _ in 0..2 {
            // The second request proves the first one's admission
            // ticket was released — a leaked slot would wedge it in
            // the queue forever instead of reaching the deadline check.
            let resp = server.handle(&request(0.25));
            match resp {
                ServeResponse::Error(ServeError::DeadlineExceeded { deadline_ms, .. }) => {
                    assert_eq!(deadline_ms, 0);
                }
                other => panic!("expected DeadlineExceeded, got {other:?}"),
            }
        }
        assert_eq!(server.cache_len(), 0, "a rejected mine must not cache");
        assert_eq!(
            server.context().shuffle_manager().used_bytes(),
            0,
            "no shuffle artifacts survive a deadline rejection"
        );
        let rejected: Vec<String> = listener
            .snapshot()
            .into_iter()
            .filter_map(|(_, ev)| match ev {
                SparkletEvent::RequestRejected { reason, .. } => Some(reason),
                _ => None,
            })
            .collect();
        assert_eq!(rejected, vec!["deadline".to_string(), "deadline".to_string()]);
    }

    #[test]
    fn generous_deadline_does_not_reject() {
        let conf = SparkletConf::new("serve-deadline-ok")
            .with_cores(2)
            .unwrap()
            .with_serve_deadline_ms(60_000)
            .unwrap();
        let (server, _) = test_server(conf);
        let r = expect_result(server.handle(&request(0.25)));
        assert_eq!(r.cache_hit, "miss");
        // The cached path also passes its deadline check.
        let r = expect_result(server.handle(&request(0.25)));
        assert_eq!(r.cache_hit, "exact");
    }

    #[test]
    fn shutdown_request_acks_and_serves_over_a_real_socket() {
        let conf = SparkletConf::new("serve-sock").with_cores(2).unwrap();
        let (server, _) = test_server(conf);
        let path = std::env::temp_dir().join(format!("sparklet-serve-test-{}.sock", std::process::id()));
        let path_str = path.to_string_lossy().to_string();
        let srv = Arc::clone(&server);
        let ps = path_str.clone();
        let t = std::thread::spawn(move || srv.run(&ps));
        // Wait for the socket to appear.
        for _ in 0..200 {
            if path.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let mut conn = UnixStream::connect(&path).expect("connect to serve socket");
        write_frame(&mut conn, &request(0.25).to_message()).unwrap();
        let resp = ServeResponse::from_message(&read_frame(&mut conn).unwrap()).unwrap();
        let res = expect_result(resp);
        assert_eq!(res.cache_hit, "miss");
        assert!(res.n_transactions > 0);
        // Shutdown over a second connection: typed ack, then the accept
        // loop exits and the socket file goes away. `conn` stays open
        // across the shutdown ON PURPOSE — run() must force idle
        // connections closed instead of joining their threads forever
        // (the blocked-in-read_frame deadlock this test regresses).
        let mut conn2 = UnixStream::connect(&path).expect("second connection");
        let shutdown = ServeRequest {
            shutdown: true,
            ..ServeRequest::default()
        };
        write_frame(&mut conn2, &shutdown.to_message()).unwrap();
        let ack = ServeResponse::from_message(&read_frame(&mut conn2).unwrap()).unwrap();
        assert_eq!(ack, ServeResponse::ShuttingDown);
        t.join().unwrap().unwrap();
        assert!(!path.exists(), "socket file removed on exit");
        assert!(
            read_frame(&mut conn).is_err(),
            "server force-closed the idle connection at shutdown"
        );
    }
}
