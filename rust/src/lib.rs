//! # rdd-eclat
//!
//! A full reproduction of *"RDD-Eclat: Approaches to Parallelize Eclat
//! Algorithm on Spark RDD Framework"* (Singh, Singh, Mishra, Garg —
//! ICCNCT 2019) as a three-layer Rust + JAX + Pallas system:
//!
//! * [`sparklet`] — a from-scratch Spark-RDD-like dataflow engine (the
//!   substrate the paper assumes): lazy RDDs, DAG scheduler, shuffle,
//!   broadcast/accumulators, caching, lineage recovery.
//! * [`fim`] — frequent itemset mining: tidsets, the triangular matrix,
//!   Borgelt transaction filtering, equivalence classes, Zaki's
//!   Bottom-Up search, the five RDD-Eclat variants (V1–V5), the
//!   RDD-Apriori (YAFIM) baseline, and sequential oracles.
//! * [`data`] — benchmark dataset substitutes: an IBM Quest synthetic
//!   generator (T10I4D100K / T40I10D100K) and a BMS-WebView-like
//!   clickstream generator, plus file I/O and scaling.
//! * [`runtime`] — the XLA/PJRT bridge: loads HLO-text artifacts AOT
//!   compiled from JAX+Pallas (`python/compile/`) and exposes batched
//!   support-count primitives to the mining hot path.
//! * [`serve`] — mining-as-a-service: a long-lived server over one
//!   persistent context (unix-socket protocol, bounded admission with
//!   per-tenant load shedding, subsuming result cache).
//! * [`coordinator`] — experiment drivers that regenerate every table
//!   and figure of the paper's evaluation section.
//! * [`timeline`] — offline replay of a persisted event log
//!   (`--event-log` JSONL) into a per-stage text Gantt with task
//!   percentiles, skew, and spill/backpressure annotations.
//! * [`util`] — in-tree substrate (thread pool, RNG, bitmaps, bench and
//!   property-test harnesses) since the build is fully offline.

pub mod cli;
pub mod coordinator;
pub mod data;
pub mod fim;
pub mod runtime;
pub mod serve;
pub mod sparklet;
pub mod timeline;
pub mod util;
