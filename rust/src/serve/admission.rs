//! Admission control for the serve mode: a bounded FIFO gate that
//! serializes mining against the shuffle memory budget, and a per-tenant
//! token-bucket load shedder.
//!
//! The gate is deliberately single-slot: one mine runs at a time (each
//! mine already fans out across every executor core, so concurrent mines
//! would fight over the same pool and the shared `BlockStore`), while up
//! to `queue_depth` requests wait their turn in arrival order. Arrivals
//! beyond that — or whose estimated cost would blow the memory budget on
//! top of current block + cache usage — are rejected with a typed
//! [`ServeError::Overloaded`] instead of spilling unboundedly.
//!
//! The shedder generalizes the streaming layer's AIMD idea to tenants:
//! each tenant id gets a token bucket refilled at the configured
//! requests/second; an empty bucket rejects with
//! [`ServeError::Throttled`] without consuming a queue slot.

use std::collections::{BTreeSet, HashMap};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::sparklet::shuffle::ShuffleManager;

use super::protocol::ServeError;

struct GateState {
    /// Next ticket number to hand out.
    next_ticket: u64,
    /// Ticket currently allowed to mine; tickets below it are done.
    serving: u64,
    /// Tickets at or above `serving` that completed out of turn (a
    /// holder dropped before waiting, e.g. its connection died);
    /// `serving` advances past any contiguous run of these so waiters
    /// behind an early-dropped ticket are never wedged.
    done: BTreeSet<u64>,
}

impl GateState {
    /// Tickets issued but not yet completed.
    fn in_flight(&self) -> usize {
        (self.next_ticket - self.serving) as usize - self.done.len()
    }
}

/// Bounded FIFO admission gate. `admit` either issues a [`Ticket`] or
/// rejects; `Ticket::wait` blocks until the caller's turn; dropping the
/// ticket passes the slot to the next waiter.
pub struct AdmissionGate {
    queue_depth: usize,
    state: Mutex<GateState>,
    turn: Condvar,
}

impl AdmissionGate {
    pub fn new(queue_depth: usize) -> Self {
        Self {
            queue_depth: queue_depth.max(1),
            state: Mutex::new(GateState {
                next_ticket: 0,
                serving: 0,
                done: BTreeSet::new(),
            }),
            turn: Condvar::new(),
        }
    }

    /// Requests currently holding tickets (one mining + the waiters).
    pub fn in_flight(&self) -> usize {
        self.state.lock().unwrap().in_flight()
    }

    /// Try to admit a request whose mine is estimated to cost
    /// `cost_estimate` bytes of shuffle/working memory. Rejects when the
    /// wait queue is full, or when the estimate on top of the store's
    /// current usage (resident blocks + external cache charges) would
    /// exceed the memory budget.
    pub fn admit(
        &self,
        cost_estimate: usize,
        shuffle: &ShuffleManager,
    ) -> Result<Ticket<'_>, ServeError> {
        let budget = shuffle.memory_budget();
        if budget != usize::MAX {
            let used = shuffle.used_bytes();
            if used.saturating_add(cost_estimate) > budget {
                return Err(ServeError::Overloaded {
                    reason: format!(
                        "estimated cost {cost_estimate} B on top of {used} B in use \
                         exceeds the {budget} B memory budget"
                    ),
                });
            }
        }
        let mut st = self.state.lock().unwrap();
        let in_flight = st.in_flight();
        // One slot mines; queue_depth more may wait.
        if in_flight >= self.queue_depth + 1 {
            return Err(ServeError::Overloaded {
                reason: format!(
                    "admission queue full ({} waiting, depth {})",
                    in_flight - 1,
                    self.queue_depth
                ),
            });
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        Ok(Ticket { gate: self, ticket })
    }
}

/// RAII admission slot: `wait` blocks until this ticket is at the head
/// of the FIFO; dropping it (after the mine, or on an error path)
/// advances the gate and wakes the next waiter.
pub struct Ticket<'a> {
    gate: &'a AdmissionGate,
    ticket: u64,
}

impl Ticket<'_> {
    /// Block until it is this ticket's turn to mine. Returns the time
    /// spent queued, in milliseconds.
    pub fn wait(&self) -> f64 {
        let start = Instant::now();
        let mut st = self.gate.state.lock().unwrap();
        while st.serving != self.ticket {
            st = self.gate.turn.wait(st).unwrap();
        }
        start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for Ticket<'_> {
    fn drop(&mut self) {
        let mut st = self.gate.state.lock().unwrap();
        // Mark this ticket complete, then advance `serving` past every
        // contiguous completed ticket. In the usual FIFO flow that is a
        // single step (serving == self.ticket); when a queued holder
        // drops before its turn, its number parks in `done` until the
        // tickets ahead of it finish — waiters in between still get
        // their turn instead of being skipped forever.
        st.done.insert(self.ticket);
        let mut serving = st.serving;
        while st.done.remove(&serving) {
            serving += 1;
        }
        st.serving = serving;
        drop(st);
        self.gate.turn.notify_all();
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-tenant token-bucket load shedder. Each tenant's bucket refills at
/// `rate` tokens/second up to a one-second burst; a request costs one
/// token. `rate <= 0` disables shedding entirely.
pub struct TenantShedder {
    rate: f64,
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl TenantShedder {
    pub fn new(rate: f64) -> Self {
        Self {
            rate,
            burst: rate.max(1.0),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Spend one token from `tenant`'s bucket, or reject.
    pub fn check(&self, tenant: &str) -> Result<(), ServeError> {
        self.check_at(tenant, Instant::now())
    }

    /// Distinct tenants currently holding buckets. Bounded by the set of
    /// recently-active tenants, not by every id ever seen: `check_at`
    /// prunes buckets that have refilled to full burst.
    pub fn bucket_count(&self) -> usize {
        self.buckets.lock().unwrap().len()
    }

    fn check_at(&self, tenant: &str, now: Instant) -> Result<(), ServeError> {
        if self.rate <= 0.0 {
            return Ok(());
        }
        let mut buckets = self.buckets.lock().unwrap();
        // Refill everything to `now`, dropping buckets that reach full
        // burst — a full bucket is indistinguishable from an absent one,
        // and client-supplied tenant ids would otherwise grow the map
        // without bound over a long-lived server's life.
        let (rate, burst) = (self.rate, self.burst);
        buckets.retain(|_, b| {
            let elapsed = now.saturating_duration_since(b.last).as_secs_f64();
            b.tokens = (b.tokens + elapsed * rate).min(burst);
            b.last = now;
            b.tokens < burst
        });
        let bucket = buckets.entry(tenant.to_string()).or_insert(Bucket {
            tokens: self.burst,
            last: now,
        });
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            Ok(())
        } else {
            Err(ServeError::Throttled {
                tenant: tenant.to_string(),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;
    use std::time::Duration;

    use super::*;

    #[test]
    fn gate_bounds_the_queue_and_frees_on_drop() {
        let shuffle = ShuffleManager::new(); // unlimited budget
        let gate = AdmissionGate::new(1);
        let head = gate.admit(0, &shuffle).unwrap(); // mining slot
        let waiter = gate.admit(0, &shuffle).unwrap(); // the one queue slot
        assert_eq!(gate.in_flight(), 2);
        let err = gate.admit(0, &shuffle).unwrap_err();
        assert!(
            matches!(err, ServeError::Overloaded { .. }),
            "third arrival rejects: {err}"
        );
        assert!(err.to_string().contains("queue full"), "{err}");
        drop(head);
        // The freed slot admits again.
        let next = gate.admit(0, &shuffle).unwrap();
        assert_eq!(gate.in_flight(), 2);
        drop(waiter);
        drop(next);
        assert_eq!(gate.in_flight(), 0);
    }

    #[test]
    fn gate_serves_in_fifo_order_across_threads() {
        let shuffle = ShuffleManager::new();
        let gate = AdmissionGate::new(8);
        let head = gate.admit(0, &shuffle).unwrap();
        assert!(head.wait() < 1_000.0, "head of the queue proceeds at once");
        let second = gate.admit(0, &shuffle).unwrap();
        let (tx, rx) = channel();
        std::thread::scope(|s| {
            s.spawn(move || {
                let queued_ms = second.wait();
                tx.send(queued_ms).unwrap();
                drop(second);
            });
            // The second ticket cannot proceed while the head is held.
            assert!(
                rx.recv_timeout(Duration::from_millis(50)).is_err(),
                "second ticket ran before the head finished"
            );
            drop(head);
            let queued_ms = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("second ticket unblocked after head drop");
            assert!(queued_ms >= 0.0);
        });
        assert_eq!(gate.in_flight(), 0);
    }

    #[test]
    fn early_dropped_ticket_does_not_wedge_later_waiters() {
        let shuffle = ShuffleManager::new();
        let gate = AdmissionGate::new(8);
        let head = gate.admit(0, &shuffle).unwrap();
        let middle = gate.admit(0, &shuffle).unwrap();
        let tail = gate.admit(0, &shuffle).unwrap();
        // A queued holder bails before its turn (e.g. its connection
        // died): the slot frees immediately...
        drop(middle);
        assert_eq!(gate.in_flight(), 2);
        // ...and once the head finishes, serving skips the parked
        // middle ticket straight to the tail instead of wedging it.
        drop(head);
        let queued_ms = tail.wait();
        assert!(queued_ms < 1_000.0, "tail proceeded at once: {queued_ms}");
        drop(tail);
        assert_eq!(gate.in_flight(), 0);
    }

    #[test]
    fn gate_rejects_when_cost_would_blow_the_budget() {
        let shuffle = ShuffleManager::with_conf(Some(1000), false);
        shuffle.charge_external(900);
        let gate = AdmissionGate::new(4);
        let err = gate.admit(200, &shuffle).unwrap_err();
        assert!(matches!(err, ServeError::Overloaded { .. }), "{err}");
        assert!(err.to_string().contains("memory budget"), "{err}");
        // A cheap request still fits.
        let t = gate.admit(50, &shuffle).unwrap();
        drop(t);
        // Releasing the external pressure re-opens the door.
        shuffle.release_external(900);
        assert!(gate.admit(200, &shuffle).is_ok());
    }

    #[test]
    fn shedder_throttles_per_tenant_and_refills() {
        let shedder = TenantShedder::new(2.0); // burst of 2 tokens
        let t0 = Instant::now();
        assert!(shedder.check_at("acme", t0).is_ok());
        assert!(shedder.check_at("acme", t0).is_ok());
        let err = shedder.check_at("acme", t0).unwrap_err();
        assert!(matches!(err, ServeError::Throttled { ref tenant } if tenant == "acme"));
        // Other tenants are unaffected.
        assert!(shedder.check_at("globex", t0).is_ok());
        // Half a second refills one token at 2/s.
        let later = t0 + Duration::from_millis(500);
        assert!(shedder.check_at("acme", later).is_ok());
        assert!(shedder.check_at("acme", later).is_err());
        // Tokens cap at the burst: a long idle doesn't bank unlimited.
        let much_later = t0 + Duration::from_secs(60);
        assert!(shedder.check_at("acme", much_later).is_ok());
        assert!(shedder.check_at("acme", much_later).is_ok());
        assert!(shedder.check_at("acme", much_later).is_err());
    }

    #[test]
    fn full_buckets_are_pruned_so_tenant_ids_do_not_accumulate() {
        let shedder = TenantShedder::new(2.0);
        let t0 = Instant::now();
        for i in 0..100 {
            assert!(shedder.check_at(&format!("tenant-{i}"), t0).is_ok());
        }
        assert_eq!(shedder.bucket_count(), 100, "all actively debited");
        // Once every bucket has refilled to full burst it carries no
        // state, so the next arrival prunes the lot.
        let later = t0 + Duration::from_secs(60);
        assert!(shedder.check_at("fresh", later).is_ok());
        assert_eq!(shedder.bucket_count(), 1);
    }

    #[test]
    fn rate_zero_disables_shedding() {
        let shedder = TenantShedder::new(0.0);
        let now = Instant::now();
        for _ in 0..100 {
            assert!(shedder.check_at("anyone", now).is_ok());
        }
    }
}
