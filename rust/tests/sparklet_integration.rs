//! Integration tests for the Sparklet engine: multi-stage jobs, shuffle
//! semantics, caching, lineage recovery, failure injection.

use std::collections::HashMap;
use std::sync::Arc;

use rdd_eclat::sparklet::{
    pair::Aggregator, ExecutorRegistry, HashPartitioner, PairRdd, SparkletConf, SparkletContext,
};

fn sc(cores: usize) -> SparkletContext {
    SparkletContext::local(cores)
}

fn sc_with_backend(cores: usize, backend: &str) -> SparkletContext {
    let conf = SparkletConf::new("backend-test")
        .with_cores(cores)
        .unwrap()
        .with_executor_backend(backend)
        .unwrap();
    SparkletContext::new(conf)
}

#[test]
fn wordcount_end_to_end() {
    let sc = sc(4);
    let lines = vec![
        "the quick brown fox".to_string(),
        "the lazy dog".to_string(),
        "the quick dog".to_string(),
    ];
    let rdd = sc.parallelize(lines, 2);
    let counts: HashMap<String, u32> = rdd
        .flat_map(|l| l.split(' ').map(|w| w.to_string()).collect::<Vec<_>>())
        .map_to_pair(|w| (w, 1u32))
        .reduce_by_key(|a, b| a + b)
        .collect_as_map();
    assert_eq!(counts["the"], 3);
    assert_eq!(counts["quick"], 2);
    assert_eq!(counts["dog"], 2);
    assert_eq!(counts["fox"], 1);
    assert_eq!(counts.len(), 6);
}

#[test]
fn reduce_by_key_matches_hashmap_oracle() {
    let sc = sc(4);
    let mut rng = rdd_eclat::util::SplitMix64::new(42);
    let pairs: Vec<(u32, u64)> = (0..5000)
        .map(|_| (rng.gen_range(100) as u32, rng.gen_range(10) as u64))
        .collect();
    let mut oracle: HashMap<u32, u64> = HashMap::new();
    for (k, v) in &pairs {
        *oracle.entry(*k).or_insert(0) += v;
    }
    let got = sc
        .parallelize(pairs, 8)
        .reduce_by_key(|a, b| a + b)
        .collect_as_map();
    assert_eq!(got, oracle);
}

#[test]
fn group_by_key_groups_everything() {
    let sc = sc(3);
    let pairs: Vec<(u8, u32)> = (0..1000u32).map(|i| ((i % 7) as u8, i)).collect();
    let grouped = sc.parallelize(pairs, 5).group_by_key().collect();
    assert_eq!(grouped.len(), 7);
    let mut total = 0;
    for (k, vs) in grouped {
        assert!(vs.iter().all(|v| (v % 7) as u8 == k));
        total += vs.len();
    }
    assert_eq!(total, 1000);
}

#[test]
fn partition_by_routes_keys() {
    let sc = sc(4);
    let pairs: Vec<(usize, String)> = (0..100).map(|i| (i, "x".to_string())).collect();
    let part = Arc::new(HashPartitioner::new(5));
    let p2 = Arc::clone(&part);
    let rdd = sc.parallelize(pairs, 4).partition_by(part);
    assert_eq!(rdd.num_partitions(), 5);
    let glommed = rdd.glom().collect();
    use rdd_eclat::sparklet::Partitioner;
    for (pi, partition) in glommed.iter().enumerate() {
        for (k, _) in partition {
            assert_eq!(p2.partition(k), pi, "key {k} in wrong partition {pi}");
        }
    }
}

#[test]
fn chained_shuffles_two_stages() {
    // (x % 10, x) -> sum per key -> re-key by sum % 3 -> group
    let sc = sc(4);
    let rdd = sc.parallelize((0..1000u64).collect::<Vec<_>>(), 6);
    let sums = rdd
        .map_to_pair(|x| (x % 10, x))
        .reduce_by_key(|a, b| a + b);
    let regrouped = sums
        .map_to_pair(|(_, sum)| (sum % 3, sum))
        .group_by_key()
        .collect();
    let total: u64 = regrouped.iter().flat_map(|(_, v)| v.iter()).sum();
    assert_eq!(total, (0..1000u64).sum::<u64>());
}

#[test]
fn combine_by_key_custom_aggregator() {
    let sc = sc(2);
    let pairs: Vec<(u8, f64)> = vec![(1, 2.0), (1, 4.0), (2, 6.0), (1, 6.0), (2, 10.0)];
    // mean per key via (sum, count) combiner
    let agg = Aggregator::new(
        |v: f64| (v, 1usize),
        |c: &mut (f64, usize), v: f64| {
            c.0 += v;
            c.1 += 1;
        },
        |c: &mut (f64, usize), o: (f64, usize)| {
            c.0 += o.0;
            c.1 += o.1;
        },
    );
    let means: HashMap<u8, f64> = sc
        .parallelize(pairs, 3)
        .combine_by_key(agg, Arc::new(HashPartitioner::new(2)), true)
        .map_values(|(s, n)| s / n as f64)
        .collect_as_map();
    assert_eq!(means[&1], 4.0);
    assert_eq!(means[&2], 8.0);
}

#[test]
fn coalesce_preserves_order() {
    let sc = sc(4);
    let data: Vec<u32> = (0..100).collect();
    let rdd = sc.parallelize(data.clone(), 8).coalesce(1);
    assert_eq!(rdd.num_partitions(), 1);
    assert_eq!(rdd.collect(), data);
}

#[test]
fn repartition_redistributes_all() {
    let sc = sc(4);
    let data: Vec<u32> = (0..1000).collect();
    let rdd = sc.parallelize(data.clone(), 2).repartition(8);
    assert_eq!(rdd.num_partitions(), 8);
    let mut got = rdd.collect();
    got.sort_unstable();
    assert_eq!(got, data);
    // reasonably balanced
    let sizes: Vec<usize> = rdd.glom().collect().iter().map(|p| p.len()).collect();
    assert!(sizes.iter().all(|&s| s > 50), "unbalanced: {sizes:?}");
}

#[test]
fn zip_with_index_is_global_and_ordered() {
    let sc = sc(3);
    let data: Vec<String> = (0..57).map(|i| format!("row{i}")).collect();
    let indexed = sc.parallelize(data.clone(), 5).zip_with_index().collect();
    for (i, (x, idx)) in indexed.iter().enumerate() {
        assert_eq!(*idx, i as u64);
        assert_eq!(*x, data[i]);
    }
}

#[test]
fn sort_by_key_total_order() {
    let sc = sc(4);
    let mut rng = rdd_eclat::util::SplitMix64::new(7);
    let pairs: Vec<(u64, u64)> = (0..2000).map(|i| (rng.next_u64() % 500, i)).collect();
    let sorted = sc.parallelize(pairs.clone(), 6).sort_by_key().collect();
    assert_eq!(sorted.len(), pairs.len());
    for w in sorted.windows(2) {
        assert!(w[0].0 <= w[1].0);
    }
}

#[test]
fn join_matches_nested_loop() {
    let sc = sc(2);
    let left = sc.parallelize(
        vec![
            (1u8, "a".to_string()),
            (2, "b".to_string()),
            (1, "c".to_string()),
        ],
        2,
    );
    let right = sc.parallelize(vec![(1u8, 10u32), (3, 30)], 2);
    let mut got = left.join(&right).collect();
    got.sort_by_key(|(k, (v, w))| (*k, v.clone(), *w));
    assert_eq!(
        got,
        vec![(1, ("a".to_string(), 10)), (1, ("c".to_string(), 10))]
    );
}

#[test]
fn caching_avoids_recompute() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let sc = sc(2);
    let computed = Arc::new(AtomicUsize::new(0));
    let c2 = Arc::clone(&computed);
    let rdd = sc
        .parallelize((0..100u32).collect::<Vec<_>>(), 4)
        .map(move |x| {
            c2.fetch_add(1, Ordering::SeqCst);
            x * 2
        })
        .cache();
    assert_eq!(rdd.count(), 100);
    let first_computations = computed.load(Ordering::SeqCst);
    assert_eq!(first_computations, 100);
    // second action hits the cache
    assert_eq!(rdd.count(), 100);
    assert_eq!(computed.load(Ordering::SeqCst), first_computations);
    // eviction (executor loss) triggers lineage recompute
    sc.cache().evict(rdd.id(), 0);
    assert_eq!(rdd.count(), 100);
    assert!(computed.load(Ordering::SeqCst) > first_computations);
}

#[test]
fn failure_injection_recovers_via_lineage() {
    let conf = SparkletConf::new("faulty")
        .with_cores(4)
        .unwrap()
        .with_failure_injection(0.5, 1234)
        .with_max_task_failures(6);
    let sc = SparkletContext::new(conf);
    let data: Vec<u64> = (0..10_000).collect();
    let sum: u64 = sc
        .parallelize(data, 16)
        .map(|x| x * 3)
        .map_to_pair(|x| (x % 5, x))
        .reduce_by_key(|a, b| a + b)
        .values()
        .collect()
        .iter()
        .sum();
    assert_eq!(sum, (0..10_000u64).map(|x| x * 3).sum::<u64>());
    assert!(
        sc.metrics().total_retries() > 0,
        "failure injection should have caused retries"
    );
}

#[test]
fn failure_injection_recovers_on_every_backend() {
    // The retry-from-lineage property must hold regardless of the
    // execution substrate: for every registered executor backend and a
    // spread of injection seeds, a multi-stage shuffle job converges to
    // the oracle sum and the injected faults really fired.
    for backend in ExecutorRegistry::names() {
        for seed in [7u64, 1234, 9999] {
            let conf = SparkletConf::new("faulty")
                .with_cores(4)
                .unwrap()
                .with_executor_backend(backend)
                .unwrap()
                .with_failure_injection(0.4, seed)
                .with_max_task_failures(8);
            let sc = SparkletContext::new(conf);
            let sum: u64 = sc
                .parallelize((0..5_000u64).collect::<Vec<_>>(), 12)
                .map(|x| x * 3)
                .map_to_pair(|x| (x % 5, x))
                .reduce_by_key(|a, b| a + b)
                .values()
                .collect()
                .iter()
                .sum();
            assert_eq!(
                sum,
                (0..5_000u64).map(|x| x * 3).sum::<u64>(),
                "{backend} seed {seed}"
            );
            assert!(
                sc.metrics().total_retries() > 0,
                "{backend} seed {seed}: injection never fired"
            );
        }
    }
}

#[test]
fn shuffle_pipeline_agrees_across_backends() {
    // Same two-shuffle job on every backend: identical results, and
    // every recorded stage is tagged with the backend that ran it.
    let mut outputs = Vec::new();
    for backend in ExecutorRegistry::names() {
        let sc = sc_with_backend(3, backend);
        let mut got = sc
            .parallelize((0..2_000u64).collect::<Vec<_>>(), 7)
            .map_to_pair(|x| (x % 13, x))
            .reduce_by_key(|a, b| a + b)
            .map_to_pair(|(k, sum)| (sum % 3, k))
            .group_by_key()
            .collect();
        got.sort_by_key(|(k, _)| *k);
        for (_, vs) in got.iter_mut() {
            vs.sort_unstable();
        }
        let stages = sc.metrics().stages();
        assert!(!stages.is_empty(), "{backend}");
        assert!(
            stages.iter().all(|s| s.backend == backend),
            "{backend}: stage tagged with wrong backend"
        );
        outputs.push((backend, got));
    }
    for pair in outputs.windows(2) {
        assert_eq!(
            pair[0].1, pair[1].1,
            "{} and {} disagree",
            pair[0].0, pair[1].0
        );
    }
}

#[test]
fn stage_metrics_carry_executor_counters() {
    let sc = sc_with_backend(2, "work-stealing");
    let _ = sc
        .parallelize((0..500u32).collect::<Vec<_>>(), 8)
        .map_to_pair(|x| (x % 3, x))
        .reduce_by_key(|a, b| a + b)
        .collect();
    let stages = sc.metrics().stages();
    assert!(stages.iter().all(|s| s.backend == "work-stealing"));
    assert!(stages.iter().all(|s| s.queue_wait_ms >= 0.0));
    // The report surfaces the executor gauge and steal totals.
    let report = sc.metrics().report();
    assert!(report.contains("steals"), "{report}");
    assert!(report.contains("tasks active"), "{report}");
}

#[test]
fn sequential_backend_caps_parallelism_at_one() {
    let sc = sc_with_backend(4, "sequential");
    assert_eq!(sc.default_parallelism(), 1);
    assert_eq!(sc.executor().name(), "sequential");
    // Jobs still run correctly, just single-threaded.
    let data: Vec<u32> = (0..100).collect();
    assert_eq!(sc.parallelize(data.clone(), 5).collect(), data);
}

#[test]
fn metrics_record_stages() {
    let sc = sc(2);
    let rdd = sc.parallelize((0..100u32).collect::<Vec<_>>(), 4);
    let _ = rdd
        .map_to_pair(|x| (x % 3, x))
        .reduce_by_key(|a, b| a + b)
        .collect();
    let stages = sc.metrics().stages();
    use rdd_eclat::sparklet::metrics::StageKind;
    assert!(stages.iter().any(|s| s.kind == StageKind::ShuffleMap));
    assert!(stages.iter().any(|s| s.kind == StageKind::Result));
}

#[test]
fn text_file_roundtrip() {
    let sc = sc(2);
    let dir = std::env::temp_dir().join("sparklet_test_io");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let input = dir.join("input.txt");
    std::fs::write(&input, "1 2 3\n4 5\n6\n").unwrap();
    let rdd = sc.text_file(input.to_str().unwrap(), 1).unwrap();
    assert_eq!(rdd.count(), 3);
    let out_dir = dir.join("out");
    rdd.save_as_text_file(out_dir.to_str().unwrap()).unwrap();
    let saved = std::fs::read_to_string(out_dir.join("part-00000")).unwrap();
    assert_eq!(saved, "1 2 3\n4 5\n6\n");
}

#[test]
fn sample_is_deterministic_and_proportional() {
    let sc = sc(4);
    let rdd = sc.parallelize((0..10_000u32).collect::<Vec<_>>(), 8);
    let a = rdd.sample(0.1, 99).collect();
    let b = rdd.sample(0.1, 99).collect();
    assert_eq!(a, b, "same seed must give same sample");
    let frac = a.len() as f64 / 10_000.0;
    assert!((0.07..0.13).contains(&frac), "fraction {frac}");
}

#[test]
fn distinct_via_reduce() {
    let sc = sc(2);
    let data = vec![1u32, 2, 2, 3, 3, 3, 4];
    let mut got: Vec<u32> = sc
        .parallelize(data, 3)
        .map_to_pair(|x| (x, ()))
        .reduce_by_key(|_, _| ())
        .keys()
        .collect();
    got.sort_unstable();
    assert_eq!(got, vec![1, 2, 3, 4]);
}

#[test]
fn union_concatenates() {
    let sc = sc(2);
    let a = sc.parallelize(vec![1u8, 2], 1);
    let b = sc.parallelize(vec![3u8, 4], 2);
    let u = a.union(&b);
    assert_eq!(u.num_partitions(), 3);
    assert_eq!(u.collect(), vec![1, 2, 3, 4]);
}

#[test]
fn accumulator_from_tasks() {
    let sc = sc(4);
    let acc = sc.accumulator(|| 0u64);
    let acc2 = acc.clone();
    sc.parallelize((0..1000u64).collect::<Vec<_>>(), 8)
        .foreach_partition(move |_, items| {
            acc2.add(items.iter().sum::<u64>());
        });
    assert_eq!(acc.value(), (0..1000u64).sum::<u64>());
}

#[test]
fn broadcast_shared_with_tasks() {
    let sc = sc(4);
    let lookup: HashMap<u32, &'static str> =
        vec![(0, "zero"), (1, "one")].into_iter().collect();
    let b = sc.broadcast(lookup);
    let rdd = sc.parallelize(vec![0u32, 1, 0, 1, 1], 2);
    let named: Vec<&'static str> = rdd.map(move |x| b.value()[&x]).collect();
    assert_eq!(named, vec!["zero", "one", "zero", "one", "one"]);
}

#[test]
fn aggregate_by_key_mean() {
    let sc = sc(3);
    let pairs = vec![(1u8, 2.0f64), (1, 4.0), (2, 6.0), (1, 6.0), (2, 10.0)];
    let means: HashMap<u8, f64> = sc
        .parallelize(pairs, 3)
        .aggregate_by_key(
            (0.0f64, 0usize),
            |c, v| {
                c.0 += v;
                c.1 += 1;
            },
            |c, o| {
                c.0 += o.0;
                c.1 += o.1;
            },
        )
        .map_values(|(s, n)| s / n as f64)
        .collect_as_map();
    assert_eq!(means[&1], 4.0);
    assert_eq!(means[&2], 8.0);
}

#[test]
fn fold_by_key_max() {
    let sc = sc(2);
    let pairs: Vec<(u8, u32)> = vec![(1, 5), (2, 9), (1, 12), (2, 3)];
    let maxes = sc
        .parallelize(pairs, 2)
        .fold_by_key(0, |a, b| a.max(b))
        .collect_as_map();
    assert_eq!(maxes[&1], 12);
    assert_eq!(maxes[&2], 9);
}

#[test]
fn cogroup_collects_both_sides() {
    let sc = sc(2);
    let a = sc.parallelize(
        vec![
            (1u8, "x".to_string()),
            (1, "y".to_string()),
            (2, "z".to_string()),
        ],
        2,
    );
    let b = sc.parallelize(vec![(1u8, 10u32), (3, 30)], 2);
    let mut got = a.cogroup(&b).collect();
    got.sort_by_key(|(k, _)| *k);
    assert_eq!(got.len(), 3);
    let (k1, (vs1, ws1)) = &got[0];
    assert_eq!(*k1, 1);
    let mut vs1 = vs1.clone();
    vs1.sort();
    assert_eq!(vs1, vec!["x".to_string(), "y".to_string()]);
    assert_eq!(ws1, &vec![10]);
    assert_eq!(got[1], (2, (vec!["z".to_string()], vec![])));
    assert_eq!(got[2], (3, (vec![], vec![30])));
}

#[test]
fn count_by_value_and_take_ordered() {
    let sc = sc(2);
    let rdd = sc.parallelize(vec![3u32, 1, 3, 2, 3, 1], 3);
    let counts = rdd.count_by_value();
    assert_eq!(counts[&3], 3);
    assert_eq!(counts[&1], 2);
    assert_eq!(counts[&2], 1);
    let rdd2 = sc.parallelize((0..100u32).rev().collect::<Vec<_>>(), 5);
    assert_eq!(rdd2.take_ordered(4), vec![0, 1, 2, 3]);
    assert_eq!(rdd2.top(3), vec![99, 98, 97]);
}

#[test]
fn constrained_budget_spills_and_stays_correct() {
    // A 4 KiB budget forces the wordcount-style shuffle to spill blocks
    // to disk; results must be oracle-identical and the spill counters
    // must show both spills and transparent reloads.
    let conf = SparkletConf::new("spill")
        .with_cores(3)
        .unwrap()
        .with_memory_budget_bytes(4 * 1024)
        .unwrap()
        .with_shared_nothing(true);
    let sc = SparkletContext::new(conf);
    let pairs: Vec<(u32, u64)> = (0..20_000).map(|i| (i % 257, 1u64)).collect();
    let mut oracle: HashMap<u32, u64> = HashMap::new();
    for (k, v) in &pairs {
        *oracle.entry(*k).or_insert(0) += v;
    }
    let got = sc
        .parallelize(pairs, 6)
        .reduce_by_key(|a, b| a + b)
        .collect_as_map();
    assert_eq!(got, oracle);
    assert!(
        sc.shuffle_manager().spilled_blocks() > 0,
        "budget never spilled: {}",
        sc.shuffle_manager().spill_summary()
    );
    assert!(
        sc.shuffle_manager().spill_reloads() > 0,
        "reduce side never reloaded a spilled block"
    );
    // the spill delta landed in the per-stage metrics
    assert!(sc.metrics().total_spilled_blocks() > 0);
    // exact byte accounting: bytes_written equals the stage-level sum
    assert_eq!(
        sc.metrics().total_shuffle_bytes(),
        sc.shuffle_manager().bytes_written()
    );
}

#[test]
fn retry_from_lineage_with_spilled_blocks() {
    // Failure injection + a tiny budget: map-stage retries re-run over a
    // shuffle whose surviving blocks sit on disk; clear_shuffle must
    // wipe spilled state cleanly so the job still converges.
    for seed in [3u64, 77] {
        let conf = SparkletConf::new("spill-retry")
            .with_cores(4)
            .unwrap()
            .with_memory_budget_bytes(2 * 1024)
            .unwrap()
            .with_failure_injection(0.4, seed)
            .with_max_task_failures(8);
        let sc = SparkletContext::new(conf);
        let sum: u64 = sc
            .parallelize((0..8_000u64).collect::<Vec<_>>(), 10)
            .map_to_pair(|x| (x % 7, x))
            .reduce_by_key(|a, b| a + b)
            .values()
            .collect()
            .iter()
            .sum();
        assert_eq!(sum, (0..8_000u64).sum::<u64>(), "seed {seed}");
        assert!(sc.metrics().total_retries() > 0, "seed {seed}: no retries");
        assert!(
            sc.shuffle_manager().spilled_blocks() > 0,
            "seed {seed}: nothing spilled"
        );
    }
}

#[test]
fn shared_nothing_mode_verifies_serialized_boundary() {
    // With the assertion mode on, every block is decode-verified on
    // write and ownership-checked on fetch; a two-shuffle pipeline runs
    // clean end-to-end.
    let conf = SparkletConf::new("shared-nothing")
        .with_cores(2)
        .unwrap()
        .with_shared_nothing(true);
    let sc = SparkletContext::new(conf);
    let mut got = sc
        .parallelize((0..500u64).collect::<Vec<_>>(), 4)
        .map_to_pair(|x| (x % 9, x))
        .reduce_by_key(|a, b| a + b)
        .map_to_pair(|(k, v)| (v % 2, k))
        .group_by_key()
        .collect();
    got.sort_by_key(|(k, _)| *k);
    let total: u64 = got.iter().map(|(_, ks)| ks.len() as u64).sum();
    assert_eq!(total, 9);
}

#[test]
fn shared_parent_shuffle_runs_once() {
    // Two actions over the same shuffled rdd: second should reuse the
    // completed shuffle (is_completed guard).
    let sc = sc(2);
    let pairs = sc
        .parallelize((0..100u32).map(|i| (i % 5, i)).collect::<Vec<_>>(), 4)
        .reduce_by_key(|a, b| a + b);
    let n1 = pairs.count();
    let stages_after_first = sc.metrics().stages().len();
    let n2 = pairs.count();
    let stages_after_second = sc.metrics().stages().len();
    assert_eq!(n1, n2);
    // Second job adds only a result stage, not another map stage.
    assert_eq!(stages_after_second - stages_after_first, 1);
}
