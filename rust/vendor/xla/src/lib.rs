//! Offline stub of the `xla` (xla-rs) API surface that
//! `rdd_eclat::runtime` consumes. The container image does not ship the
//! PJRT shared library, so this crate makes the runtime module *compile*
//! while every entry point fails fast at runtime with a clear message.
//!
//! All runtime callers are already gated on
//! `runtime::artifacts_available()` (the artifacts manifest existing), so
//! tests and benches skip cleanly instead of hitting these stubs. When a
//! real PJRT toolchain is present, point `rust/Cargo.toml` at the real
//! `xla` crate — the type and method names below mirror it exactly.

use std::fmt;

/// Error type for every stub entry point.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Self {
            msg: format!(
                "{what}: XLA/PJRT runtime not available in this build \
                 (offline stub; see rust/vendor/xla)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({path})"
        )))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Compiled executable (stub: execution always fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub: constructors succeed so argument packing
/// type-checks; readback always fails).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar<T>(_value: T) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple2"))
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple3"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stubs_fail_fast_with_clear_message() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline stub"));
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[3]).is_err());
    }
}
