//! Experiment configuration shared by CLI and benches.

/// Knobs for a figure regeneration run. Environment overrides (used by
/// CI and the quick test path):
/// * `REPRO_SCALE`  — dataset scale factor (default 0.25)
/// * `REPRO_SEED`   — generator seed (default 2019)
/// * `REPRO_CORES`  — executor cores (default: machine parallelism)
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Fraction of the full Table-1 dataset size to generate. The paper's
    /// *shape* (algorithm ordering, crossovers) is scale-stable; full
    /// scale (1.0) reproduces Table-1 sizes exactly.
    pub scale: f64,
    pub cores: usize,
    /// `p` for EclatV4/V5 (paper: 10).
    pub p: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            seed: env_u64("REPRO_SEED", 2019),
            scale: env_f64("REPRO_SCALE", 0.25),
            cores: env_usize(
                "REPRO_CORES",
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            ),
            p: 10,
        }
    }
}

impl ExperimentConfig {
    pub fn with_scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores;
        self
    }
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = ExperimentConfig::default();
        assert!(c.scale > 0.0);
        assert!(c.cores >= 1);
        assert_eq!(c.p, 10);
    }

    #[test]
    fn builders() {
        let c = ExperimentConfig::default().with_scale(0.5).with_cores(2);
        assert_eq!(c.scale, 0.5);
        assert_eq!(c.cores, 2);
    }
}
