//! Zero-dependency binary codec for the shuffle data plane.
//!
//! Everything that crosses a wide (shuffle) dependency is serialized
//! through [`SerDe`] into owned byte blocks, so
//!
//! * shuffle byte accounting is **exact** (`bytes == block.len()`, not a
//!   `size_of`-based estimate),
//! * blocks can be spilled to disk and reloaded verbatim
//!   ([`super::block::BlockStore`]), and
//! * a block is process-boundary-ready: it reconstructs from its bytes
//!   alone, which is the stepping stone to the multi-process executor
//!   backend (ROADMAP).
//!
//! The format is deliberately boring: little-endian fixed-width scalars,
//! `u64` length prefixes for sequences, one tag byte for enums. Records
//! inside a block get an additional per-record `u32` length frame
//! ([`encode_records`]) so a corrupt or truncated payload fails decoding
//! loudly instead of smearing into neighbouring records.
//!
//! Implementation invariant relied on by the `Vec<T>` length guard:
//! every `SerDe` impl for a non-zero-sized type writes **at least one
//! byte** per value. Keep that true for new impls.

use std::fmt;

/// Typed decode failures. Encoding is infallible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SerDeError {
    /// Ran off the end of the buffer.
    Eof { needed: usize, remaining: usize },
    /// A decoded value failed validation (bad utf-8, bad bool tag, …).
    Invalid { what: &'static str },
    /// The value decoded cleanly but left bytes unconsumed.
    Trailing { remaining: usize },
    /// A framed record's payload consumed a different number of bytes
    /// than its length prefix declared.
    Frame {
        index: usize,
        declared: usize,
        consumed: usize,
    },
}

impl fmt::Display for SerDeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Eof { needed, remaining } => write!(
                f,
                "unexpected end of buffer: needed {needed} bytes, {remaining} remaining"
            ),
            Self::Invalid { what } => write!(f, "invalid encoding: bad {what}"),
            Self::Trailing { remaining } => {
                write!(f, "decode left {remaining} trailing bytes unconsumed")
            }
            Self::Frame {
                index,
                declared,
                consumed,
            } => write!(
                f,
                "record {index} frame mismatch: declared {declared} bytes, consumed {consumed}"
            ),
        }
    }
}

impl std::error::Error for SerDeError {}

/// Cursor over a byte buffer being decoded.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    /// Consume the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], SerDeError> {
        if self.remaining() < n {
            return Err(SerDeError::Eof {
                needed: n,
                remaining: self.remaining(),
            });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn array<const N: usize>(&mut self) -> Result<[u8; N], SerDeError> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }
}

/// Binary serialization for shuffle payloads. Implemented for the
/// primitives, tuples, `String`, `Vec<T>`, `Option<T>`, and the FIM
/// record types (tidsets, equivalence classes, itemsets).
pub trait SerDe: Sized {
    /// Append the encoding of `self` to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode one value from the reader, advancing it.
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError>;

    /// Encode into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }

    /// Decode a whole buffer, rejecting trailing bytes.
    fn from_bytes(bytes: &[u8]) -> Result<Self, SerDeError> {
        let mut r = Reader::new(bytes);
        let v = Self::decode(&mut r)?;
        if r.remaining() != 0 {
            return Err(SerDeError::Trailing {
                remaining: r.remaining(),
            });
        }
        Ok(v)
    }
}

// ------------------------------------------------------------ primitives

macro_rules! le_serde {
    ($($t:ty),* $(,)?) => {$(
        impl SerDe for $t {
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
                Ok(<$t>::from_le_bytes(r.array()?))
            }
        }
    )*};
}

le_serde!(u8, u16, u32, u64, u128, i8, i16, i32, i64, i128, f32, f64);

impl SerDe for usize {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u64).encode(out);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        usize::try_from(u64::decode(r)?).map_err(|_| SerDeError::Invalid {
            what: "usize (overflow)",
        })
    }
}

impl SerDe for isize {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as i64).encode(out);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        isize::try_from(i64::decode(r)?).map_err(|_| SerDeError::Invalid {
            what: "isize (overflow)",
        })
    }
}

impl SerDe for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(*self as u8);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        match u8::decode(r)? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(SerDeError::Invalid { what: "bool tag" }),
        }
    }
}

impl SerDe for char {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u32).encode(out);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        char::from_u32(u32::decode(r)?).ok_or(SerDeError::Invalid {
            what: "char scalar value",
        })
    }
}

impl SerDe for () {
    fn encode(&self, _out: &mut Vec<u8>) {}
    fn decode(_r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        Ok(())
    }
}

// ----------------------------------------------------------- containers

impl SerDe for String {
    fn encode(&self, out: &mut Vec<u8>) {
        self.len().encode(out);
        out.extend_from_slice(self.as_bytes());
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        let len = usize::decode(r)?;
        let bytes = r.take(len)?;
        std::str::from_utf8(bytes)
            .map(str::to_string)
            .map_err(|_| SerDeError::Invalid {
                what: "utf-8 string",
            })
    }
}

impl<T: SerDe> SerDe for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        self.len().encode(out);
        for x in self {
            x.encode(out);
        }
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        let len = usize::decode(r)?;
        // Every non-zero-sized element encodes to >= 1 byte (module
        // invariant), so a declared length past the remaining buffer is
        // corrupt — reject it before trying to allocate for it.
        if std::mem::size_of::<T>() != 0 && len > r.remaining() {
            return Err(SerDeError::Invalid {
                what: "vec length (exceeds buffer)",
            });
        }
        let mut v = Vec::with_capacity(len.min(r.remaining()));
        for _ in 0..len {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl<T: SerDe> SerDe for Option<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(x) => {
                out.push(1);
                x.encode(out);
            }
        }
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        match u8::decode(r)? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            _ => Err(SerDeError::Invalid { what: "option tag" }),
        }
    }
}

impl<T: SerDe, E: SerDe> SerDe for Result<T, E> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Ok(x) => {
                out.push(0);
                x.encode(out);
            }
            Err(e) => {
                out.push(1);
                e.encode(out);
            }
        }
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        match u8::decode(r)? {
            0 => Ok(Ok(T::decode(r)?)),
            1 => Ok(Err(E::decode(r)?)),
            _ => Err(SerDeError::Invalid { what: "result tag" }),
        }
    }
}

impl<T: SerDe> SerDe for Box<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        (**self).encode(out);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        Ok(Box::new(T::decode(r)?))
    }
}

macro_rules! tuple_serde {
    ($(($($name:ident : $idx:tt),+))*) => {$(
        impl<$($name: SerDe),+> SerDe for ($($name,)+) {
            fn encode(&self, out: &mut Vec<u8>) {
                $(self.$idx.encode(out);)+
            }
            fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
                Ok(($($name::decode(r)?,)+))
            }
        }
    )*};
}

tuple_serde! {
    (A: 0)
    (A: 0, B: 1)
    (A: 0, B: 1, C: 2)
    (A: 0, B: 1, C: 2, D: 3)
    (A: 0, B: 1, C: 2, D: 3, E: 4)
}

// -------------------------------------------------------- block framing

/// Serialize a record batch with length-prefixed framing: a `u64` record
/// count, then per record a `u32` payload length followed by the payload.
/// The resulting `Vec<u8>` *is* the shuffle block — its `len()` is the
/// exact byte cost the metrics report.
pub fn encode_records<T: SerDe>(records: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + records.len() * 8);
    records.len().encode(&mut out);
    for rec in records {
        let at = out.len();
        out.extend_from_slice(&[0u8; 4]); // length frame, patched below
        rec.encode(&mut out);
        let len = out.len() - at - 4;
        let len32 = u32::try_from(len).expect("shuffle record exceeds u32::MAX bytes");
        out[at..at + 4].copy_from_slice(&len32.to_le_bytes());
    }
    out
}

/// Decode a block produced by [`encode_records`], verifying every
/// record's frame and rejecting trailing bytes.
pub fn decode_records<T: SerDe>(bytes: &[u8]) -> Result<Vec<T>, SerDeError> {
    let mut r = Reader::new(bytes);
    let count = usize::decode(&mut r)?;
    // Each record costs at least its 4-byte frame.
    if count > r.remaining() / 4 {
        return Err(SerDeError::Invalid {
            what: "record count (exceeds buffer)",
        });
    }
    let mut out = Vec::with_capacity(count);
    for index in 0..count {
        let declared = u32::decode(&mut r)? as usize;
        let start = r.position();
        let rec = T::decode(&mut r)?;
        let consumed = r.position() - start;
        if consumed != declared {
            return Err(SerDeError::Frame {
                index,
                declared,
                consumed,
            });
        }
        out.push(rec);
    }
    if r.remaining() != 0 {
        return Err(SerDeError::Trailing {
            remaining: r.remaining(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: SerDe + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        let back = T::from_bytes(&bytes).expect("roundtrip decode");
        assert_eq!(back, v);
    }

    #[test]
    fn primitive_roundtrips() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u16::MAX);
        roundtrip(0xDEAD_BEEFu32);
        roundtrip(u64::MAX);
        roundtrip(-1i64);
        roundtrip(i32::MIN);
        roundtrip(usize::MAX);
        roundtrip(-7isize);
        roundtrip(true);
        roundtrip(false);
        roundtrip(1.5f32);
        roundtrip(f64::NEG_INFINITY);
        roundtrip('é');
        roundtrip('💾');
        roundtrip(());
    }

    #[test]
    fn container_roundtrips() {
        roundtrip(String::new());
        roundtrip("héllo wörld — 数据".to_string());
        roundtrip(Vec::<u32>::new());
        roundtrip(vec![1u32, 2, 3]);
        roundtrip((0..10_000u32).collect::<Vec<u32>>());
        roundtrip(Some(42u32));
        roundtrip(None::<String>);
        roundtrip(Ok::<u32, String>(7));
        roundtrip(Err::<u32, String>("boom".to_string()));
        assert!(matches!(
            Result::<u32, String>::from_bytes(&[9]),
            Err(SerDeError::Invalid { what: "result tag" })
        ));
        roundtrip(Box::new(7u64));
        roundtrip((1u32, "x".to_string()));
        roundtrip((1u8, (2u16, 3u32), vec![4u64]));
        roundtrip(vec![(Some('a'), vec![1u32]), (None, vec![])]);
    }

    #[test]
    fn bad_inputs_are_typed_errors_not_panics() {
        // truncated
        let bytes = 12345u64.to_bytes();
        assert!(matches!(
            u64::from_bytes(&bytes[..4]),
            Err(SerDeError::Eof { .. })
        ));
        // trailing
        let mut bytes = 1u32.to_bytes();
        bytes.push(0);
        assert!(matches!(
            u32::from_bytes(&bytes),
            Err(SerDeError::Trailing { remaining: 1 })
        ));
        // invalid bool tag
        assert!(matches!(
            bool::from_bytes(&[7]),
            Err(SerDeError::Invalid { .. })
        ));
        // invalid utf-8
        let mut s = 2usize.to_bytes();
        s.extend_from_slice(&[0xFF, 0xFE]);
        assert!(matches!(
            String::from_bytes(&s),
            Err(SerDeError::Invalid { .. })
        ));
        // vec length past the buffer
        let huge = u64::MAX.to_bytes();
        assert!(matches!(
            Vec::<u32>::from_bytes(&huge),
            Err(SerDeError::Invalid { .. })
        ));
    }

    #[test]
    fn record_framing_roundtrip_and_exact_size() {
        let recs: Vec<(u32, String)> = (0..50)
            .map(|i| (i, format!("value-{i}-ñ")))
            .collect();
        let block = encode_records(&recs);
        // exactness: the block length is the byte cost, nothing hidden
        let expected: usize = 8 + recs
            .iter()
            .map(|r| 4 + r.to_bytes().len())
            .sum::<usize>();
        assert_eq!(block.len(), expected);
        let back: Vec<(u32, String)> = decode_records(&block).unwrap();
        assert_eq!(back, recs);
        // empty batch
        let empty = encode_records::<u32>(&[]);
        assert_eq!(empty.len(), 8);
        assert_eq!(decode_records::<u32>(&empty).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn corrupt_frames_fail_loudly() {
        let block = encode_records(&[(1u32, 2u32), (3, 4)]);
        // shrink a record's declared length -> frame mismatch
        let mut bad = block.clone();
        bad[8] = 4; // first frame says 4 bytes, record consumes 8
        assert!(matches!(
            decode_records::<(u32, u32)>(&bad),
            Err(SerDeError::Frame { index: 0, .. })
        ));
        // truncate mid-record -> Eof
        assert!(matches!(
            decode_records::<(u32, u32)>(&block[..block.len() - 2]),
            Err(SerDeError::Eof { .. })
        ));
        // bogus record count -> invalid
        let mut bogus = block.clone();
        bogus[..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            decode_records::<(u32, u32)>(&bogus),
            Err(SerDeError::Invalid { .. })
        ));
        // wrong type view of valid bytes -> some typed error, not UB
        assert!(decode_records::<String>(&block).is_err());
    }
}
