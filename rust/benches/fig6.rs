//! Bench target: Fig. 6 — scalability on increasing T10I4D100K size
//! (doubled 1x..16x) at min_sup = 0.05.

use rdd_eclat::coordinator::{experiments, report, ExperimentConfig};

fn main() {
    let cfg = ExperimentConfig::default();
    let suite = experiments::fig_scaling(&cfg);
    suite.finish();
    println!(
        "{}",
        report::render_claims(&[report::check_linear_scaling(&suite)])
    );
}
