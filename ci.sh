#!/usr/bin/env bash
# Tier-1 verification, run on every PR (locally or by the GitHub
# workflow): release build, the full rust test suite, formatting, and
# the python kernel/model tests.
#
# The build is fully offline: external crates are vendored shims under
# rust/vendor (see rust/Cargo.toml), so no registry access is needed.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== bench smoke (regenerates BENCH_fim.json on a tiny dataset)"
# Keeps the perf-trajectory artifact green: a tiny-scale sweep of every
# registered engine x executor backend must run and emit parseable
# JSON. BENCH_SMOKE_SCALE overrides the dataset scale (default 0.02,
# ~2k transactions).
REPRO_SCALE="${BENCH_SMOKE_SCALE:-0.02}" cargo run --release --quiet -- \
    bench --dataset t10 --min-sup 0.02 --out BENCH_fim.json
python3 - <<'EOF'
import json
rows = json.load(open("BENCH_fim.json"))
assert rows, "bench smoke wrote an empty BENCH_fim.json"
assert all("engine" in r and "backend" in r and "wall_ms" in r for r in rows), rows[:1]
backends = {r["backend"] for r in rows}
assert {"fifo", "work-stealing", "sequential"} <= backends, backends
# kernel counters + exact shuffle/spill/backpressure fields: present and
# sane on every row (the serialized-block data plane reports exact
# bytes, spill counters, and AIMD backpressure state)
counters = [
    "kernel_intersections",
    "kernel_early_aborts",
    "kernel_repr_switches",
    "kernel_bytes_allocated",
    "kernel_nanos",
    "shuffle_bytes",
    "spilled_blocks",
    "spill_reloads",
    "bp_shrinks",
    "bp_recoveries",
    "bp_watermark_bytes",
]
# task-duration distribution fields from the event subsystem: present
# and sane on every row (p50 <= p95 <= p99, skew >= 0)
percentiles = ["task_p50_ms", "task_p95_ms", "task_p99_ms", "task_skew"]
for r in rows:
    assert "tidset" in r, r
    assert "memory_budget_mb" in r and "bp_effective_batch" in r, r
    for k in counters:
        assert k in r, (k, r)
        assert isinstance(r[k], int) and r[k] >= 0, (k, r[k])
    for k in percentiles:
        assert k in r, (k, r)
        assert isinstance(r[k], (int, float)) and r[k] >= 0, (k, r[k])
    assert r["task_p50_ms"] <= r["task_p95_ms"] <= r["task_p99_ms"], r
    # kernel throughput: every row carries intersections_per_sec, and it
    # must be non-zero wherever the engine actually intersected tidsets
    # (apriori/fp-growth never do — their rows are legitimately 0.0)
    assert "intersections_per_sec" in r, r
    ips = r["intersections_per_sec"]
    assert isinstance(ips, (int, float)) and ips >= 0, (ips, r)
    if r["kernel_intersections"] > 0:
        assert ips > 0, ("intersecting row reports zero throughput", r)
# the tidset sweep must cover the full representation axis
tidsets = {r["tidset"] for r in rows}
assert {"vec", "bitmap", "diffset", "hybrid"} <= tidsets, tidsets
# the streaming backpressure probe row rides along
probe = [r for r in rows if r["engine"] == "incremental-stream"]
assert probe, "missing incremental-stream backpressure probe row"
assert all(r["bp_watermark_bytes"] > 0 for r in probe), probe
print(
    f"BENCH_fim.json OK: {len(rows)} rows, backends: {sorted(backends)}, "
    f"tidsets: {sorted(tidsets)}, bp probe rows: {len(probe)}"
)
EOF

echo "== bench smoke under a constrained memory budget (spill path)"
# One engine, larger dataset slice, 1 MiB shuffle budget: blocks must
# actually spill to disk and the run must still complete correctly.
# BENCH_SPILL_SCALE overrides the dataset scale (default 0.5, ~50k
# transactions — enough serialized shuffle volume to exceed 1 MiB).
REPRO_SCALE="${BENCH_SPILL_SCALE:-0.5}" SPARKLET_MEMORY_MB=1 cargo run --release --quiet -- \
    bench --dataset t10 --min-sup 0.02 --engines eclat-v1 --executor fifo \
    --tidset vec --out BENCH_spill.json
python3 - <<'EOF'
import json
rows = json.load(open("BENCH_spill.json"))
assert rows, "constrained bench wrote an empty BENCH_spill.json"
batch = [r for r in rows if r["engine"] != "incremental-stream"]
assert batch and all(r["memory_budget_mb"] == 1 for r in batch), batch
spilled = sum(r["spilled_blocks"] for r in rows)
reloads = sum(r["spill_reloads"] for r in rows)
assert spilled > 0, f"1 MiB budget never spilled a block: {rows}"
print(f"spill smoke OK: {spilled} blocks spilled / {reloads} reloads under a 1 MiB budget")
EOF

echo "== event-log smoke (mine --event-log + timeline replay)"
# A tiny mine persists its scheduler/task/shuffle events as JSONL; every
# line must parse, timestamps must be monotone, job/stage/task spans
# must balance, and the timeline command must replay the log offline.
REPRO_SCALE=0.02 cargo run --release --quiet -- \
    mine --dataset t10 --min-sup 0.02 --engine eclat-v1 \
    --event-log EVENTS_mine.jsonl
python3 - <<'EOF'
import json
lines = [l for l in open("EVENTS_mine.jsonl") if l.strip()]
assert lines, "mine --event-log wrote an empty log"
events = [json.loads(l) for l in lines]  # every line is valid JSON
last_t = -1.0
open_jobs, open_stages, open_tasks = set(), set(), set()
starts = ends = 0
for e in events:
    assert "t_ms" in e and "type" in e, e
    assert e["t_ms"] >= last_t, f"timestamps went backwards at {e}"
    last_t = e["t_ms"]
    t = e["type"]
    if t == "JobStart":
        open_jobs.add(e["job"])
    elif t == "JobEnd":
        open_jobs.remove(e["job"])
    elif t == "StageSubmitted":
        assert e["job"] in open_jobs, f"stage outside job span: {e}"
        open_stages.add(e["stage"])
    elif t == "StageCompleted":
        open_stages.remove(e["stage"])
    elif t == "TaskStart":
        assert e["stage"] in open_stages, f"task outside stage span: {e}"
        open_tasks.add((e["stage"], e["task"], e["attempt"]))
        starts += 1
    elif t == "TaskEnd":
        open_tasks.remove((e["stage"], e["task"], e["attempt"]))
        ends += 1
assert not open_jobs and not open_stages and not open_tasks, (
    open_jobs, open_stages, open_tasks)
assert starts == ends > 0, (starts, ends)
kinds = {e["type"] for e in events}
assert "KernelSnapshot" in kinds, kinds
print(f"EVENTS_mine.jsonl OK: {len(events)} events, {starts} tasks, kinds: {sorted(kinds)}")
EOF
cargo run --release --quiet -- timeline --log EVENTS_mine.jsonl | head -40

echo "== multi-process smoke (mine --executor multi-process + worker fleet)"
# The same tiny mine on the multi-process backend: the driver must fork
# and register >= 2 worker processes, tasks must carry worker ids,
# workers must fetch shuffle blocks from the driver, and the itemset
# histogram must be identical to a sequential-backend run (remote
# bottom-up == in-process oracle).
REPRO_SCALE=0.02 cargo run --release --quiet -- \
    mine --dataset t10 --min-sup 0.02 --engine eclat-v1 \
    --executor sequential > MINE_seq.txt
REPRO_SCALE=0.02 SPARKLET_WORKERS=2 cargo run --release --quiet -- \
    mine --dataset t10 --min-sup 0.02 --engine eclat-v1 \
    --executor multi-process --event-log EVENTS_mp.jsonl > MINE_mp.txt
python3 - <<'EOF'
import json, re
events = [json.loads(l) for l in open("EVENTS_mp.jsonl") if l.strip()]
workers = {e["worker"] for e in events if e["type"] == "WorkerRegistered"}
assert len(workers) >= 2, f"want >= 2 registered workers, got {workers}"
assert any(e["type"] == "TaskEnd" and e.get("worker") for e in events), \
    "no task span carries a worker id"
fetches = sum(1 for e in events if e["type"] == "RemoteFetch")
assert fetches > 0, "workers never fetched shuffle blocks from the driver"
def histogram(path):
    return [l for l in open(path) if re.match(r"\s+L\d+: \d+", l)]
seq, mp = histogram("MINE_seq.txt"), histogram("MINE_mp.txt")
assert seq and seq == mp, f"itemset histograms diverge:\nseq={seq}\nmp={mp}"
print(f"multi-process smoke OK: workers {sorted(workers)}, "
      f"{fetches} remote fetches, histogram identical to sequential")
EOF
# replay the multi-process log: task bars must group into worker lanes
cargo run --release --quiet -- timeline --log EVENTS_mp.jsonl | head -40

echo "== chaos smoke (seeded fault plan: spill fault + worker kill, answer identical)"
# A seeded, replayable fault schedule — the first spill reload fails
# like an unreadable disk AND worker w0 dies after its first task —
# must recover through the retry policy and lineage re-execution, and
# the itemset histogram must be identical to a fault-free sequential
# run. Scale 0.5 under a 1 MiB budget forces real spill traffic (same
# sizing as the spill smoke above); the injection-counter proof that
# the schedule fires lives in rust/tests/crash_anywhere.rs.
REPRO_SCALE=0.5 cargo run --release --quiet -- \
    mine --dataset t10 --min-sup 0.02 --engine eclat-v1 \
    --executor sequential > MINE_chaos_seq.txt
REPRO_SCALE=0.5 SPARKLET_WORKERS=2 SPARKLET_MEMORY_MB=1 cargo run --release --quiet -- \
    mine --dataset t10 --min-sup 0.02 --engine eclat-v1 \
    --executor multi-process \
    --fault-plan 'seed=7; spill_read:nth=1; worker_kill=w0:1' \
    --event-log EVENTS_chaos.jsonl > MINE_chaos.txt
python3 - <<'EOF'
import json, re
def hist(path):
    return [l.strip() for l in open(path) if re.match(r"\s+L\d+: \d+", l)]
chaos, seq = hist("MINE_chaos.txt"), hist("MINE_chaos_seq.txt")
assert chaos and chaos == seq, f"chaos histogram diverged from the oracle:\n{chaos}\n{seq}"
events = [json.loads(l) for l in open("EVENTS_chaos.jsonl") if l.strip()]
lost = [e["worker"] for e in events if e["type"] == "WorkerLost"]
assert lost == ["w0"], f"want exactly one injected w0 death, got {lost}"
retried = any(e["type"] == "TaskStart" and e["attempt"] > 0 for e in events)
assert retried, "the killed worker's task never retried"
print(f"chaos smoke OK: w0 killed + spill fault injected, "
      f"histogram identical to sequential ({len(chaos)} lengths)")
EOF

echo "== serve smoke (long-lived server: cache, subsumption, shedding, shutdown)"
# A background `serve` on one persistent context answers a miss, an
# exact repeat, and a subsumed query (higher threshold, filtered from
# cache); histograms must equal the sequential batch path at both
# thresholds. A second server under a 1 MiB budget must reject an
# oversized request with exit 3 (typed Overloaded). Both shut down
# gracefully via `query --shutdown`, and the event logs must carry
# balanced Request* spans with cache_hit labels.
SERVE_SOCK="/tmp/sparklet-serve-$$.sock"
REPRO_SCALE=0.02 cargo run --release --quiet -- \
    serve --socket "$SERVE_SOCK" --executor fifo \
    --event-log EVENTS_serve.jsonl > SERVE_out.txt 2>&1 &
SERVE_PID=$!
for _ in $(seq 1 100); do [ -S "$SERVE_SOCK" ] && break; sleep 0.1; done
[ -S "$SERVE_SOCK" ] || { echo "serve never bound $SERVE_SOCK"; cat SERVE_out.txt; exit 1; }
cargo run --release --quiet -- query --socket "$SERVE_SOCK" \
    --dataset t10 --min-sup 0.02 > QUERY_miss.txt
cargo run --release --quiet -- query --socket "$SERVE_SOCK" \
    --dataset t10 --min-sup 0.02 > QUERY_exact.txt
cargo run --release --quiet -- query --socket "$SERVE_SOCK" \
    --dataset t10 --min-sup 0.05 > QUERY_subsumed.txt
grep -q "cache: miss" QUERY_miss.txt
grep -q "cache: exact" QUERY_exact.txt
grep -q "cache: subsumed" QUERY_subsumed.txt
# sequential-oracle histograms through the batch path, both thresholds
REPRO_SCALE=0.02 cargo run --release --quiet -- \
    mine --dataset t10 --min-sup 0.02 --engine sequential \
    --executor sequential > MINE_low.txt
REPRO_SCALE=0.02 cargo run --release --quiet -- \
    mine --dataset t10 --min-sup 0.05 --engine sequential \
    --executor sequential > MINE_high.txt
python3 - <<'EOF'
import re
def hist(path):
    return [l.strip() for l in open(path) if re.match(r"\s+L\d+: \d+", l)]
miss, exact, sub = hist("QUERY_miss.txt"), hist("QUERY_exact.txt"), hist("QUERY_subsumed.txt")
low, high = hist("MINE_low.txt"), hist("MINE_high.txt")
assert miss and miss == exact == low, f"low-threshold histograms diverge:\n{miss}\n{exact}\n{low}"
assert sub and sub == high, f"subsumed histogram != fresh mine at 0.05:\n{sub}\n{high}"
print(f"serve histograms OK: {len(low)} lengths at 0.02, {len(high)} at 0.05")
EOF
cargo run --release --quiet -- query --socket "$SERVE_SOCK" --shutdown
wait "$SERVE_PID"
# rejection under a tiny memory budget: t40 at scale 0.3 estimates far
# past 1 MiB, so admission must refuse it before mining (exit 3)
SERVE_SOCK2="/tmp/sparklet-serve2-$$.sock"
REPRO_SCALE=0.3 cargo run --release --quiet -- \
    serve --socket "$SERVE_SOCK2" --memory-budget 1 \
    --event-log EVENTS_serve2.jsonl > SERVE2_out.txt 2>&1 &
SERVE2_PID=$!
for _ in $(seq 1 100); do [ -S "$SERVE_SOCK2" ] && break; sleep 0.1; done
[ -S "$SERVE_SOCK2" ] || { echo "serve never bound $SERVE_SOCK2"; cat SERVE2_out.txt; exit 1; }
set +e
cargo run --release --quiet -- query --socket "$SERVE_SOCK2" \
    --dataset t40 --min-sup 0.1 > QUERY_rejected.txt 2>&1
rc=$?
set -e
if [ "$rc" -ne 3 ]; then
    echo "expected exit 3 (Overloaded) from the over-budget query, got $rc"
    cat QUERY_rejected.txt
    exit 1
fi
grep -q "overloaded" QUERY_rejected.txt
cargo run --release --quiet -- query --socket "$SERVE_SOCK2" --shutdown
wait "$SERVE2_PID"
# per-request deadline: a 1 ms budget cannot absorb a fresh mine, so
# the query is rejected typed (exit 3, same "retry later" class as a
# shed) and its span ends RequestRejected{reason: deadline}
SERVE_SOCK3="/tmp/sparklet-serve3-$$.sock"
REPRO_SCALE=0.02 cargo run --release --quiet -- \
    serve --socket "$SERVE_SOCK3" --deadline-ms 1 \
    --event-log EVENTS_serve3.jsonl > SERVE3_out.txt 2>&1 &
SERVE3_PID=$!
for _ in $(seq 1 100); do [ -S "$SERVE_SOCK3" ] && break; sleep 0.1; done
[ -S "$SERVE_SOCK3" ] || { echo "serve never bound $SERVE_SOCK3"; cat SERVE3_out.txt; exit 1; }
set +e
cargo run --release --quiet -- query --socket "$SERVE_SOCK3" \
    --dataset t10 --min-sup 0.02 > QUERY_deadline.txt 2>&1
rc=$?
set -e
if [ "$rc" -ne 3 ]; then
    echo "expected exit 3 (DeadlineExceeded) from the 1 ms budget query, got $rc"
    cat QUERY_deadline.txt
    exit 1
fi
grep -qi "deadline" QUERY_deadline.txt
cargo run --release --quiet -- query --socket "$SERVE_SOCK3" --shutdown
wait "$SERVE3_PID"
python3 - <<'EOF'
import json
def spans(path):
    events = [json.loads(l) for l in open(path) if l.strip()]
    reqs = {}
    for e in events:
        if not e["type"].startswith("Request"):
            continue
        reqs.setdefault(e["request"], []).append(e)
    for rid, span in reqs.items():
        types = [e["type"] for e in span]
        assert types[0] == "RequestReceived", (rid, span)
        assert types[-1] in ("RequestCompleted", "RequestRejected"), (rid, span)
        assert types.count("RequestReceived") == 1, (rid, span)
        if types[-1] == "RequestCompleted":
            assert "RequestAdmitted" in types, (rid, span)
    return reqs
served = spans("EVENTS_serve.jsonl")
hits = sorted(s[-1]["cache_hit"] for s in served.values()
              if s[-1]["type"] == "RequestCompleted")
assert hits == ["exact", "miss", "subsumed"], hits
shed = spans("EVENTS_serve2.jsonl")
reasons = [s[-1]["reason"] for s in shed.values()
           if s[-1]["type"] == "RequestRejected"]
assert "overloaded" in reasons, (reasons, shed)
# the deadline server's span: Received -> Admitted (the request DID
# win a slot) -> Rejected with the new typed reason
dead = spans("EVENTS_serve3.jsonl")
reasons3 = [s[-1]["reason"] for s in dead.values()
            if s[-1]["type"] == "RequestRejected"]
assert "deadline" in reasons3, (reasons3, dead)
print(f"serve event spans OK: {len(served)} served ({hits}), "
      f"{len(shed)} on the budgeted server, rejects {reasons}, "
      f"deadline rejects {reasons3}")
EOF
# offline replay tallies the request spans in the footer
cargo run --release --quiet -- timeline --log EVENTS_serve.jsonl | grep "serving:"

echo "== micro-bench smoke (kernel scalar-vs-unrolled gate + diffset kernel)"
# One-rep pass over the intersection + kernel + Bottom-Up micro-benches
# so kernel regressions surface as wall-time deltas in the uploaded
# bench-results artifact, then gate the unrolled bitmap AND+popcount
# kernel at >= 1.3x its scalar reference loop.
REPRO_BENCH_REPS=1 REPRO_BENCH_WARMUP=0 REPRO_MICRO_ONLY=intersect,kernel,bottom-up \
    cargo bench --bench micro
python3 - <<'EOF'
import csv, os
# cargo runs bench binaries from the package dir, so the CSVs land under
# rust/target/bench-results (plain target/ kept as a fallback).
candidates = ("rust/target/bench-results/micro_kernel.csv",
              "target/bench-results/micro_kernel.csv")
path = next((p for p in candidates if os.path.exists(p)), None)
assert path, f"micro_kernel.csv not written to any of {candidates}"
rows = list(csv.DictReader(open(path)))
assert rows, f"{path} is empty"
med = {r["series"]: float(r["median_ms"]) for r in rows}
for s in ("bitmap-into-min-scalar", "bitmap-into-min-unrolled",
          "bitmap-count-scalar", "bitmap-count-unrolled",
          "vec-merge-scalar", "vec-merge-branchless",
          "diffset-subtract-scalar", "diffset-subtract-branchless",
          "class-per-call", "class-batched"):
    assert s in med, (s, sorted(med))
ratio = med["bitmap-into-min-scalar"] / max(med["bitmap-into-min-unrolled"], 1e-9)
assert ratio >= 1.3, (
    f"unrolled bitmap AND+popcount is only {ratio:.2f}x the scalar loop "
    f"(gate: >= 1.3x; medians {med['bitmap-into-min-scalar']:.3f} ms vs "
    f"{med['bitmap-into-min-unrolled']:.3f} ms)")
print(f"kernel micro gate OK: unrolled into-min {ratio:.2f}x scalar "
      f"({len(rows)} series rows in {path})")
EOF

echo "== cargo clippy --all-targets -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    # Advisory by default (same policy as rustfmt below: lint drift
    # should not mask real build/test failures on dev images).
    # CI_CLIPPY_STRICT=1 makes it a hard gate — the GitHub workflow
    # sets it, so lints block merges.
    if ! cargo clippy --all-targets -- -D warnings; then
        if [ "${CI_CLIPPY_STRICT:-0}" = "1" ]; then
            echo "clippy check failed (CI_CLIPPY_STRICT=1)"
            exit 1
        fi
        echo "warn: clippy findings (non-fatal locally; fix before merge)"
    fi
else
    echo "skip: clippy not installed"
fi

echo "== cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    # Advisory by default (images without rustfmt skip it; formatting
    # drift should not mask real failures). CI_FMT_STRICT=1 makes it a
    # hard gate.
    if ! cargo fmt --all -- --check; then
        if [ "${CI_FMT_STRICT:-0}" = "1" ]; then
            echo "formatting check failed (CI_FMT_STRICT=1)"
            exit 1
        fi
        echo "warn: formatting drift detected (non-fatal; run 'cargo fmt')"
    fi
else
    echo "skip: rustfmt not installed"
fi

echo "== python tests"
if python3 -c 'import pytest' >/dev/null 2>&1; then
    (cd python && python3 -m pytest tests -q)
else
    echo "skip: pytest not installed"
fi

echo "== ci.sh OK"
