//! Integration tests for the event bus: ordering invariants under every
//! executor backend, listener isolation during real jobs, bus-derived
//! metrics vs the shuffle counters, and golden event-log replay through
//! the `timeline` module.

use std::collections::HashSet;
use std::sync::Arc;

use rdd_eclat::sparklet::{
    CollectingListener, EventListener, ExecutorRegistry, SparkletConf, SparkletContext,
    SparkletEvent,
};
use rdd_eclat::timeline;

fn sc_with_backend(cores: usize, backend: &str) -> SparkletContext {
    let conf = SparkletConf::new("events-test")
        .with_cores(cores)
        .unwrap()
        .with_executor_backend(backend)
        .unwrap();
    SparkletContext::new(conf)
}

/// One two-shuffle job, oracle-checked so callers know the workload
/// really ran.
fn run_shuffle_job(sc: &SparkletContext) {
    let sum: u64 = sc
        .parallelize((0..2_000u64).collect::<Vec<_>>(), 8)
        .map_to_pair(|x| (x % 13, x))
        .reduce_by_key(|a, b| a + b)
        .map_to_pair(|(_, s)| (s % 3, s))
        .reduce_by_key(|a, b| a + b)
        .values()
        .collect()
        .iter()
        .sum();
    assert_eq!(sum, (0..2_000u64).sum::<u64>());
}

#[test]
fn every_backend_preserves_span_ordering() {
    // Task events are emitted from the task closures, i.e. from whatever
    // thread the backend runs them on (fifo workers, work-stealing
    // workers, or the caller for sequential). Regardless of backend the
    // delivered sequence must satisfy the span invariants: timestamps
    // monotone, JobStart before JobEnd, StageSubmitted before the
    // stage's tasks, TaskStart before the matching TaskEnd, and
    // StageCompleted carrying as many tasks as actually ended.
    for backend in ExecutorRegistry::names() {
        let sc = sc_with_backend(3, backend);
        let collector = CollectingListener::new();
        sc.events().register(Arc::new(collector.clone()));
        run_shuffle_job(&sc);

        let events = collector.snapshot();
        assert!(!events.is_empty(), "{backend}: no events delivered");
        let mut last_t = f64::NEG_INFINITY;
        let mut open_jobs: HashSet<u64> = HashSet::new();
        let mut submitted: HashSet<u64> = HashSet::new();
        let mut open_tasks: HashSet<(u64, usize, usize)> = HashSet::new();
        let mut starts = 0usize;
        let mut ends = 0usize;
        for (t, e) in &events {
            assert!(*t >= last_t, "{backend}: timestamps went backwards");
            last_t = *t;
            match e {
                SparkletEvent::JobStart { job_id } => {
                    assert!(open_jobs.insert(*job_id), "{backend}: job {job_id} reopened");
                }
                SparkletEvent::JobEnd { job_id } => {
                    assert!(
                        open_jobs.remove(job_id),
                        "{backend}: JobEnd {job_id} without JobStart"
                    );
                }
                SparkletEvent::StageSubmitted { job_id, stage_tag, num_tasks, .. } => {
                    assert!(open_jobs.contains(job_id), "{backend}: stage outside job span");
                    assert!(*num_tasks > 0, "{backend}: empty stage submitted");
                    submitted.insert(*stage_tag);
                }
                SparkletEvent::TaskStart { stage_tag, task, attempt, .. } => {
                    assert!(
                        submitted.contains(stage_tag),
                        "{backend}: task before its StageSubmitted"
                    );
                    assert!(
                        open_tasks.insert((*stage_tag, *task, *attempt)),
                        "{backend}: duplicate TaskStart"
                    );
                    starts += 1;
                }
                SparkletEvent::TaskEnd { stage_tag, task, attempt, ok, .. } => {
                    assert!(
                        open_tasks.remove(&(*stage_tag, *task, *attempt)),
                        "{backend}: TaskEnd without TaskStart"
                    );
                    assert!(*ok, "{backend}: unexpected task failure");
                    ends += 1;
                }
                SparkletEvent::StageCompleted { stage_tag, metrics, .. } => {
                    assert!(
                        submitted.contains(stage_tag),
                        "{backend}: StageCompleted before StageSubmitted"
                    );
                    assert!(metrics.num_tasks > 0, "{backend}: completed stage has no tasks");
                }
                _ => {}
            }
        }
        assert!(open_jobs.is_empty(), "{backend}: unbalanced job spans");
        assert!(open_tasks.is_empty(), "{backend}: unbalanced task spans");
        assert!(starts > 0 && starts == ends, "{backend}: {starts} starts / {ends} ends");
    }
}

#[test]
fn bus_derived_metrics_match_shuffle_counters() {
    // The MetricsRegistry is now fed exclusively through the bus
    // (StageCompleted -> MetricsListener). Its aggregate totals must
    // still equal the shuffle manager's own exact byte counter, and the
    // StageCompleted events a second listener sees must sum to the same
    // figures — one source of truth, two subscribers.
    let sc = sc_with_backend(4, "fifo");
    let collector = CollectingListener::new();
    sc.events().register(Arc::new(collector.clone()));
    run_shuffle_job(&sc);

    assert_eq!(
        sc.metrics().total_shuffle_bytes(),
        sc.shuffle_manager().bytes_written()
    );
    let (mut bytes, mut records) = (0u64, 0u64);
    for (_, e) in collector.snapshot() {
        if let SparkletEvent::StageCompleted { metrics, .. } = e {
            bytes += metrics.shuffle_bytes;
            records += metrics.shuffle_records;
        }
    }
    assert_eq!(bytes, sc.metrics().total_shuffle_bytes());
    assert_eq!(records, sc.metrics().total_shuffle_records());
}

#[test]
fn panicking_listener_does_not_break_the_job() {
    struct Bomb;
    impl EventListener for Bomb {
        fn on_event(&self, _t: f64, _e: &SparkletEvent) {
            panic!("listener bomb");
        }
    }
    let sc = sc_with_backend(3, "work-stealing");
    let collector = CollectingListener::new();
    sc.events().register(Arc::new(Bomb));
    sc.events().register(Arc::new(collector.clone()));
    // The job must complete correctly and the well-behaved listener must
    // still receive every event despite the bomb firing on each one.
    run_shuffle_job(&sc);
    assert!(!collector.is_empty());
    assert_eq!(sc.events().dropped(), 0);
    assert!(sc.metrics().stages().len() >= 2);
}

#[test]
fn golden_event_log_replays_to_exact_counts() {
    // Record a real run to JSONL via the conf-wired EventLogWriter, then
    // replay it offline: the timeline must reproduce the exact job,
    // stage, and task counts a live listener observed.
    let path = std::env::temp_dir().join("sparklet_events_golden.jsonl");
    let _ = std::fs::remove_file(&path); // writer appends; start clean
    let conf = SparkletConf::new("golden")
        .with_cores(3)
        .unwrap()
        .with_event_log(path.to_str().unwrap());
    let sc = SparkletContext::try_new(conf).unwrap();
    let collector = CollectingListener::new();
    sc.events().register(Arc::new(collector.clone()));
    run_shuffle_job(&sc);
    run_shuffle_job(&sc); // two jobs -> multiple job spans in one log

    let (mut jobs, mut stages, mut starts, mut ends) = (0usize, 0usize, 0usize, 0usize);
    for (_, e) in collector.snapshot() {
        match e {
            SparkletEvent::JobStart { .. } => jobs += 1,
            SparkletEvent::StageCompleted { .. } => stages += 1,
            SparkletEvent::TaskStart { .. } => starts += 1,
            SparkletEvent::TaskEnd { .. } => ends += 1,
            _ => {}
        }
    }

    let log = std::fs::read_to_string(&path).unwrap();
    let rp = timeline::replay(&log).unwrap();
    assert!(rp.bad_lines.is_empty(), "unparseable lines: {:?}", rp.bad_lines);
    assert_eq!(rp.n_jobs(), jobs);
    assert_eq!(rp.n_stages(), stages);
    assert_eq!(rp.task_starts, starts);
    assert_eq!(rp.task_ends, ends);
    assert_eq!(rp.n_tasks(), ends, "every ended task attempt reconstructed");
    assert_eq!(rp.unknown_events, 0);

    // And the human rendering carries the stats the log encodes.
    let rendered = timeline::render(&rp, 40);
    assert!(rendered.contains("p50"), "{rendered}");
    assert!(rendered.contains("skew"), "{rendered}");
    assert!(rendered.contains(&format!("{} jobs", jobs)), "{rendered}");
    let _ = std::fs::remove_file(&path);
}
