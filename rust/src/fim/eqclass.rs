//! Equivalence classes and Zaki's Bottom-Up search (Algorithm 1 of the
//! paper, transcribed from [12] / the SPMF implementation).
//!
//! Itemsets sharing a (k-1)-length prefix form an equivalence class; each
//! class is an independent sub-lattice, which is precisely what the paper
//! partitions across executors in Phase-3/4. `bottom_up` recursively
//! decomposes a class, intersecting member tidsets pairwise and keeping
//! candidates that clear `min_sup`.

use super::tidset::TidOps;
use super::trimatrix::TriMatrix;
use super::types::{FrequentItemset, Item};
use crate::sparklet::serde::{Reader, SerDe, SerDeError};

/// An equivalence class: all member itemsets share `prefix`; a member is
/// (last item, tidset of `prefix ∪ {item}`).
#[derive(Debug, Clone)]
pub struct EquivalenceClass<TS> {
    pub prefix: Vec<Item>,
    pub members: Vec<(Item, TS)>,
}

impl<TS> EquivalenceClass<TS> {
    /// Workload proxy used by the partitioner ablation: classes with more
    /// members generate more candidates (the paper's §4.4 measure).
    pub fn weight(&self) -> usize {
        self.members.len()
    }
}

/// Classes are the payload of the Phase-3/4 `partitionBy` shuffle, so
/// they serialize generically over the tidset representation.
impl<TS: SerDe> SerDe for EquivalenceClass<TS> {
    fn encode(&self, out: &mut Vec<u8>) {
        self.prefix.encode(out);
        self.members.encode(out);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        Ok(Self {
            prefix: Vec::decode(r)?,
            members: Vec::decode(r)?,
        })
    }
}

/// Reusable buffers for the Bottom-Up recursion (arena-style): spare
/// tidset values whose internal storage `intersect_into_min` overwrites,
/// and spare (emptied) member vectors for child classes. Together with
/// the explicit push/pop prefix stack this amortizes the old
/// clone-per-member recursion's allocations to zero once warm.
struct BottomUpScratch<TS> {
    tidsets: Vec<TS>,
    member_vecs: Vec<Vec<(Item, TS)>>,
}

/// Algorithm 1: Bottom-Up(EC_k). Appends every frequent itemset derived
/// from `class` (sizes `prefix.len() + 2` and deeper) to `out`.
pub fn bottom_up<TS: TidOps>(
    class: &EquivalenceClass<TS>,
    min_sup: u32,
    out: &mut Vec<FrequentItemset>,
) {
    let mut prefix = class.prefix.clone();
    let mut scratch = BottomUpScratch {
        tidsets: Vec::new(),
        member_vecs: Vec::new(),
    };
    bottom_up_rec(&class.members, &mut prefix, 1, min_sup, out, &mut scratch);
    debug_assert_eq!(prefix, class.prefix, "prefix stack must be balanced");
}

/// One recursion level over an explicit prefix stack. Instead of cloning
/// the prefix per member and allocating a fresh tidset per candidate
/// (the old shape), the prefix is pushed/popped in place and candidate
/// tidsets are materialized into pool-recycled buffers by the fused
/// bounded walk (`intersect_into_min`) — failed candidates hand their
/// buffer straight back to the pool.
fn bottom_up_rec<TS: TidOps>(
    members: &[(Item, TS)],
    prefix: &mut Vec<Item>,
    depth: usize,
    min_sup: u32,
    out: &mut Vec<FrequentItemset>,
    scratch: &mut BottomUpScratch<TS>,
) {
    for i in 0..members.len() {
        let (item_i, ref ts_i) = members[i];
        prefix.push(item_i);
        let mut next_members = scratch.member_vecs.pop().unwrap_or_default();
        debug_assert!(next_members.is_empty());
        // §Perf O5+O6+O8 + batching: one fused walk per candidate
        // applies the min_sup bound AND materializes the survivor into
        // a pool-recycled buffer, and the whole class is intersected in
        // one batched kernel call so per-call overhead (clock reads,
        // counter atomics, operand borrows) amortizes across members.
        ts_i.intersect_class_into(
            &members[i + 1..],
            min_sup,
            &mut scratch.tidsets,
            &mut next_members,
            |item_j, sup| {
                let mut items = Vec::with_capacity(prefix.len() + 1);
                items.extend_from_slice(prefix);
                items.push(item_j);
                out.push(FrequentItemset::new(items, sup));
            },
        );
        if !next_members.is_empty() {
            // adaptive representations re-measure the fresh class here
            TS::adapt_class(ts_i, &mut next_members, depth);
            bottom_up_rec(&next_members, prefix, depth + 1, min_sup, out, scratch);
        }
        scratch
            .tidsets
            .extend(next_members.drain(..).map(|(_, ts)| ts));
        scratch.member_vecs.push(next_members);
        prefix.pop();
    }
}

/// Build the 1-length-prefix equivalence classes of frequent 2-itemsets
/// from the vertical dataset (Phase-3 of EclatV1, Algorithm 4 lines
/// 1–16). `vertical` must be sorted in the processing order (the paper
/// sorts by ascending support). Emits the frequent 2-itemsets into
/// `two_itemsets` as a side product.
///
/// `tri_matrix`: when present, prunes infrequent pairs *before* the
/// tidset intersection (`triMatrixMode = true`). Item ids in the matrix
/// are the positions in `vertical` (dense ranks), matching how the RDD
/// algorithms rank items.
pub fn build_classes<TS: TidOps>(
    vertical: &[(Item, TS)],
    min_sup: u32,
    tri_matrix: Option<&TriMatrix>,
    rank_of: impl Fn(Item) -> u32,
    two_itemsets: &mut Vec<FrequentItemset>,
) -> Vec<(usize, EquivalenceClass<TS>)> {
    let n = vertical.len();
    let mut classes = Vec::new();
    let mut spare: Vec<TS> = Vec::new();
    for i in 0..n.saturating_sub(1) {
        let (item_i, ref ts_i) = vertical[i];
        let mut members: Vec<(Item, TS)> = Vec::new();
        // §Perf O5+O6+O8 + batching: each surviving pair is walked
        // exactly once by the fused bounded+materializing kernel, and
        // the whole row is one batched class-intersection call. With a
        // tri-matrix the pre-filter drops infrequent pairs *before* the
        // batch (triMatrixMode = true; survivors are frequent by
        // construction, so the fused walk never aborts).
        let on_survivor = |item_j: Item, sup: u32| {
            two_itemsets.push(FrequentItemset::new(vec![item_i, item_j], sup));
        };
        match tri_matrix {
            Some(m) => ts_i.intersect_class_into(
                vertical[i + 1..]
                    .iter()
                    .filter(|(item_j, _)| m.get_support(rank_of(item_i), rank_of(*item_j)) >= min_sup),
                min_sup,
                &mut spare,
                &mut members,
                on_survivor,
            ),
            None => ts_i.intersect_class_into(
                &vertical[i + 1..],
                min_sup,
                &mut spare,
                &mut members,
                on_survivor,
            ),
        }
        if !members.is_empty() {
            TS::adapt_class(ts_i, &mut members, 0);
            classes.push((
                i,
                EquivalenceClass {
                    prefix: vec![item_i],
                    members,
                },
            ));
        }
    }
    classes
}

/// Decompose 1-prefix classes one level further into 2-length-prefix
/// classes (the paper's §6 future-work: "the results can be explored for
/// the k-length prefixes where k >= 2"). Finer classes → more, smaller
/// parallel units → better balance at high skew. Emits the frequent
/// 3-itemsets discovered during decomposition into `three_itemsets`.
///
/// Returned keys are dense ranks in construction order (prefix-sorted),
/// ready for the same partitioners as the 1-prefix path.
pub fn decompose_to_prefix2<TS: TidOps>(
    classes: Vec<(usize, EquivalenceClass<TS>)>,
    min_sup: u32,
    three_itemsets: &mut Vec<FrequentItemset>,
) -> Vec<(usize, EquivalenceClass<TS>)> {
    let mut out = Vec::new();
    let mut rank = 0usize;
    let mut spare: Vec<TS> = Vec::new();
    for (_, class) in classes {
        for i in 0..class.members.len() {
            let (item_i, ref ts_i) = class.members[i];
            let mut prefix = class.prefix.clone();
            prefix.push(item_i);
            let mut members: Vec<(Item, TS)> = Vec::new();
            // §Perf O5+O6+O8 + batching: fused bounded+materializing
            // walks, one batched kernel call per sub-class row
            ts_i.intersect_class_into(
                &class.members[i + 1..],
                min_sup,
                &mut spare,
                &mut members,
                |item_j, sup| {
                    let mut items = prefix.clone();
                    items.push(item_j);
                    three_itemsets.push(FrequentItemset::new(items, sup));
                },
            );
            if !members.is_empty() {
                TS::adapt_class(ts_i, &mut members, 1);
                out.push((
                    rank,
                    EquivalenceClass {
                        prefix: prefix.clone(),
                        members,
                    },
                ));
                rank += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::tidset::VecTidset;

    /// Tiny database from Zaki's paper style: items 0..4, 6 transactions.
    fn vertical_db() -> (Vec<(Item, VecTidset)>, usize) {
        // txns: 0:{0,1,2} 1:{1,2,3} 2:{0,1,3} 3:{0,1,2,3} 4:{1,2} 5:{0,3}
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 1, 3],
            vec![0, 1, 2, 3],
            vec![1, 2],
            vec![0, 3],
        ];
        let n = txns.len();
        let mut vertical = Vec::new();
        for item in 0..4u32 {
            let tids: Vec<u32> = txns
                .iter()
                .enumerate()
                .filter(|(_, t)| t.contains(&item))
                .map(|(i, _)| i as u32)
                .collect();
            vertical.push((item, VecTidset::from_tids(&tids, n)));
        }
        (vertical, n)
    }

    fn brute_force(txns: &[Vec<Item>], min_sup: u32) -> std::collections::BTreeSet<(Vec<Item>, u32)> {
        // enumerate all itemsets over items present
        let mut items: Vec<Item> = txns.iter().flatten().copied().collect();
        items.sort_unstable();
        items.dedup();
        let mut out = std::collections::BTreeSet::new();
        let m = items.len();
        for mask in 1u32..(1 << m) {
            let set: Vec<Item> = (0..m)
                .filter(|b| mask >> b & 1 == 1)
                .map(|b| items[b])
                .collect();
            let sup = txns
                .iter()
                .filter(|t| set.iter().all(|i| t.contains(i)))
                .count() as u32;
            if sup >= min_sup {
                out.insert((set, sup));
            }
        }
        out
    }

    #[test]
    fn classes_and_bottom_up_match_bruteforce() {
        let (vertical, _n) = vertical_db();
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 1, 3],
            vec![0, 1, 2, 3],
            vec![1, 2],
            vec![0, 3],
        ];
        for min_sup in 1..=4u32 {
            let mut twos = Vec::new();
            let classes = build_classes(&vertical, min_sup, None, |i| i, &mut twos);
            let mut all = Vec::new();
            // 1-itemsets
            for (item, ts) in &vertical {
                let sup = ts.support() as u32;
                if sup >= min_sup {
                    all.push(FrequentItemset::new(vec![*item], sup));
                }
            }
            all.extend(twos);
            for (_, c) in &classes {
                bottom_up(c, min_sup, &mut all);
            }
            let got: std::collections::BTreeSet<(Vec<Item>, u32)> =
                all.iter().map(|f| (f.items.clone(), f.support)).collect();
            assert_eq!(got, brute_force(&txns, min_sup), "min_sup={min_sup}");
            assert_eq!(got.len(), all.len(), "duplicates at min_sup={min_sup}");
        }
    }

    /// Run vertical-conversion → build_classes → bottom_up under any
    /// representation and return the canonical itemset set.
    fn mine_with<TS: TidOps>(
        txns: &[Vec<Item>],
        min_sup: u32,
    ) -> std::collections::BTreeSet<(Vec<Item>, u32)> {
        let n = txns.len();
        let mut vertical: Vec<(Item, TS)> = Vec::new();
        let mut items: Vec<Item> = txns.iter().flatten().copied().collect();
        items.sort_unstable();
        items.dedup();
        for item in items {
            let tids: Vec<u32> = txns
                .iter()
                .enumerate()
                .filter(|(_, t)| t.contains(&item))
                .map(|(i, _)| i as u32)
                .collect();
            if tids.len() as u32 >= min_sup {
                vertical.push((item, TS::from_tids(&tids, n)));
            }
        }
        vertical.sort_by_key(|(item, ts)| (ts.support(), *item));
        let mut all: Vec<FrequentItemset> = vertical
            .iter()
            .map(|(item, ts)| FrequentItemset::new(vec![*item], ts.support() as u32))
            .collect();
        let mut twos = Vec::new();
        let classes = build_classes(&vertical, min_sup, None, |i| i, &mut twos);
        all.extend(twos);
        for (_, c) in &classes {
            bottom_up(c, min_sup, &mut all);
        }
        all.iter().map(|f| (f.items.clone(), f.support)).collect()
    }

    #[test]
    fn all_representations_mine_identically() {
        use crate::fim::tidset::{BitmapTidset, DiffTidset, HybridTidset};
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 1, 3],
            vec![0, 1, 2, 3],
            vec![1, 2],
            vec![0, 3],
        ];
        // plus a universe-dense db (all diffsets empty) and a skewed one
        let dense: Vec<Vec<Item>> = vec![vec![1, 2, 3, 4]; 5];
        let mut skewed = txns.clone();
        skewed.extend(vec![vec![0, 1, 2, 3]; 8]);
        for db in [&txns, &dense, &skewed] {
            for min_sup in 1..=4u32 {
                let want = mine_with::<VecTidset>(db, min_sup);
                assert_eq!(mine_with::<BitmapTidset>(db, min_sup), want, "bitmap ms={min_sup}");
                assert_eq!(mine_with::<DiffTidset>(db, min_sup), want, "diffset ms={min_sup}");
                assert_eq!(mine_with::<HybridTidset>(db, min_sup), want, "hybrid ms={min_sup}");
            }
        }
    }

    #[test]
    fn trimatrix_pruning_preserves_result() {
        let (vertical, _) = vertical_db();
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 1, 3],
            vec![0, 1, 2, 3],
            vec![1, 2],
            vec![0, 3],
        ];
        let mut tm = TriMatrix::new(4);
        for t in &txns {
            tm.update_transaction(t);
        }
        for min_sup in 1..=4u32 {
            let mut twos_pruned = Vec::new();
            let mut twos_plain = Vec::new();
            let c1 = build_classes(&vertical, min_sup, Some(&tm), |i| i, &mut twos_pruned);
            let c2 = build_classes(&vertical, min_sup, None, |i| i, &mut twos_plain);
            twos_pruned.sort();
            twos_plain.sort();
            assert_eq!(twos_pruned, twos_plain);
            assert_eq!(c1.len(), c2.len());
        }
    }

    #[test]
    fn prefix2_decomposition_preserves_itemsets() {
        let (vertical, _) = vertical_db();
        for min_sup in 1..=3u32 {
            // 1-prefix path
            let mut twos_a = Vec::new();
            let classes1 = build_classes(&vertical, min_sup, None, |i| i, &mut twos_a);
            let mut all_1p = twos_a.clone();
            for (_, c) in &classes1 {
                bottom_up(c, min_sup, &mut all_1p);
            }
            // 2-prefix path: decompose, then bottom-up from level 3
            let mut twos_b = Vec::new();
            let classes1b = build_classes(&vertical, min_sup, None, |i| i, &mut twos_b);
            let mut threes = Vec::new();
            let classes2 = decompose_to_prefix2(classes1b, min_sup, &mut threes);
            let mut all_2p = twos_b;
            all_2p.extend(threes);
            for (_, c) in &classes2 {
                bottom_up(c, min_sup, &mut all_2p);
            }
            let canon = |v: &[FrequentItemset]| -> std::collections::BTreeSet<_> {
                v.iter().map(|f| (f.items.clone(), f.support)).collect()
            };
            assert_eq!(canon(&all_1p), canon(&all_2p), "min_sup={min_sup}");
        }
    }

    #[test]
    fn prefix2_produces_more_finer_classes() {
        let (vertical, _) = vertical_db();
        let mut twos = Vec::new();
        let classes1 = build_classes(&vertical, 1, None, |i| i, &mut twos);
        let n1 = classes1.len();
        let max_w1 = classes1.iter().map(|(_, c)| c.weight()).max().unwrap();
        let mut threes = Vec::new();
        let classes2 = decompose_to_prefix2(classes1, 1, &mut threes);
        assert!(classes2.len() >= n1, "{} < {n1}", classes2.len());
        let max_w2 = classes2.iter().map(|(_, c)| c.weight()).max().unwrap();
        assert!(max_w2 <= max_w1);
        // prefixes are 2 items long
        assert!(classes2.iter().all(|(_, c)| c.prefix.len() == 2));
    }

    #[test]
    fn class_weight_is_member_count() {
        let (vertical, _) = vertical_db();
        let mut twos = Vec::new();
        let classes = build_classes(&vertical, 1, None, |i| i, &mut twos);
        for (_, c) in &classes {
            assert_eq!(c.weight(), c.members.len());
        }
    }
}
