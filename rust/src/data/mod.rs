//! Benchmark datasets: generators for the paper's four datasets (Table 1)
//! and file I/O.
//!
//! The real BMS_WebView click-streams are not redistributable and the
//! original IBM Quest binary is long gone, so both are *re-implemented
//! generators* calibrated to Table 1's statistics (see DESIGN.md §3 for
//! the substitution argument).

pub mod bms_gen;
pub mod ibm_gen;
pub mod reader;
pub mod scale;
pub mod stats;

pub use bms_gen::BmsSpec;
pub use ibm_gen::QuestSpec;
pub use reader::{read_transactions, write_transactions};
pub use stats::DatasetStats;

use crate::fim::Transaction;

/// The four benchmark datasets of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    Bms1,
    Bms2,
    T10I4D100K,
    T40I10D100K,
}

impl Dataset {
    pub fn all() -> [Dataset; 4] {
        [Self::Bms1, Self::Bms2, Self::T10I4D100K, Self::T40I10D100K]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Bms1 => "BMS_WebView_1",
            Self::Bms2 => "BMS_WebView_2",
            Self::T10I4D100K => "T10I4D100K",
            Self::T40I10D100K => "T40I10D100K",
        }
    }

    /// Paper Table 1 properties (transactions, items, avg width).
    pub fn table1_row(&self) -> (usize, usize, f64) {
        match self {
            Self::Bms1 => (59_602, 497, 2.5),
            Self::Bms2 => (77_512, 3_340, 5.0),
            Self::T10I4D100K => (100_000, 870, 10.0),
            Self::T40I10D100K => (100_000, 1_000, 40.0),
        }
    }

    /// Whether the paper enables the triangular matrix for this dataset.
    pub fn tri_matrix_mode(&self) -> bool {
        matches!(self, Self::T10I4D100K | Self::T40I10D100K)
    }

    /// Generate the dataset (full size) with the given seed.
    pub fn generate(&self, seed: u64) -> Vec<Transaction> {
        self.generate_scaled(seed, 1.0)
    }

    /// Generate with a scale factor on the transaction count (used by the
    /// quick test paths; Fig. 6 uses `scale::replicate` instead).
    pub fn generate_scaled(&self, seed: u64, scale: f64) -> Vec<Transaction> {
        match self {
            Self::Bms1 => BmsSpec::bms1().scaled(scale).generate(seed),
            Self::Bms2 => BmsSpec::bms2().scaled(scale).generate(seed),
            Self::T10I4D100K => QuestSpec::t10i4d100k().scaled(scale).generate(seed),
            Self::T40I10D100K => QuestSpec::t40i10d100k().scaled(scale).generate(seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows_match_paper() {
        assert_eq!(Dataset::Bms1.table1_row(), (59_602, 497, 2.5));
        assert_eq!(Dataset::T40I10D100K.table1_row().0, 100_000);
    }

    #[test]
    fn tri_matrix_flags_match_paper() {
        assert!(!Dataset::Bms1.tri_matrix_mode());
        assert!(!Dataset::Bms2.tri_matrix_mode());
        assert!(Dataset::T10I4D100K.tri_matrix_mode());
        assert!(Dataset::T40I10D100K.tri_matrix_mode());
    }
}
