//! Quickstart: mine frequent itemsets from a small inline basket
//! database through the unified `MiningSession` API (engine `eclat-v4`)
//! and print the result.
//!
//! Run: `cargo run --release --example quickstart`

use rdd_eclat::fim::engine::MiningSession;
use rdd_eclat::sparklet::SparkletContext;

fn main() {
    // A tiny market-basket database: items are integer-coded products.
    let baskets: Vec<Vec<u32>> = vec![
        vec![1, 2, 5],    // bread, milk, beer
        vec![2, 4],       // milk, eggs
        vec![2, 3],       // milk, butter
        vec![1, 2, 4],    // bread, milk, eggs
        vec![1, 3],       // bread, butter
        vec![2, 3],       // milk, butter
        vec![1, 3],       // bread, butter
        vec![1, 2, 3, 5], // bread, milk, butter, beer
        vec![1, 2, 3],    // bread, milk, butter
    ];
    let names = ["", "bread", "milk", "butter", "eggs", "beer"];

    // An in-process Sparklet "cluster" with 4 executor cores.
    let sc = SparkletContext::local(4);

    // Mine with EclatV4 (hash-partitioned equivalence classes, p=4),
    // requiring an itemset to appear in at least 2 baskets. Swap the
    // engine name for any other registered engine ("apriori",
    // "fpgrowth", "eclat-v1"..) — the session API is identical.
    let report = MiningSession::new("eclat-v4")
        .min_sup(2)
        .p(4)
        .run_vec(&sc, &baskets)
        .expect("eclat-v4 is a builtin engine");

    println!("frequent itemsets (min_sup = 2):");
    let mut itemsets = report.result.itemsets.clone();
    itemsets.sort_by_key(|f| (f.items.len(), std::cmp::Reverse(f.support)));
    for f in &itemsets {
        let labels: Vec<&str> = f.items.iter().map(|&i| names[i as usize]).collect();
        println!("  {{{}}} x{}", labels.join(", "), f.support);
    }
    println!("total: {}", report.summary());
    assert!(report.result.len() >= 10, "demo db should yield >= 10 itemsets");
}
