//! XLA-backed FIM primitives: batched tidset intersection and the
//! co-occurrence (candidate-2-itemset) count matrix.
//!
//! The artifacts are compiled for fixed tile shapes; this module tiles /
//! pads arbitrary workloads onto them:
//!
//!  * `intersect_batch`: rows are processed in chunks of the artifact's
//!    R; the word axis in chunks of W (AND + popcount are elementwise /
//!    additive across word chunks, so chunk supports just sum).
//!  * `cooc_tri_matrix`: item blocks of I × I swept pairwise (bi ≤ bj),
//!    transaction axis in chunks of K, partial products accumulated into
//!    the triangular matrix — the same schedule the Pallas grid uses on
//!    TPU, lifted one level up.

use anyhow::{Context, Result};

use crate::fim::trimatrix::TriMatrix;
use crate::fim::types::Item;
use crate::util::Bitmap;

use super::executable::ArtifactRegistry;

/// Which artifacts this engine uses.
const INTERSECT: &str = "intersect_256x1024";
const INTERSECT_MINSUP: &str = "intersect_minsup_256x1024";
const COOC_PAIR: &str = "cooc_pair_256x2048";

/// XLA-accelerated support-count engine. NOT `Send`: PJRT handles live on
/// the driver thread; phases batch their work and call in from there.
pub struct XlaFim {
    registry: ArtifactRegistry,
    dir: String,
}

impl XlaFim {
    /// Load the engine from the artifacts directory (`make artifacts`).
    pub fn load(dir: &str) -> Result<Self> {
        let mut registry = ArtifactRegistry::new()?;
        registry.load(dir, INTERSECT)?;
        registry.load(dir, INTERSECT_MINSUP)?;
        registry.load(dir, COOC_PAIR)?;
        Ok(Self {
            registry,
            dir: dir.to_string(),
        })
    }

    /// Load from the default artifacts dir.
    pub fn load_default() -> Result<Self> {
        Self::load(&super::artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.registry.platform()
    }

    /// Batched tidset intersection: `out[i] = xs[i] & ys[i]` with
    /// supports. All bitmaps must share the same universe.
    pub fn intersect_batch(
        &mut self,
        xs: &[&Bitmap],
        ys: &[&Bitmap],
    ) -> Result<(Vec<Bitmap>, Vec<u32>)> {
        assert_eq!(xs.len(), ys.len());
        if xs.is_empty() {
            return Ok((Vec::new(), Vec::new()));
        }
        let nbits = xs[0].nbits();
        let n_words = xs[0].words().len();
        let art = self.registry.load(&self.dir, INTERSECT)?;
        let (tile_r, tile_w) = art.shape;

        let n = xs.len();
        let mut out_words: Vec<Vec<u32>> = vec![vec![0u32; n_words]; n];
        let mut supports = vec![0u32; n];

        for row0 in (0..n).step_by(tile_r) {
            let rows = tile_r.min(n - row0);
            for word0 in (0..n_words).step_by(tile_w) {
                let words = tile_w.min(n_words - word0);
                // pack [tile_r, tile_w] i32 tiles (zero-padded)
                let mut xt = vec![0i32; tile_r * tile_w];
                let mut yt = vec![0i32; tile_r * tile_w];
                for r in 0..rows {
                    let xw = &xs[row0 + r].words()[word0..word0 + words];
                    let yw = &ys[row0 + r].words()[word0..word0 + words];
                    for (c, (&a, &b)) in xw.iter().zip(yw).enumerate() {
                        xt[r * tile_w + c] = a as i32;
                        yt[r * tile_w + c] = b as i32;
                    }
                }
                let lx = xla::Literal::vec1(&xt).reshape(&[tile_r as i64, tile_w as i64])?;
                let ly = xla::Literal::vec1(&yt).reshape(&[tile_r as i64, tile_w as i64])?;
                let result = art.exe.execute::<xla::Literal>(&[lx, ly])?[0][0]
                    .to_literal_sync()?;
                let (inter, sup) = result.to_tuple2().context("intersect output tuple")?;
                let inter: Vec<i32> = inter.to_vec()?;
                let sup: Vec<i32> = sup.to_vec()?;
                for r in 0..rows {
                    supports[row0 + r] += sup[r] as u32;
                    let dst = &mut out_words[row0 + r][word0..word0 + words];
                    for (c, d) in dst.iter_mut().enumerate() {
                        *d = inter[r * tile_w + c] as u32;
                    }
                }
            }
        }

        let bitmaps = out_words
            .into_iter()
            .map(|words| {
                let mut b = Bitmap::new(nbits);
                for (i, w) in words.into_iter().enumerate() {
                    if w != 0 {
                        // write whole words through the tid interface-free path
                        for bit in 0..32 {
                            if w >> bit & 1 == 1 {
                                let idx = i * 32 + bit;
                                if idx < nbits {
                                    b.set(idx);
                                }
                            }
                        }
                    }
                }
                b
            })
            .collect();
        Ok((bitmaps, supports))
    }

    /// Batched intersection with the min_sup test fused into the graph
    /// (the `intersect_minsup` artifact): returns only supports and the
    /// 0/1 frequency mask — the readback-light path when callers discard
    /// infrequent intersections anyway. `min_sup` is a runtime scalar
    /// operand, so one compiled executable serves every threshold.
    ///
    /// Constraint of the fused artifact: the word axis must fit a single
    /// tile (mask composition across word chunks would need a host-side
    /// re-check); larger universes should use `intersect_batch`.
    pub fn intersect_minsup_batch(
        &mut self,
        xs: &[&Bitmap],
        ys: &[&Bitmap],
        min_sup: u32,
    ) -> Result<(Vec<u32>, Vec<bool>)> {
        assert_eq!(xs.len(), ys.len());
        if xs.is_empty() {
            return Ok((Vec::new(), Vec::new()));
        }
        let art = self.registry.load(&self.dir, INTERSECT_MINSUP)?;
        let (tile_r, tile_w) = art.shape;
        let n_words = xs[0].words().len();
        anyhow::ensure!(
            n_words <= tile_w,
            "universe {} words exceeds fused-artifact tile {tile_w}; use intersect_batch",
            n_words
        );
        let n = xs.len();
        let mut supports = vec![0u32; n];
        let mut mask = vec![false; n];
        for row0 in (0..n).step_by(tile_r) {
            let rows = tile_r.min(n - row0);
            let mut xt = vec![0i32; tile_r * tile_w];
            let mut yt = vec![0i32; tile_r * tile_w];
            for r in 0..rows {
                for (c, (&a, &b)) in xs[row0 + r]
                    .words()
                    .iter()
                    .zip(ys[row0 + r].words())
                    .enumerate()
                {
                    xt[r * tile_w + c] = a as i32;
                    yt[r * tile_w + c] = b as i32;
                }
            }
            let lx = xla::Literal::vec1(&xt).reshape(&[tile_r as i64, tile_w as i64])?;
            let ly = xla::Literal::vec1(&yt).reshape(&[tile_r as i64, tile_w as i64])?;
            let lm = xla::Literal::scalar(min_sup as i32);
            let result = art.exe.execute::<xla::Literal>(&[lx, ly, lm])?[0][0]
                .to_literal_sync()?;
            let (_, sup, m) = result.to_tuple3().context("minsup output tuple")?;
            let sup: Vec<i32> = sup.to_vec()?;
            let m: Vec<i32> = m.to_vec()?;
            for r in 0..rows {
                supports[row0 + r] = sup[r] as u32;
                mask[row0 + r] = m[r] != 0;
            }
        }
        Ok((supports, mask))
    }

    /// Candidate-2-itemset counts (the paper's Phase-2 triangular matrix)
    /// from per-item transaction bitmaps, via the cooc_pair matmul
    /// artifact. `items[i]` is the bitmap of item with dense rank `i`.
    pub fn cooc_tri_matrix(&mut self, items: &[&Bitmap]) -> Result<TriMatrix> {
        let n = items.len();
        let mut tri = TriMatrix::new(n);
        if n < 2 {
            return Ok(tri);
        }
        let n_txns = items[0].nbits();
        let art = self.registry.load(&self.dir, COOC_PAIR)?;
        let (tile_i, tile_k) = art.shape;

        // Dense 0/1 tile builder for item block starting at `base`,
        // transaction chunk starting at `t0`.
        let build_tile = |base: usize, t0: usize| -> Vec<f32> {
            let mut tile = vec![0f32; tile_i * tile_k];
            for r in 0..tile_i.min(n - base) {
                let bm = items[base + r];
                let hi = (t0 + tile_k).min(n_txns);
                // walk words overlapping [t0, hi)
                let w0 = t0 / 32;
                let w1 = hi.div_ceil(32);
                for wi in w0..w1.min(bm.words().len()) {
                    let w = bm.words()[wi];
                    if w == 0 {
                        continue;
                    }
                    let mut bits = w;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        let t = wi * 32 + b;
                        if t >= t0 && t < hi {
                            tile[r * tile_k + (t - t0)] = 1.0;
                        }
                    }
                }
            }
            tile
        };

        for bi in (0..n).step_by(tile_i) {
            for bj in (bi..n).step_by(tile_i) {
                // accumulate over transaction chunks
                let mut acc = vec![0f32; tile_i * tile_i];
                for t0 in (0..n_txns).step_by(tile_k) {
                    let a = build_tile(bi, t0);
                    let b = if bj == bi {
                        a.clone()
                    } else {
                        build_tile(bj, t0)
                    };
                    let la =
                        xla::Literal::vec1(&a).reshape(&[tile_i as i64, tile_k as i64])?;
                    let lb =
                        xla::Literal::vec1(&b).reshape(&[tile_i as i64, tile_k as i64])?;
                    let result = art.exe.execute::<xla::Literal>(&[la, lb])?[0][0]
                        .to_literal_sync()?;
                    let tile = result.to_tuple1().context("cooc output tuple")?;
                    let tile: Vec<f32> = tile.to_vec()?;
                    for (x, t) in acc.iter_mut().zip(tile) {
                        *x += t;
                    }
                }
                tri.add_cooc_tile(&acc, tile_i, bi, bj);
            }
        }
        Ok(tri)
    }

    /// Convenience: build per-item bitmaps from a vertical tid list and
    /// produce the triangular matrix. Items must be densely ranked
    /// (`rank -> tids`); rank order must match the caller's.
    pub fn cooc_from_vertical(
        &mut self,
        vertical: &[(Item, Vec<u32>)],
        n_txns: usize,
    ) -> Result<TriMatrix> {
        let bitmaps: Vec<Bitmap> = vertical
            .iter()
            .map(|(_, tids)| Bitmap::from_sorted_tids(tids, n_txns))
            .collect();
        let refs: Vec<&Bitmap> = bitmaps.iter().collect();
        self.cooc_tri_matrix(&refs)
    }
}
