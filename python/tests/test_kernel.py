"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

hypothesis sweeps shapes and contents; these are the core correctness
signal for everything the rust runtime executes.
"""

import numpy as np
import pytest

# hypothesis is not part of the offline image; skip this module cleanly
# (rather than erroring at collection) when it is missing.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.cooccurrence import (
    cooc_pair,
    cooccurrence,
    vmem_bytes as cooc_vmem,
)
from compile.kernels.intersect import intersect, vmem_bytes as inter_vmem
from compile.kernels.ref import cooccurrence_ref, intersect_ref, support_ref


# ---------------------------------------------------------------- cooccurrence
def dense_01(ni: int, nt: int, density: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.random((ni, nt)) < density).astype(np.float32)


@pytest.mark.parametrize(
    "ni,nt,bi,bj,bk",
    [
        (128, 512, 128, 128, 512),
        (256, 1024, 128, 128, 512),
        (256, 2048, 128, 128, 512),
        (128, 512, 64, 64, 128),
        (64, 128, 64, 64, 128),
    ],
)
def test_cooc_matches_ref_shapes(ni, nt, bi, bj, bk):
    a = dense_01(ni, nt, 0.3, seed=ni * 7 + nt)
    got = cooccurrence(a, block_i=bi, block_j=bj, block_k=bk)
    want = cooccurrence_ref(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_cooc_counts_are_exact_integers():
    a = dense_01(128, 512, 0.5, seed=1)
    got = np.asarray(cooccurrence(a))
    assert np.all(got == np.round(got))
    # diagonal = per-item supports
    np.testing.assert_array_equal(np.diag(got), a.sum(axis=1))


def test_cooc_symmetry():
    a = dense_01(128, 512, 0.2, seed=2)
    got = np.asarray(cooccurrence(a))
    np.testing.assert_array_equal(got, got.T)


def test_cooc_rejects_non_divisible():
    a = dense_01(100, 512, 0.3, seed=3)
    with pytest.raises(ValueError):
        cooccurrence(a, block_i=64, block_j=64, block_k=128)


def test_cooc_empty_and_full():
    z = np.zeros((64, 128), np.float32)
    np.testing.assert_array_equal(
        np.asarray(cooccurrence(z, block_i=64, block_j=64, block_k=128)), 0.0
    )
    o = np.ones((64, 128), np.float32)
    np.testing.assert_array_equal(
        np.asarray(cooccurrence(o, block_i=64, block_j=64, block_k=128)), 128.0
    )


@settings(max_examples=25, deadline=None)
@given(
    ni_blocks=st.integers(1, 3),
    nt_blocks=st.integers(1, 4),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_cooc_hypothesis(ni_blocks, nt_blocks, density, seed):
    bi = bj = 32
    bk = 64
    a = dense_01(ni_blocks * bi, nt_blocks * bk, density, seed)
    got = cooccurrence(a, block_i=bi, block_j=bj, block_k=bk)
    want = cooccurrence_ref(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


def test_cooc_pair_asymmetric_blocks():
    # a @ b.T for two different item blocks — the rust tiling path
    a = dense_01(64, 128, 0.3, seed=21)
    b = dense_01(64, 128, 0.4, seed=22)
    got = cooc_pair(a, b, block_i=32, block_j=32, block_k=64)
    want = a.astype(np.float32) @ b.astype(np.float32).T
    np.testing.assert_array_equal(np.asarray(got), want)


def test_cooc_pair_rejects_mismatch():
    a = dense_01(64, 128, 0.3, seed=23)
    b = dense_01(64, 256, 0.3, seed=24)
    with pytest.raises(ValueError):
        cooc_pair(a, b, block_i=32, block_j=32, block_k=64)


@settings(max_examples=15, deadline=None)
@given(
    blocks=st.integers(1, 3),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_cooc_pair_hypothesis(blocks, density, seed):
    a = dense_01(blocks * 32, 2 * 64, density, seed)
    b = dense_01(blocks * 32, 2 * 64, 1.0 - density, seed ^ 1)
    got = cooc_pair(a, b, block_i=32, block_j=32, block_k=64)
    want = a.astype(np.float32) @ b.astype(np.float32).T
    np.testing.assert_array_equal(np.asarray(got), want)


# ------------------------------------------------------------------- intersect
def bitmaps(r: int, w: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(-(2**31), 2**31, size=(r, w), dtype=np.int64).astype(
        np.int32
    )


@pytest.mark.parametrize(
    "r,w,br", [(64, 256, 64), (256, 1024, 256), (512, 128, 256), (256, 64, 64)]
)
def test_intersect_matches_ref_shapes(r, w, br):
    x, y = bitmaps(r, w, seed=r + w), bitmaps(r, w, seed=r * w)
    gi, gs = intersect(x, y, block_r=br)
    wi, ws = intersect_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


def test_intersect_identities():
    x = bitmaps(64, 64, seed=9)
    zero = np.zeros_like(x)
    gi, gs = intersect(x, zero, block_r=64)
    np.testing.assert_array_equal(np.asarray(gi), 0)
    np.testing.assert_array_equal(np.asarray(gs), 0)
    gi, gs = intersect(x, x, block_r=64)
    np.testing.assert_array_equal(np.asarray(gi), x)
    np.testing.assert_array_equal(
        np.asarray(gs), np.asarray(support_ref(jnp.asarray(x)))
    )


def test_intersect_support_counts_bits():
    # row of all-ones words: support = 32 * words
    x = np.full((64, 16), -1, np.int32)
    _, gs = intersect(x, x, block_r=64)
    np.testing.assert_array_equal(np.asarray(gs), 32 * 16)


def test_intersect_rejects_non_divisible():
    x = bitmaps(100, 64, seed=1)
    with pytest.raises(ValueError):
        intersect(x, x, block_r=64)


@settings(max_examples=25, deadline=None)
@given(
    r_blocks=st.integers(1, 4),
    w=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_intersect_hypothesis(r_blocks, w, seed):
    br = 32
    r = r_blocks * br
    x, y = bitmaps(r, w, seed=seed), bitmaps(r, w, seed=seed ^ 0x5EED)
    gi, gs = intersect(x, y, block_r=br)
    wi, ws = intersect_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_intersect_support_vs_python_sets(seed):
    """End-to-end semantic check: bitmap path == python set intersection."""
    rng = np.random.default_rng(seed)
    n_tids = 32 * 8
    a = set(rng.choice(n_tids, size=40, replace=False).tolist())
    b = set(rng.choice(n_tids, size=40, replace=False).tolist())

    def pack(s):
        words = np.zeros(8, np.uint32)
        for t in s:
            words[t // 32] |= np.uint32(1) << np.uint32(t % 32)
        return words.view(np.int32)

    x = np.tile(pack(a), (32, 1))
    y = np.tile(pack(b), (32, 1))
    _, gs = intersect(x, y, block_r=32)
    assert int(np.asarray(gs)[0]) == len(a & b)


# ------------------------------------------------------------------ VMEM model
def test_vmem_estimates_within_budget():
    assert cooc_vmem(128, 128, 512) < 16 * 2**20
    assert inter_vmem(256, 1024) < 16 * 2**20
