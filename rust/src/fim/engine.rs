//! The unified mining API: one builder-driven session for every
//! algorithm, tidset representation, and execution mode.
//!
//! The paper evaluates a *family* of algorithms (five RDD-Eclat variants
//! against Spark Apriori / FP-Growth), and the data-structure-axis study
//! of Singh et al. (arXiv:1908.01338) swaps representations under a
//! fixed algorithm. Both demand that **engine**, **tidset
//! representation**, and **partition strategy** be orthogonal, swappable
//! axes behind one API:
//!
//! * [`FimEngine`] — the trait every mining engine implements (the five
//!   Eclat variants, the fused V6, Apriori/YAFIM, FP-Growth/PFP, and the
//!   sequential oracle).
//! * [`EngineRegistry`] — a static name → engine registry. New engines
//!   (GPU tidset intersection via `runtime/`, distributed executors)
//!   register once and appear everywhere: CLI `--engine` values, the
//!   `bench` sweep, coordinator experiments, and the cross-engine
//!   agreement test suite.
//! * [`MiningConfig`] — the orthogonal axes as plain data: `min_sup`,
//!   [`TidsetRepr`], [`PartitionStrategy`], `p`, `tri_matrix`,
//!   `prefix_len`, `n_groups`.
//! * [`MiningSession`] — the builder that composes an engine with a
//!   config, optional post-stages (closed/maximal/top-k from
//!   [`super::postprocess`]) and rule generation ([`super::rules`]),
//!   and returns a [`MiningReport`]: the itemsets plus per-stage
//!   [`StageMetrics`] pulled from the engine's `MetricsRegistry`, so
//!   every run is benchmarkable for free.

use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::sparklet::events::{self, SparkletEvent};
use crate::sparklet::metrics::StageMetrics;
use crate::sparklet::{Rdd, SparkletContext};
use crate::util::text::closest;

use super::apriori::mine_apriori_rdd;
use super::eclat::{mine_eclat, EclatVariant};
use super::fpgrowth::mine_fpgrowth_rdd;
use super::postprocess;
use super::rules::{generate_rules, Rule};
use super::sequential::eclat_sequential_with;
use super::tidset::{kernel, BitmapTidset, DiffTidset, HybridTidset, KernelStats, VecTidset};
use super::types::{abs_min_sup, MiningResult, Transaction};

// ------------------------------------------------------------------ axes

/// Tidset representation axis (the data-structure perspective).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TidsetRepr {
    /// Sorted `Vec<u32>` tid lists — the paper's (and SPMF's) layout.
    Vec,
    /// Packed `u32` bitmaps (AND + popcount) — the layout the XLA
    /// artifact consumes.
    Bitmap,
    /// Zaki's dEclat diffsets: below the root level each member stores
    /// `d(PX) = t(P) \ t(PX)`, turning the dominant intersection into a
    /// cheap subtraction. The win case is dense datasets.
    Diffset,
    /// Per-class adaptive: every equivalence class re-measures its
    /// density and switches Vec ↔ Bitmap ↔ Diffset at class
    /// boundaries, so skewed datasets get the right kernel everywhere.
    Hybrid,
    /// Pick per run by measured vertical-database density: bitmaps win
    /// once the average tidset is dense enough that word-parallel AND
    /// beats the element-wise merge.
    Auto,
}

impl TidsetRepr {
    /// Density at/above which `Auto` selects [`TidsetRepr::Bitmap`] —
    /// the same break-even [`HybridTidset`] applies per class
    /// (see `tidset::DENSE_THRESHOLD` for the derivation).
    pub const AUTO_DENSITY_THRESHOLD: f64 = crate::fim::tidset::DENSE_THRESHOLD;

    pub fn name(&self) -> &'static str {
        match self {
            Self::Vec => "vec",
            Self::Bitmap => "bitmap",
            Self::Diffset => "diffset",
            Self::Hybrid => "hybrid",
            Self::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_lowercase().as_str() {
            "vec" | "veclist" | "tidlist" | "list" => Ok(Self::Vec),
            "bitmap" | "bits" | "bitset" => Ok(Self::Bitmap),
            "diffset" | "diff" | "dset" | "declat" => Ok(Self::Diffset),
            "hybrid" | "adaptive" => Ok(Self::Hybrid),
            "auto" => Ok(Self::Auto),
            other => Err(format!(
                "unknown tidset representation {other:?} (vec|bitmap|diffset|hybrid|auto)"
            )),
        }
    }

    /// All concrete (non-`Auto`) representations, in bench-sweep order.
    pub fn all_concrete() -> [TidsetRepr; 4] {
        [Self::Vec, Self::Bitmap, Self::Diffset, Self::Hybrid]
    }

    /// Resolve `Auto` against a measured vertical database:
    /// `total_tids` item occurrences spread over `n_items` frequent
    /// items and `n_txns` transactions. Fixed representations
    /// (including `Diffset` and `Hybrid`, which adapt per class on
    /// their own) pass through unchanged.
    pub fn resolve(self, total_tids: usize, n_items: usize, n_txns: usize) -> TidsetRepr {
        match self {
            Self::Auto => {
                if n_items == 0 || n_txns == 0 {
                    return Self::Vec;
                }
                let density = total_tids as f64 / (n_items as f64 * n_txns as f64);
                if density >= Self::AUTO_DENSITY_THRESHOLD {
                    Self::Bitmap
                } else {
                    Self::Vec
                }
            }
            fixed => fixed,
        }
    }
}

/// Equivalence-class placement axis (`fim::partitioners`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// The engine's paper-default placement: V4 → hash, V5 →
    /// reverse-hash, V6 → LPT-weighted, everything else →
    /// `defaultPartitioner(n - 1)`.
    EngineDefault,
    /// `defaultPartitioner(n - 1)`: one partition per class rank.
    Ranked,
    /// `hashPartitioner(p)`.
    Hash,
    /// `reverseHashPartitioner(p)` (boustrophedon rank striping).
    ReverseHash,
    /// Greedy LPT over actual class weights into `p` partitions.
    Weighted,
}

impl PartitionStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            Self::EngineDefault => "engine",
            Self::Ranked => "ranked",
            Self::Hash => "hash",
            Self::ReverseHash => "reverse-hash",
            Self::Weighted => "weighted",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_lowercase().as_str() {
            "engine" | "engine-default" => Ok(Self::EngineDefault),
            "ranked" | "default" => Ok(Self::Ranked),
            "hash" => Ok(Self::Hash),
            "reverse-hash" | "reversehash" | "reverse" => Ok(Self::ReverseHash),
            "weighted" | "lpt" => Ok(Self::Weighted),
            other => Err(format!(
                "unknown partition strategy {other:?} \
                 (engine|ranked|hash|reverse-hash|weighted)"
            )),
        }
    }
}

/// Mining parameters shared by every engine — the orthogonal axes as
/// plain data. Engines read the knobs that apply to them (Apriori
/// ignores `tidset`; FP-Growth only reads `min_sup` and `n_groups`).
#[derive(Debug, Clone, PartialEq)]
pub struct MiningConfig {
    /// Absolute minimum support count (see [`abs_min_sup`]).
    pub min_sup: u32,
    /// Tidset representation for the intersection kernel.
    pub tidset: TidsetRepr,
    /// Equivalence-class placement.
    pub partitioning: PartitionStrategy,
    /// `p`: class partitions for hash/reverse-hash/weighted (paper: 10).
    pub p: usize,
    /// Triangular-matrix 2-itemset pruning (the paper disables it on
    /// BMS1/BMS2, whose item-id space is too large).
    pub tri_matrix: bool,
    /// Equivalence-class prefix length: 1 (the paper) or 2 (§6 future
    /// work). V6Fused always uses 2.
    pub prefix_len: usize,
    /// PFP group shards for FP-Growth.
    pub n_groups: usize,
}

impl Default for MiningConfig {
    fn default() -> Self {
        Self {
            min_sup: 1,
            tidset: TidsetRepr::Vec,
            partitioning: PartitionStrategy::EngineDefault,
            p: 10,
            tri_matrix: true,
            prefix_len: 1,
            n_groups: 8,
        }
    }
}

impl MiningConfig {
    pub fn new(min_sup: u32) -> Self {
        Self {
            min_sup,
            ..Self::default()
        }
    }

    pub fn with_min_sup(mut self, min_sup: u32) -> Self {
        self.min_sup = min_sup;
        self
    }

    pub fn with_tidset(mut self, repr: TidsetRepr) -> Self {
        self.tidset = repr;
        self
    }

    pub fn with_partitioning(mut self, strategy: PartitionStrategy) -> Self {
        self.partitioning = strategy;
        self
    }

    pub fn with_p(mut self, p: usize) -> Self {
        self.p = p.max(1);
        self
    }

    pub fn with_tri_matrix(mut self, on: bool) -> Self {
        self.tri_matrix = on;
        self
    }

    pub fn with_prefix_len(mut self, k: usize) -> Self {
        assert!((1..=2).contains(&k), "prefix_len must be 1 or 2");
        self.prefix_len = k;
        self
    }

    pub fn with_n_groups(mut self, g: usize) -> Self {
        self.n_groups = g.max(1);
        self
    }
}

// ----------------------------------------------------------------- trait

/// A frequent-itemset mining engine. Implementations must be pure
/// functions of `(txns, cfg)` up to timing: every engine registered in
/// the [`EngineRegistry`] is held to the sequential oracle by the
/// cross-engine agreement suite (`tests/engine_registry.rs`).
pub trait FimEngine: Send + Sync {
    /// Canonical registry name (kebab-case, e.g. `"eclat-v4"`).
    fn name(&self) -> &'static str;

    /// Display label for tables and bench series (e.g. `"EclatV4"`).
    fn label(&self) -> &'static str {
        self.name()
    }

    /// Alternate lookup spellings (matched case-insensitively).
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// One-line description for `--help` and docs.
    fn describe(&self) -> &'static str {
        ""
    }

    /// Whether the engine's hot path reads [`MiningConfig::tidset`] —
    /// drives the bench's tidset-representation sweep (engines that
    /// ignore the axis get one vec row instead of identical rows per
    /// representation). Defaults to `true` so a newly registered
    /// vertical-layout engine joins the kernel perf trajectory without
    /// extra wiring; representation-blind engines override to `false`.
    fn tidset_sensitive(&self) -> bool {
        true
    }

    /// Mine the transactions RDD under `cfg`. Transactions must be
    /// normalized (sorted + deduplicated items).
    ///
    /// Recoverable execution failures (retries exhausted against an
    /// injected fault schedule, a job deadline expiring) surface as
    /// [`FimError::Execution`]; infallible engines simply wrap their
    /// result in `Ok`. Panics that escape an engine are additionally
    /// caught at the [`MiningSession`] boundary and re-typed, so
    /// session callers never observe an unwinding mine.
    fn mine(
        &self,
        sc: &SparkletContext,
        txns: &Rdd<Transaction>,
        cfg: &MiningConfig,
    ) -> Result<MiningResult, FimError>;
}

// -------------------------------------------------------- builtin engines

/// One of the paper's RDD-Eclat variants (plus the §6 fusion) as an
/// engine.
pub struct EclatEngine {
    variant: EclatVariant,
}

impl EclatEngine {
    pub fn new(variant: EclatVariant) -> Self {
        Self { variant }
    }

    pub fn variant(&self) -> EclatVariant {
        self.variant
    }
}

impl FimEngine for EclatEngine {
    fn name(&self) -> &'static str {
        match self.variant {
            EclatVariant::V1 => "eclat-v1",
            EclatVariant::V2 => "eclat-v2",
            EclatVariant::V3 => "eclat-v3",
            EclatVariant::V4 => "eclat-v4",
            EclatVariant::V5 => "eclat-v5",
            EclatVariant::V6Fused => "eclat-v6",
        }
    }

    fn label(&self) -> &'static str {
        self.variant.name()
    }

    fn aliases(&self) -> &'static [&'static str] {
        match self.variant {
            EclatVariant::V1 => &["v1"],
            EclatVariant::V2 => &["v2"],
            EclatVariant::V3 => &["v3"],
            EclatVariant::V4 => &["v4"],
            EclatVariant::V5 => &["v5"],
            EclatVariant::V6Fused => &["v6", "v6-fused", "fused"],
        }
    }

    fn describe(&self) -> &'static str {
        match self.variant {
            EclatVariant::V1 => "RDD-Eclat V1: groupByKey vertical DB, per-class Bottom-Up",
            EclatVariant::V2 => "RDD-Eclat V2: V1 + broadcast-trie transaction filtering",
            EclatVariant::V3 => "RDD-Eclat V3: V2 with hashmap-accumulator vertical DB",
            EclatVariant::V4 => "RDD-Eclat V4: V3 + hashPartitioner(p) class placement",
            EclatVariant::V5 => "RDD-Eclat V5: V3 + reverseHashPartitioner(p) placement",
            EclatVariant::V6Fused => {
                "fused §6 future work: 2-prefix classes + LPT-weighted placement"
            }
        }
    }

    fn mine(
        &self,
        sc: &SparkletContext,
        txns: &Rdd<Transaction>,
        cfg: &MiningConfig,
    ) -> Result<MiningResult, FimError> {
        mine_eclat(sc, txns, self.variant, cfg)
    }
}

/// RDD-Apriori (YAFIM), the paper's main baseline.
pub struct AprioriEngine;

impl FimEngine for AprioriEngine {
    fn name(&self) -> &'static str {
        "apriori"
    }

    fn label(&self) -> &'static str {
        "RDD-Apriori"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["yafim", "rdd-apriori"]
    }

    fn describe(&self) -> &'static str {
        "RDD-Apriori (YAFIM): per-level candidate broadcast + database re-scan"
    }

    fn tidset_sensitive(&self) -> bool {
        false // horizontal layout: never touches tidsets
    }

    fn mine(
        &self,
        sc: &SparkletContext,
        txns: &Rdd<Transaction>,
        cfg: &MiningConfig,
    ) -> Result<MiningResult, FimError> {
        Ok(mine_apriori_rdd(sc, txns, cfg.min_sup))
    }
}

/// Parallel FP-Growth (PFP/DFPS shape), the third baseline family.
pub struct FpGrowthEngine;

impl FimEngine for FpGrowthEngine {
    fn name(&self) -> &'static str {
        "fpgrowth"
    }

    fn label(&self) -> &'static str {
        "RDD-FPGrowth"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fp-growth", "pfp", "rdd-fpgrowth"]
    }

    fn describe(&self) -> &'static str {
        "parallel FP-Growth (PFP): item-group shards, per-group FP-trees"
    }

    fn tidset_sensitive(&self) -> bool {
        false // FP-tree layout: never touches tidsets
    }

    fn mine(
        &self,
        sc: &SparkletContext,
        txns: &Rdd<Transaction>,
        cfg: &MiningConfig,
    ) -> Result<MiningResult, FimError> {
        Ok(mine_fpgrowth_rdd(sc, txns, cfg.min_sup, cfg.n_groups))
    }
}

/// The sequential correctness oracle as an engine: single-threaded Eclat
/// on the driver, generic over the tidset representation (`Auto`
/// resolves to tid lists here — there is no distributed phase to size
/// against).
pub struct SequentialEngine;

impl FimEngine for SequentialEngine {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn label(&self) -> &'static str {
        "Sequential"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["seq", "oracle"]
    }

    fn describe(&self) -> &'static str {
        "single-threaded Eclat oracle (driver-side, no RDD stages)"
    }

    fn mine(
        &self,
        _sc: &SparkletContext,
        txns: &Rdd<Transaction>,
        cfg: &MiningConfig,
    ) -> Result<MiningResult, FimError> {
        let db = txns.collect();
        Ok(match cfg.tidset {
            TidsetRepr::Bitmap => eclat_sequential_with::<BitmapTidset>(&db, cfg.min_sup),
            TidsetRepr::Diffset => eclat_sequential_with::<DiffTidset>(&db, cfg.min_sup),
            TidsetRepr::Hybrid => eclat_sequential_with::<HybridTidset>(&db, cfg.min_sup),
            TidsetRepr::Vec | TidsetRepr::Auto => {
                eclat_sequential_with::<VecTidset>(&db, cfg.min_sup)
            }
        })
    }
}

// -------------------------------------------------------------- registry

/// The static engine registry. Builtins register once here; additional
/// backends call [`EngineRegistry::register`] and immediately appear in
/// every consumer (CLI, bench sweep, experiments, agreement tests).
pub struct EngineRegistry;

type EngineList = Vec<Arc<dyn FimEngine>>;

static REGISTRY: OnceLock<Mutex<EngineList>> = OnceLock::new();

fn builtin_engines() -> EngineList {
    let mut engines: EngineList = Vec::new();
    for variant in EclatVariant::all_with_fused() {
        engines.push(Arc::new(EclatEngine::new(variant)));
    }
    engines.push(Arc::new(AprioriEngine));
    engines.push(Arc::new(FpGrowthEngine));
    engines.push(Arc::new(SequentialEngine));
    engines
}

fn registry() -> &'static Mutex<EngineList> {
    REGISTRY.get_or_init(|| Mutex::new(builtin_engines()))
}

/// Case/punctuation-insensitive name key ("EclatV4" == "eclat-v4").
fn normalize(name: &str) -> String {
    name.chars()
        .filter(|c| *c != '-' && *c != '_')
        .flat_map(|c| c.to_lowercase())
        .collect()
}

impl EngineRegistry {
    /// Canonical names of all registered engines, in registration order.
    pub fn names() -> Vec<&'static str> {
        registry().lock().unwrap().iter().map(|e| e.name()).collect()
    }

    /// All registered engines.
    pub fn engines() -> Vec<Arc<dyn FimEngine>> {
        registry().lock().unwrap().clone()
    }

    /// Look an engine up by canonical name or alias, case-insensitively
    /// and ignoring `-`/`_` ("EclatV4", "eclat-v4" and "v4" all match).
    /// Canonical names win over aliases, so an engine registered under a
    /// name that collides with another engine's alias stays reachable.
    pub fn get(name: &str) -> Option<Arc<dyn FimEngine>> {
        let key = normalize(name);
        let reg = registry().lock().unwrap();
        reg.iter()
            .find(|e| normalize(e.name()) == key)
            .or_else(|| {
                reg.iter()
                    .find(|e| e.aliases().iter().any(|a| normalize(a) == key))
            })
            .cloned()
    }

    /// Register an engine (replacing any engine with the same canonical
    /// name). This is the one-line hook future backends use.
    pub fn register(engine: Arc<dyn FimEngine>) {
        let mut reg = registry().lock().unwrap();
        let key = normalize(engine.name());
        reg.retain(|e| normalize(e.name()) != key);
        reg.push(engine);
    }

    /// Closest registered name/alias to a misspelled input, if any is
    /// plausibly near.
    pub fn suggest(name: &str) -> Option<&'static str> {
        let reg = registry().lock().unwrap();
        let candidates: Vec<&'static str> = reg
            .iter()
            .flat_map(|e| std::iter::once(e.name()).chain(e.aliases().iter().copied()))
            .collect();
        closest(&name.to_lowercase(), candidates, 3)
    }

    /// `name — description` lines for `--help`.
    pub fn describe_all() -> String {
        let reg = registry().lock().unwrap();
        let mut out = String::new();
        for e in reg.iter() {
            out.push_str(&format!("  {:<12} {}\n", e.name(), e.describe()));
        }
        out
    }
}

// ----------------------------------------------------------------- error

/// Typed errors of the unified API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FimError {
    /// The session named an engine the registry does not know.
    UnknownEngine {
        name: String,
        suggestion: Option<String>,
    },
    /// The mine itself failed after the execution layer gave up:
    /// retries exhausted against a fault schedule, a job deadline
    /// expired, or a stage panicked unrecoverably. The reason carries
    /// the scheduler's typed display (`RetryError` et al.) verbatim.
    Execution { reason: String },
}

impl std::fmt::Display for FimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::UnknownEngine { name, suggestion } => {
                write!(f, "unknown engine {name:?}")?;
                if let Some(s) = suggestion {
                    write!(f, " — did you mean {s:?}?")?;
                }
                write!(f, " (registered: {})", EngineRegistry::names().join(", "))
            }
            Self::Execution { reason } => write!(f, "mining failed: {reason}"),
        }
    }
}

impl std::error::Error for FimError {}

// ------------------------------------------------------------ post stages

/// Result post-stages, chained in order on the mined itemsets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PostStage {
    /// Keep only closed itemsets.
    Closed,
    /// Keep only maximal itemsets.
    Maximal,
    /// Keep the k highest-support itemsets.
    TopK(usize),
}

impl PostStage {
    /// Apply this stage to a result. Public so callers that reuse cached
    /// *full* results (serve mode) can run post-stages on the response
    /// path without re-mining.
    pub fn apply(self, result: &MiningResult) -> MiningResult {
        match self {
            Self::Closed => postprocess::closed_itemsets(result),
            Self::Maximal => postprocess::maximal_itemsets(result),
            Self::TopK(k) => postprocess::top_k(result, k),
        }
    }

    /// Parse a CLI/wire spec: `closed`, `maximal`, or `top=K` (also
    /// `top:K`). Shared by the `mine` flags and the serve protocol.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let spec = spec.trim();
        match spec {
            "closed" => Ok(Self::Closed),
            "maximal" => Ok(Self::Maximal),
            _ => {
                let k = spec
                    .strip_prefix("top=")
                    .or_else(|| spec.strip_prefix("top:"))
                    .ok_or_else(|| {
                        format!("unknown post-stage {spec:?} (use closed|maximal|top=K)")
                    })?;
                let k: usize = k
                    .parse()
                    .map_err(|_| format!("top-k count {k:?} is not a number"))?;
                if k == 0 {
                    return Err("top-k count must be >= 1".into());
                }
                Ok(Self::TopK(k))
            }
        }
    }
}

// ---------------------------------------------------------------- report

/// What one session run produced: the itemsets, optional rules, and the
/// per-stage engine metrics recorded while the mine ran.
#[derive(Debug, Clone)]
pub struct MiningReport {
    /// Canonical engine name ("eclat-v4").
    pub engine: &'static str,
    /// Display label ("EclatV4").
    pub label: &'static str,
    /// Absolute min_sup the run used (after fraction resolution).
    pub min_sup: u32,
    /// Transaction count, when the session had to measure it (fractional
    /// min_sup or rule generation).
    pub n_transactions: Option<usize>,
    /// Requested tidset representation.
    pub tidset: TidsetRepr,
    /// The mined itemsets (after post-stages).
    pub result: MiningResult,
    /// Association rules, when the session asked for them.
    pub rules: Option<Vec<Rule>>,
    /// Wall time of the mine (excluding post-stages), milliseconds.
    pub wall_ms: f64,
    /// Engine stages recorded during the mine, in execution order.
    pub stages: Vec<StageMetrics>,
    /// Kernel work counters (intersections, early aborts, representation
    /// switches, bytes allocated) snapshotted around the mine. The
    /// counters are process-global, so concurrent sessions in the same
    /// process bleed into each other's deltas — exact for the CLI and
    /// bench, indicative under parallel test runs.
    pub kernel: KernelStats,
}

impl MiningReport {
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn shuffle_records(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle_records).sum()
    }

    /// Exact serialized shuffle bytes across the run's stages.
    pub fn shuffle_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle_bytes).sum()
    }

    /// Shuffle blocks spilled to disk under the memory budget.
    pub fn spilled_blocks(&self) -> u64 {
        self.stages.iter().map(|s| s.spilled_blocks).sum()
    }

    /// (p50, p95, p99) task durations in ms across the run's stages
    /// (all zeros when no tasks were timed).
    pub fn task_percentiles(&self) -> (f64, f64, f64) {
        (
            events::aggregate_task_quantile(&self.stages, 0.50),
            events::aggregate_task_quantile(&self.stages, 0.95),
            events::aggregate_task_quantile(&self.stages, 0.99),
        )
    }

    /// Skew factor: max/median task duration across the run's stages
    /// (1.0 = balanced, 0 when unmeasured).
    pub fn skew_factor(&self) -> f64 {
        events::aggregate_skew(&self.stages)
    }

    /// Intersection kernel throughput for this run (invocations per
    /// second of in-kernel wall time; 0.0 for engines that never
    /// intersect tidsets).
    pub fn intersections_per_sec(&self) -> f64 {
        self.kernel.intersections_per_sec()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let (_, p95, _) = self.task_percentiles();
        format!(
            "{}: {} itemsets (max length {}) in {:.1} ms — {} stages, \
             shuffle {} records / {} bytes, kernel {} ∩ @ {:.0} ∩/s \
             ({} early-aborts, {} repr switches), \
             p95 task {:.1} ms / skew {:.1}x",
            self.label,
            self.result.len(),
            self.result.max_length(),
            self.wall_ms,
            self.n_stages(),
            self.shuffle_records(),
            self.shuffle_bytes(),
            self.kernel.intersections,
            self.intersections_per_sec(),
            self.kernel.early_aborts,
            self.kernel.repr_switches,
            p95,
            self.skew_factor(),
        )
    }
}

// --------------------------------------------------------------- session

/// Builder for one mining run: engine (by registry name) × config axes ×
/// post-stage pipeline. Cheap to clone; `run` can be called repeatedly.
#[derive(Debug, Clone)]
pub struct MiningSession {
    engine: String,
    cfg: MiningConfig,
    min_sup_frac: Option<f64>,
    post: Vec<PostStage>,
    min_conf: Option<f64>,
}

impl MiningSession {
    pub fn new(engine: impl Into<String>) -> Self {
        Self {
            engine: engine.into(),
            cfg: MiningConfig::default(),
            min_sup_frac: None,
            post: Vec::new(),
            min_conf: None,
        }
    }

    /// Absolute minimum support count.
    pub fn min_sup(mut self, min_sup: u32) -> Self {
        self.cfg.min_sup = min_sup;
        self.min_sup_frac = None;
        self
    }

    /// Relative minimum support (fraction of |D|, resolved at run time).
    pub fn min_sup_frac(mut self, frac: f64) -> Self {
        self.min_sup_frac = Some(frac);
        self
    }

    pub fn tidset(mut self, repr: TidsetRepr) -> Self {
        self.cfg.tidset = repr;
        self
    }

    pub fn partitioning(mut self, strategy: PartitionStrategy) -> Self {
        self.cfg.partitioning = strategy;
        self
    }

    pub fn p(mut self, p: usize) -> Self {
        self.cfg.p = p.max(1);
        self
    }

    pub fn tri_matrix(mut self, on: bool) -> Self {
        self.cfg.tri_matrix = on;
        self
    }

    pub fn prefix_len(mut self, k: usize) -> Self {
        self.cfg = self.cfg.with_prefix_len(k);
        self
    }

    pub fn n_groups(mut self, g: usize) -> Self {
        self.cfg.n_groups = g.max(1);
        self
    }

    /// Replace the whole config at once (axes set earlier are lost).
    pub fn config(mut self, cfg: MiningConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Append a post-stage (chained in call order).
    pub fn post(mut self, stage: PostStage) -> Self {
        self.post.push(stage);
        self
    }

    /// Also generate association rules at this confidence threshold.
    /// Rules always derive from the *full* mining result, even when
    /// post-stages condense `report.result` (rule generation needs the
    /// anti-monotone subset supports a condensed result drops).
    pub fn rules(mut self, min_conf: f64) -> Self {
        self.min_conf = Some(min_conf);
        self
    }

    pub fn engine_name(&self) -> &str {
        &self.engine
    }

    pub fn mining_config(&self) -> &MiningConfig {
        &self.cfg
    }

    /// Run on a transactions RDD (items must be sorted + deduplicated;
    /// `transactions_from_lines` and [`MiningSession::run_vec`] both
    /// normalize).
    pub fn run(
        &self,
        sc: &SparkletContext,
        txns: &Rdd<Transaction>,
    ) -> Result<MiningReport, FimError> {
        self.run_with_known_count(sc, txns, None)
    }

    /// `run`, with |D| supplied by a caller that already knows it (so
    /// fractional min_sup / rule lift don't cost an extra count job).
    fn run_with_known_count(
        &self,
        sc: &SparkletContext,
        txns: &Rdd<Transaction>,
        known_n: Option<usize>,
    ) -> Result<MiningReport, FimError> {
        let engine = EngineRegistry::get(&self.engine).ok_or_else(|| FimError::UnknownEngine {
            name: self.engine.clone(),
            suggestion: EngineRegistry::suggest(&self.engine).map(str::to_string),
        })?;
        let mut cfg = self.cfg.clone();
        // |D| is only measured when something needs it (fractional
        // min_sup, rule lift) — counting costs a job.
        let n_transactions = if self.min_sup_frac.is_some() || self.min_conf.is_some() {
            Some(known_n.unwrap_or_else(|| txns.count()))
        } else {
            None
        };
        if let Some(frac) = self.min_sup_frac {
            cfg.min_sup = abs_min_sup(frac, n_transactions.unwrap_or(0));
        }
        let stage_mark = sc.metrics().stages().len();
        let kernel_mark = kernel::snapshot();
        let t0 = Instant::now();
        // The unwind boundary of the unified API: engines that surface
        // failures through panics (the closure-typed `run_stage` path
        // can't carry a Result through `collect`) are re-typed here, so
        // a session caller always gets `Err(FimError)`, never an
        // unwinding mine. Engines that already return typed errors
        // (the described-task path) pass straight through the `?`.
        let mined = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.mine(sc, txns, &cfg)
        }))
        .unwrap_or_else(|payload| {
            let reason = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "engine panicked".to_string());
            Err(FimError::Execution { reason })
        })?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let kernel_stats = kernel::snapshot().since(&kernel_mark);
        // The per-session kernel delta goes onto the event bus so an
        // event log attributes kernel work to the run that did it (the
        // same cross-thread caveat as `MiningReport::kernel` applies).
        sc.events().emit(SparkletEvent::KernelSnapshot {
            intersections: kernel_stats.intersections,
            early_aborts: kernel_stats.early_aborts,
            repr_switches: kernel_stats.repr_switches,
            bytes_allocated: kernel_stats.bytes_allocated,
            nanos: kernel_stats.nanos,
        });
        let all_stages = sc.metrics().stages();
        let stages = all_stages
            .get(stage_mark.min(all_stages.len())..)
            .map(|s| s.to_vec())
            .unwrap_or_default();
        // Rules derive from the FULL result: generate_rules looks up
        // antecedent/consequent supports anti-monotonically, and a
        // condensed (closed/maximal/top-k) result would miss them.
        let rules = self
            .min_conf
            .map(|conf| generate_rules(&mined, conf, n_transactions.unwrap_or(0)));
        let mut result = mined;
        for stage in &self.post {
            result = stage.apply(&result);
        }
        Ok(MiningReport {
            engine: engine.name(),
            label: engine.label(),
            min_sup: cfg.min_sup,
            n_transactions,
            tidset: cfg.tidset,
            result,
            rules,
            wall_ms,
            stages,
            kernel: kernel_stats,
        })
    }

    /// Run on an in-memory database: parallelize over the context's
    /// default parallelism, normalize transactions, mine.
    pub fn run_vec(
        &self,
        sc: &SparkletContext,
        txns: &[Transaction],
    ) -> Result<MiningReport, FimError> {
        let parts = sc.default_parallelism().max(1);
        let rdd = sc.parallelize(txns.to_vec(), parts).map(|mut t| {
            t.sort_unstable();
            t.dedup();
            t
        });
        self.run_with_known_count(sc, &rdd, Some(txns.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::sequential::eclat_sequential;

    fn demo_db() -> Vec<Transaction> {
        vec![
            vec![1, 2, 5],
            vec![2, 4],
            vec![2, 3],
            vec![1, 2, 4],
            vec![1, 3],
            vec![2, 3],
            vec![1, 3],
            vec![1, 2, 3, 5],
            vec![1, 2, 3],
        ]
    }

    #[test]
    fn registry_resolves_names_and_aliases() {
        for name in [
            "eclat-v1", "eclat-v5", "eclat-v6", "apriori", "fpgrowth", "sequential",
        ] {
            assert!(EngineRegistry::get(name).is_some(), "{name}");
        }
        // aliases and spelling variants
        assert_eq!(EngineRegistry::get("v4").unwrap().name(), "eclat-v4");
        assert_eq!(EngineRegistry::get("EclatV4").unwrap().name(), "eclat-v4");
        assert_eq!(EngineRegistry::get("YAFIM").unwrap().name(), "apriori");
        assert_eq!(EngineRegistry::get("fp-growth").unwrap().name(), "fpgrowth");
        assert_eq!(EngineRegistry::get("oracle").unwrap().name(), "sequential");
        assert!(EngineRegistry::get("nope").is_none());
        // tidset sensitivity drives the bench repr sweep
        assert!(EngineRegistry::get("eclat-v4").unwrap().tidset_sensitive());
        assert!(EngineRegistry::get("sequential").unwrap().tidset_sensitive());
        assert!(!EngineRegistry::get("apriori").unwrap().tidset_sensitive());
        assert!(!EngineRegistry::get("fpgrowth").unwrap().tidset_sensitive());
    }

    #[test]
    fn registry_names_cover_the_paper_family() {
        let names = EngineRegistry::names();
        for want in [
            "eclat-v1", "eclat-v2", "eclat-v3", "eclat-v4", "eclat-v5",
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn unknown_engine_error_suggests() {
        let sc = SparkletContext::local(2);
        let err = MiningSession::new("eclat-v9")
            .min_sup(2)
            .run_vec(&sc, &demo_db())
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown engine"), "{msg}");
        assert!(msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn every_builtin_engine_matches_oracle_all_reprs() {
        let sc = SparkletContext::local(2);
        let oracle = eclat_sequential(&demo_db(), 2);
        for name in EngineRegistry::names() {
            for repr in TidsetRepr::all_concrete() {
                let report = MiningSession::new(name)
                    .min_sup(2)
                    .tidset(repr)
                    .p(3)
                    .run_vec(&sc, &demo_db())
                    .unwrap();
                assert!(
                    report.result.same_as(&oracle),
                    "{name} tidset={}",
                    repr.name()
                );
            }
        }
    }

    #[test]
    fn partition_strategies_are_orthogonal_to_results() {
        let sc = SparkletContext::local(2);
        let oracle = eclat_sequential(&demo_db(), 2);
        for strategy in [
            PartitionStrategy::EngineDefault,
            PartitionStrategy::Ranked,
            PartitionStrategy::Hash,
            PartitionStrategy::ReverseHash,
            PartitionStrategy::Weighted,
        ] {
            let report = MiningSession::new("eclat-v3")
                .min_sup(2)
                .partitioning(strategy)
                .p(3)
                .run_vec(&sc, &demo_db())
                .unwrap();
            assert!(report.result.same_as(&oracle), "{}", strategy.name());
        }
    }

    #[test]
    fn auto_repr_resolves_by_density() {
        // dense: every item in half the transactions
        assert_eq!(
            TidsetRepr::Auto.resolve(500, 10, 100),
            TidsetRepr::Bitmap
        );
        // sparse: avg support 1 out of 10_000
        assert_eq!(TidsetRepr::Auto.resolve(10, 10, 10_000), TidsetRepr::Vec);
        // fixed reprs pass through
        assert_eq!(TidsetRepr::Vec.resolve(500, 10, 100), TidsetRepr::Vec);
        assert_eq!(
            TidsetRepr::Bitmap.resolve(1, 10, 10_000),
            TidsetRepr::Bitmap
        );
        // degenerate inputs
        assert_eq!(TidsetRepr::Auto.resolve(0, 0, 0), TidsetRepr::Vec);
        // and a real mine under Auto stays exact
        let sc = SparkletContext::local(2);
        let report = MiningSession::new("eclat-v5")
            .min_sup(2)
            .tidset(TidsetRepr::Auto)
            .run_vec(&sc, &demo_db())
            .unwrap();
        assert!(report.result.same_as(&eclat_sequential(&demo_db(), 2)));
    }

    #[test]
    fn post_stage_pipeline_applies_in_order() {
        let sc = SparkletContext::local(2);
        let full = MiningSession::new("eclat-v4")
            .min_sup(2)
            .run_vec(&sc, &demo_db())
            .unwrap()
            .result;
        let closed = MiningSession::new("eclat-v4")
            .min_sup(2)
            .post(PostStage::Closed)
            .run_vec(&sc, &demo_db())
            .unwrap()
            .result;
        assert!(closed.same_as(&postprocess::closed_itemsets(&full)));
        let top3 = MiningSession::new("eclat-v4")
            .min_sup(2)
            .post(PostStage::Maximal)
            .post(PostStage::TopK(3))
            .run_vec(&sc, &demo_db())
            .unwrap()
            .result;
        assert!(top3.len() <= 3);
    }

    #[test]
    fn post_stage_parse_accepts_cli_and_wire_specs() {
        assert_eq!(PostStage::parse("closed"), Ok(PostStage::Closed));
        assert_eq!(PostStage::parse(" maximal "), Ok(PostStage::Maximal));
        assert_eq!(PostStage::parse("top=5"), Ok(PostStage::TopK(5)));
        assert_eq!(PostStage::parse("top:12"), Ok(PostStage::TopK(12)));
        assert!(PostStage::parse("open").unwrap_err().contains("unknown"));
        assert!(PostStage::parse("top=zero")
            .unwrap_err()
            .contains("not a number"));
        assert!(PostStage::parse("top=0").unwrap_err().contains(">= 1"));
    }

    #[test]
    fn rules_ride_along() {
        let sc = SparkletContext::local(2);
        let report = MiningSession::new("eclat-v4")
            .min_sup(2)
            .rules(0.5)
            .run_vec(&sc, &demo_db())
            .unwrap();
        let rules = report.rules.as_ref().unwrap();
        assert!(!rules.is_empty());
        assert!(rules.iter().all(|r| r.confidence >= 0.5));
        assert_eq!(report.n_transactions, Some(demo_db().len()));
        // Rules survive post-stage condensation: they derive from the
        // full result, not the maximal-filtered one.
        let condensed = MiningSession::new("eclat-v4")
            .min_sup(2)
            .post(PostStage::Maximal)
            .rules(0.5)
            .run_vec(&sc, &demo_db())
            .unwrap();
        let condensed_rules = condensed.rules.as_ref().unwrap();
        assert_eq!(condensed_rules.len(), rules.len());
        assert!(condensed_rules.iter().all(|r| !r.lift.is_nan()));
    }

    #[test]
    fn report_carries_stage_metrics() {
        let sc = SparkletContext::local(2);
        let before = sc.metrics().stages().len();
        let report = MiningSession::new("eclat-v1")
            .min_sup(2)
            .run_vec(&sc, &demo_db())
            .unwrap();
        assert!(report.n_stages() > 0, "eclat runs stages");
        assert!(report.wall_ms >= 0.0);
        // only the stages of *this* run, not the context's history
        assert_eq!(
            sc.metrics().stages().len(),
            before + report.n_stages()
        );
        assert_eq!(report.engine, "eclat-v1");
        assert_eq!(report.label, "EclatV1");
        assert!(report.summary().contains("EclatV1"));
    }

    #[test]
    fn fractional_min_sup_resolves_at_run_time() {
        let sc = SparkletContext::local(2);
        let report = MiningSession::new("eclat-v3")
            .min_sup_frac(0.5)
            .run_vec(&sc, &demo_db())
            .unwrap();
        // ceil(0.5 * 9) = 5
        assert_eq!(report.min_sup, 5);
        assert!(report
            .result
            .same_as(&eclat_sequential(&demo_db(), 5)));
    }

    #[test]
    fn custom_engine_registers_in_one_line() {
        // A correct "new backend": delegates to the oracle. Registering
        // it makes it addressable by the session API immediately.
        struct MirrorOracle;
        impl FimEngine for MirrorOracle {
            fn name(&self) -> &'static str {
                "mirror-oracle"
            }
            fn mine(
                &self,
                _sc: &SparkletContext,
                txns: &Rdd<Transaction>,
                cfg: &MiningConfig,
            ) -> Result<MiningResult, FimError> {
                Ok(eclat_sequential(&txns.collect(), cfg.min_sup))
            }
        }
        EngineRegistry::register(Arc::new(MirrorOracle));
        let sc = SparkletContext::local(2);
        let report = MiningSession::new("mirror-oracle")
            .min_sup(2)
            .run_vec(&sc, &demo_db())
            .unwrap();
        assert!(report.result.same_as(&eclat_sequential(&demo_db(), 2)));
    }

    #[test]
    fn panicking_engine_surfaces_as_typed_execution_error() {
        // An engine that unwinds (the closure-typed run_stage path
        // panics on retry exhaustion) must reach the session caller as
        // Err(FimError::Execution), never as a propagated panic.
        struct Unwinder;
        impl FimEngine for Unwinder {
            fn name(&self) -> &'static str {
                "test-unwinder"
            }
            fn mine(
                &self,
                _sc: &SparkletContext,
                _txns: &Rdd<Transaction>,
                _cfg: &MiningConfig,
            ) -> Result<MiningResult, FimError> {
                panic!("stage deadbeef failed: retries exhausted after 3 attempts: boom");
            }
        }
        EngineRegistry::register(Arc::new(Unwinder));
        let sc = SparkletContext::local(2);
        let err = MiningSession::new("test-unwinder")
            .min_sup(2)
            .run_vec(&sc, &demo_db())
            .unwrap_err();
        match &err {
            FimError::Execution { reason } => {
                assert!(reason.contains("retries exhausted"), "{reason}");
            }
            other => panic!("want Execution, got {other:?}"),
        }
        assert!(err.to_string().contains("mining failed"), "{err}");
        // An engine returning a typed error passes through untouched.
        struct TypedFail;
        impl FimEngine for TypedFail {
            fn name(&self) -> &'static str {
                "test-typed-fail"
            }
            fn mine(
                &self,
                _sc: &SparkletContext,
                _txns: &Rdd<Transaction>,
                _cfg: &MiningConfig,
            ) -> Result<MiningResult, FimError> {
                Err(FimError::Execution {
                    reason: "deadline exceeded: 9 ms elapsed against a 5 ms budget".into(),
                })
            }
        }
        EngineRegistry::register(Arc::new(TypedFail));
        let err = MiningSession::new("test-typed-fail")
            .min_sup(2)
            .run_vec(&sc, &demo_db())
            .unwrap_err();
        assert!(err.to_string().contains("deadline exceeded"), "{err}");
    }

    #[test]
    fn kernel_stats_ride_along_in_reports() {
        let sc = SparkletContext::local(2);
        for repr in TidsetRepr::all_concrete() {
            let report = MiningSession::new("eclat-v4")
                .min_sup(2)
                .tidset(repr)
                .run_vec(&sc, &demo_db())
                .unwrap();
            // the demo db always pays at least one kernel intersection
            assert!(
                report.kernel.intersections > 0,
                "{}: {:?}",
                repr.name(),
                report.kernel
            );
            assert!(report.summary().contains("kernel"));
        }
    }

    #[test]
    fn axis_parsers() {
        assert_eq!(TidsetRepr::parse("bitmap").unwrap(), TidsetRepr::Bitmap);
        assert_eq!(TidsetRepr::parse("VEC").unwrap(), TidsetRepr::Vec);
        assert_eq!(TidsetRepr::parse("auto").unwrap(), TidsetRepr::Auto);
        assert_eq!(TidsetRepr::parse("diffset").unwrap(), TidsetRepr::Diffset);
        assert_eq!(TidsetRepr::parse("dEclat").unwrap(), TidsetRepr::Diffset);
        assert_eq!(TidsetRepr::parse("hybrid").unwrap(), TidsetRepr::Hybrid);
        assert_eq!(TidsetRepr::parse("adaptive").unwrap(), TidsetRepr::Hybrid);
        assert!(TidsetRepr::parse("trie").is_err());
        // fixed adaptive reprs pass through Auto resolution unchanged
        assert_eq!(
            TidsetRepr::Diffset.resolve(500, 10, 100),
            TidsetRepr::Diffset
        );
        assert_eq!(TidsetRepr::Hybrid.resolve(1, 10, 10_000), TidsetRepr::Hybrid);
        assert_eq!(
            PartitionStrategy::parse("weighted").unwrap(),
            PartitionStrategy::Weighted
        );
        assert_eq!(
            PartitionStrategy::parse("reverse-hash").unwrap(),
            PartitionStrategy::ReverseHash
        );
        assert!(PartitionStrategy::parse("zigzag").is_err());
    }
}
