//! Bench target: Table 1 — dataset properties (generated vs paper) plus
//! generation throughput.

use rdd_eclat::coordinator::{experiments, ExperimentConfig};
use rdd_eclat::data::Dataset;
use rdd_eclat::util::bench::BenchSuite;

fn main() {
    let cfg = ExperimentConfig::default();
    println!("{}", experiments::table1(&cfg));

    let mut suite = BenchSuite::new("table1_generation", "dataset generation time");
    for d in Dataset::all() {
        suite.measure(d.name(), "scale", cfg.scale, || {
            let _ = d.generate_scaled(cfg.seed, cfg.scale);
        });
    }
    suite.finish();
}
