//! Bench target: microbenchmarks of the hot-path primitives — the inputs
//! to the §Perf optimization loop (EXPERIMENTS.md).
//!
//!  * tidset intersection throughput (merge, gallop, bitmap)
//!  * scalar vs unrolled kernel series (the u64×8 block loops) plus
//!    batched vs per-call class intersection
//!  * triangular-matrix update throughput
//!  * trie candidate counting
//!  * Sparklet shuffle (reduceByKey) record throughput
//!  * Bottom-Up recursion on a synthetic dense class

use rdd_eclat::fim::eqclass::{bottom_up, EquivalenceClass};
use rdd_eclat::fim::tidset::{BitmapTidset, DiffTidset, HybridTidset, TidOps, VecTidset};
use rdd_eclat::fim::trie::ItemTrie;
use rdd_eclat::fim::trimatrix::TriMatrix;
use rdd_eclat::sparklet::{PairRdd, SparkletContext};
use rdd_eclat::util::bench::BenchSuite;
use rdd_eclat::util::{Bitmap, SplitMix64};

fn main() {
    // REPRO_MICRO_ONLY=intersect,kernel,bottom-up runs a subset — the CI
    // bench smoke uses it so kernel regressions surface as wall-time
    // deltas in the uploaded bench-results artifact without paying for
    // the full suite.
    let only = std::env::var("REPRO_MICRO_ONLY").unwrap_or_default();
    let run = |name: &str| only.is_empty() || only.split(',').any(|s| s.trim() == name);
    if run("intersect") {
        intersection_bench();
    }
    if run("kernel") {
        kernel_bench();
    }
    if run("trimatrix") {
        trimatrix_bench();
    }
    if run("trie") {
        trie_bench();
    }
    if run("shuffle") {
        shuffle_bench();
    }
    if run("bottom-up") {
        bottom_up_bench();
    }
}

fn random_tids(rng: &mut SplitMix64, universe: usize, density: f64) -> Vec<u32> {
    (0..universe as u32).filter(|_| rng.gen_bool(density)).collect()
}

fn intersection_bench() {
    let mut suite = BenchSuite::new("micro_intersect", "tidset intersection throughput");
    let mut rng = SplitMix64::new(1);
    let universe = 100_000;
    let a = random_tids(&mut rng, universe, 0.1);
    let b = random_tids(&mut rng, universe, 0.1);
    let small = random_tids(&mut rng, universe, 0.002);

    let va = VecTidset::from_tids(&a, universe);
    let vb = VecTidset::from_tids(&b, universe);
    let vs = VecTidset::from_tids(&small, universe);
    suite.measure("merge-10k∩10k", "case", 0.0, || {
        std::hint::black_box(va.intersect_support(&vb));
    });
    suite.measure("gallop-200∩10k", "case", 1.0, || {
        std::hint::black_box(vs.intersect_support(&va));
    });

    let ba = BitmapTidset::from_tids(&a, universe);
    let bb = BitmapTidset::from_tids(&b, universe);
    suite.measure("bitmap-and-count", "case", 2.0, || {
        std::hint::black_box(ba.intersect_support(&bb));
    });
    suite.measure("bitmap-and-alloc", "case", 3.0, || {
        std::hint::black_box(ba.intersect(&bb));
    });

    // Diffset kernel on a dense class: two members at ~80% of the
    // prefix support — the subtraction walks the small diffsets while
    // the vec merge walks the full tidsets (the dEclat win case).
    let dense_universe = 50_000;
    let base = random_tids(&mut rng, dense_universe, 0.8);
    let keep = |rng: &mut SplitMix64, frac: f64| -> Vec<u32> {
        base.iter().copied().filter(|_| rng.gen_bool(frac)).collect()
    };
    let (x, y) = (keep(&mut rng, 0.8), keep(&mut rng, 0.8));
    let dp = DiffTidset::from_tids(&base, dense_universe);
    let dx = dp.intersect(&DiffTidset::from_tids(&x, dense_universe));
    let dy = dp.intersect(&DiffTidset::from_tids(&y, dense_universe));
    suite.measure("diffset-subtract-dense", "case", 4.0, || {
        std::hint::black_box(dx.intersect_support(&dy));
    });
    let vx = VecTidset::from_tids(&x, dense_universe);
    let vy = VecTidset::from_tids(&y, dense_universe);
    suite.measure("vec-merge-dense", "case", 5.0, || {
        std::hint::black_box(vx.intersect_support(&vy));
    });
    // fused bounded+materializing walk into a reused buffer (no alloc)
    let mut scratch = DiffTidset::empty();
    suite.measure("diffset-into-min-dense", "case", 6.0, || {
        std::hint::black_box(dx.intersect_into_min(&dy, 1, &mut scratch));
    });
    suite.finish();
}

/// Scalar reference loops for the kernel series: the pre-unroll 3-way
/// branch shapes, kept here so the CSV always carries a baseline to
/// ratio the shipped kernels against.
fn scalar_merge_intersect(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

fn scalar_merge_difference_count(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut count) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                count += 1;
                i += 1;
            }
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    count + (a.len() - i)
}

fn kernel_bench() {
    let mut suite = BenchSuite::new(
        "micro_kernel",
        "scalar vs unrolled/branchless tidset kernels (u64×8 word blocks)",
    );
    let mut rng = SplitMix64::new(7);

    // --- bitmap AND+popcount: the CI-gated pair ------------------------
    // Dense 50% bitmaps over 200k tids = 6250 words = ~390 unroll blocks;
    // need=1 so the min-bound probe never aborts and both loops walk the
    // full word arrays. Inner-repeat so medians are stable even under
    // REPRO_BENCH_REPS=1 (the CI smoke setting).
    let universe = 200_000;
    let inner = 256;
    let ba = Bitmap::from_sorted_tids(&random_tids(&mut rng, universe, 0.5), universe);
    let bb = Bitmap::from_sorted_tids(&random_tids(&mut rng, universe, 0.5), universe);
    let mut out = Bitmap::new(universe);
    suite.measure("bitmap-into-min-scalar", "case", 0.0, || {
        for _ in 0..inner {
            std::hint::black_box(ba.and_into_min_scalar(&bb, 1, &mut out));
        }
    });
    suite.measure("bitmap-into-min-unrolled", "case", 1.0, || {
        for _ in 0..inner {
            std::hint::black_box(ba.and_into_min(&bb, 1, &mut out));
        }
    });
    suite.measure("bitmap-count-scalar", "case", 2.0, || {
        for _ in 0..inner {
            std::hint::black_box(ba.and_count_scalar(&bb));
        }
    });
    suite.measure("bitmap-count-unrolled", "case", 3.0, || {
        for _ in 0..inner {
            std::hint::black_box(ba.and_count(&bb));
        }
    });
    suite.measure("bitmap-count-min-scalar", "case", 4.0, || {
        for _ in 0..inner {
            std::hint::black_box(ba.and_count_min_scalar(&bb, 1));
        }
    });
    suite.measure("bitmap-count-min-unrolled", "case", 5.0, || {
        for _ in 0..inner {
            std::hint::black_box(ba.and_count_min(&bb, 1));
        }
    });

    // --- vec merge: 3-way-branch scalar vs branchless two-pointer ------
    let a = random_tids(&mut rng, universe, 0.1);
    let b = random_tids(&mut rng, universe, 0.1);
    let mut vout: Vec<u32> = Vec::new();
    let vec_inner = 32;
    suite.measure("vec-merge-scalar", "case", 6.0, || {
        for _ in 0..vec_inner {
            scalar_merge_intersect(&a, &b, &mut vout);
            std::hint::black_box(vout.len());
        }
    });
    suite.measure("vec-merge-branchless", "case", 7.0, || {
        for _ in 0..vec_inner {
            VecTidset::intersect_sorted_into(&a, &b, &mut vout);
            std::hint::black_box(vout.len());
        }
    });

    // --- diffset subtraction: 3-way-branch scalar vs branchless --------
    // d(PXY) = d(PY) \ d(PX) on ~20% holes of a dense base (the dEclat
    // shape from intersection_bench).
    let dense_universe = 50_000;
    let base = random_tids(&mut rng, dense_universe, 0.8);
    let keep = |rng: &mut SplitMix64, frac: f64| -> Vec<u32> {
        base.iter().copied().filter(|_| rng.gen_bool(frac)).collect()
    };
    let (x, y) = (keep(&mut rng, 0.8), keep(&mut rng, 0.8));
    let dp = DiffTidset::from_tids(&base, dense_universe);
    let dx = dp.intersect(&DiffTidset::from_tids(&x, dense_universe));
    let dy = dp.intersect(&DiffTidset::from_tids(&y, dense_universe));
    let diffs_of = |d: &DiffTidset| -> Vec<u32> {
        match d {
            DiffTidset::Diff { diffs, .. } => diffs.clone(),
            DiffTidset::Tids(t) => t.clone(),
        }
    };
    let (dx_tids, dy_tids) = (diffs_of(&dx), diffs_of(&dy));
    suite.measure("diffset-subtract-scalar", "case", 8.0, || {
        for _ in 0..vec_inner {
            std::hint::black_box(scalar_merge_difference_count(&dy_tids, &dx_tids));
        }
    });
    suite.measure("diffset-subtract-branchless", "case", 9.0, || {
        for _ in 0..vec_inner {
            std::hint::black_box(dx.intersect_support(&dy));
        }
    });

    // --- class intersection: per-call loop vs batched entry point ------
    // Same 32-member bitmap class through both paths; the batched path
    // amortizes the kernel clock to two reads per class.
    let class_universe = 20_000;
    let cbase = random_tids(&mut rng, class_universe, 0.4);
    let prefix_ts = BitmapTidset::from_tids(&cbase, class_universe);
    let members: Vec<(u32, BitmapTidset)> = (0..32u32)
        .map(|i| {
            let tids: Vec<u32> =
                cbase.iter().copied().filter(|_| rng.gen_bool(0.8)).collect();
            (i, BitmapTidset::from_tids(&tids, class_universe))
        })
        .collect();
    let mut pool: Vec<BitmapTidset> = Vec::new();
    let mut survivors: Vec<(u32, BitmapTidset)> = Vec::new();
    suite.measure("class-per-call", "case", 10.0, || {
        for (_, m) in &members {
            let mut buf = pool.pop().unwrap_or_else(BitmapTidset::empty);
            match prefix_ts.intersect_into_min(m, 1, &mut buf) {
                Some(sup) => {
                    std::hint::black_box(sup);
                    survivors.push((0, buf));
                }
                None => pool.push(buf),
            }
        }
        pool.extend(survivors.drain(..).map(|(_, ts)| ts));
    });
    suite.measure("class-batched", "case", 11.0, || {
        prefix_ts.intersect_class_into(&members, 1, &mut pool, &mut survivors, |_, sup| {
            std::hint::black_box(sup);
        });
        pool.extend(survivors.drain(..).map(|(_, ts)| ts));
    });
    suite.finish();
}

fn trimatrix_bench() {
    let mut suite = BenchSuite::new("micro_trimatrix", "triangular matrix update throughput");
    let mut rng = SplitMix64::new(2);
    let n_items = 1000;
    let txns: Vec<Vec<u32>> = (0..5_000)
        .map(|_| {
            let mut t: Vec<u32> = (0..40).map(|_| rng.gen_range(n_items) as u32).collect();
            t.sort_unstable();
            t.dedup();
            t
        })
        .collect();
    suite.measure("update-5k-wide-txns", "width", 40.0, || {
        let mut m = TriMatrix::new(n_items);
        for t in &txns {
            m.update_transaction(t);
        }
        std::hint::black_box(&m);
    });
    suite.finish();
}

fn trie_bench() {
    let mut suite = BenchSuite::new("micro_trie", "candidate trie subset counting");
    let mut rng = SplitMix64::new(3);
    let n_items = 300u32;
    // 2000 random 3-item candidates
    let mut trie = ItemTrie::new();
    for _ in 0..2000 {
        let mut c: Vec<u32> = (0..3).map(|_| rng.gen_range(n_items as usize) as u32).collect();
        c.sort_unstable();
        c.dedup();
        if c.len() == 3 {
            trie.insert(&c);
        }
    }
    let txns: Vec<Vec<u32>> = (0..2_000)
        .map(|_| {
            let mut t: Vec<u32> = (0..15).map(|_| rng.gen_range(n_items as usize) as u32).collect();
            t.sort_unstable();
            t.dedup();
            t
        })
        .collect();
    suite.measure("count-2k-cands-2k-txns", "case", 0.0, || {
        let mut local = trie.clone();
        for t in &txns {
            local.count_subsets(t);
        }
        std::hint::black_box(&local);
    });
    suite.finish();
}

fn shuffle_bench() {
    let mut suite = BenchSuite::new("micro_shuffle", "Sparklet reduceByKey throughput");
    for &n in &[100_000usize, 500_000] {
        let pairs: Vec<(u32, u64)> = (0..n).map(|i| ((i % 1000) as u32, 1u64)).collect();
        suite.measure("reduceByKey", "records", n as f64, || {
            let sc = SparkletContext::local(2);
            let out = sc
                .parallelize(pairs.clone(), 8)
                .reduce_by_key(|a, b| a + b)
                .collect();
            std::hint::black_box(out);
        });
    }
    suite.finish();
}

fn bottom_up_bench() {
    let mut suite = BenchSuite::new(
        "micro_bottom_up",
        "Bottom-Up recursion on a dense class, per tidset representation",
    );
    let mut rng = SplitMix64::new(4);
    let universe = 20_000;
    // one class with 40 members over a correlated tid universe — deep
    // recursion territory; regenerate per representation from the same
    // tid lists so the four series mine identical lattices
    let base = random_tids(&mut rng, universe, 0.4);
    let member_tids: Vec<Vec<u32>> = (0..40u32)
        .map(|_| {
            base.iter()
                .copied()
                .filter(|_| rng.gen_bool(0.8))
                .collect()
        })
        .collect();
    fn class_of<TS: TidOps>(member_tids: &[Vec<u32>], universe: usize) -> EquivalenceClass<TS> {
        EquivalenceClass {
            prefix: vec![999],
            members: member_tids
                .iter()
                .enumerate()
                .map(|(i, tids)| (i as u32, TS::from_tids(tids, universe)))
                .collect(),
        }
    }
    let vec_class = class_of::<VecTidset>(&member_tids, universe);
    let bitmap_class = class_of::<BitmapTidset>(&member_tids, universe);
    let diff_class = class_of::<DiffTidset>(&member_tids, universe);
    let hybrid_class = class_of::<HybridTidset>(&member_tids, universe);
    for &min_sup_frac in &[0.35f64, 0.3] {
        let min_sup = (universe as f64 * min_sup_frac) as u32;
        suite.measure("vec", "min_sup", min_sup_frac, || {
            let mut out = Vec::new();
            bottom_up(&vec_class, min_sup, &mut out);
            std::hint::black_box(out.len());
        });
        suite.measure("bitmap", "min_sup", min_sup_frac, || {
            let mut out = Vec::new();
            bottom_up(&bitmap_class, min_sup, &mut out);
            std::hint::black_box(out.len());
        });
        suite.measure("diffset", "min_sup", min_sup_frac, || {
            let mut out = Vec::new();
            bottom_up(&diff_class, min_sup, &mut out);
            std::hint::black_box(out.len());
        });
        suite.measure("hybrid", "min_sup", min_sup_frac, || {
            let mut out = Vec::new();
            bottom_up(&hybrid_class, min_sup, &mut out);
            std::hint::black_box(out.len());
        });
    }
    suite.finish();
}
