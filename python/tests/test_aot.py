"""AOT path: lowering produces loadable, well-formed HLO text.

The rust runtime's loader is exercised end-to-end in rust tests; here we
validate the python half — that every artifact lowers, is HLO text (not a
proto), declares the expected parameter/result shapes, and that the
jax-side execution of the lowered function still matches the oracle.
"""

import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels.ref import cooccurrence_ref, intersect_ref


def test_cooc_hlo_text_shape_signature():
    text = aot.lower_cooc(128, 512)
    assert text.startswith("HloModule"), "must be HLO text, not a proto"
    assert "f32[128,512]" in text
    assert "f32[128,128]" in text


@pytest.mark.parametrize("rows,words", aot.INTERSECT_SHAPES)
def test_intersect_hlo_text_shape_signature(rows, words):
    text = aot.lower_intersect(rows, words)
    assert text.startswith("HloModule")
    assert f"s32[{rows},{words}]" in text
    assert f"s32[{rows}]" in text


def test_minsup_artifact_has_scalar_param():
    text = aot.lower_intersect_minsup(64, 256)
    assert text.startswith("HloModule")
    # three parameters: x, y, min_sup scalar
    assert len(re.findall(r"parameter\(2\)", text)) >= 1


def test_root_is_tuple():
    # return_tuple=True => root instruction is a tuple; the rust side
    # unwraps with to_tupleN.
    text = aot.lower_intersect(64, 256)
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert any("tuple" in l for l in root_lines)


def test_emit_all_writes_manifest(tmp_path):
    outdir = str(tmp_path)
    written = aot.emit_all(outdir)
    manifest = (tmp_path / "manifest.txt").read_text().split()
    assert set(written) == set(manifest)
    assert "model.hlo.txt" in manifest
    model_text = (tmp_path / "model.hlo.txt").read_text()
    default_text = (tmp_path / aot.DEFAULT_MODEL).read_text()
    assert model_text == default_text


def test_lowered_cooc_executes_like_oracle():
    rng = np.random.default_rng(11)
    a = (rng.random((128, 512)) < 0.3).astype(np.float32)
    compiled = jax.jit(model.cooc_step).lower(
        jax.ShapeDtypeStruct((128, 512), jnp.float32)
    ).compile()
    (got,) = compiled(a)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(cooccurrence_ref(jnp.asarray(a)))
    )


def test_lowered_intersect_executes_like_oracle():
    rng = np.random.default_rng(12)
    x = rng.integers(-(2**31), 2**31, size=(64, 256), dtype=np.int64).astype(
        np.int32
    )
    y = rng.integers(-(2**31), 2**31, size=(64, 256), dtype=np.int64).astype(
        np.int32
    )
    spec = jax.ShapeDtypeStruct((64, 256), jnp.int32)
    compiled = jax.jit(model.intersect_step).lower(spec, spec).compile()
    gi, gs = compiled(x, y)
    wi, ws = intersect_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
