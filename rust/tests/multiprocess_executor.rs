//! End-to-end tests for the multi-process executor: real worker
//! processes (the `repro` binary's hidden `worker` subcommand) connected
//! over unix sockets, remote dispatch of described bottom-up mining
//! tasks, driver-served shuffle block fetches, and lineage re-execution
//! when a worker is killed mid-stage.
//!
//! The worker binary comes from `CARGO_BIN_EXE_repro` — never
//! `current_exe()`, which under `cargo test` is the libtest harness and
//! would fork-bomb the test run.

use std::sync::Arc;

use rdd_eclat::data::Dataset;
use rdd_eclat::fim::engine::MiningSession;
use rdd_eclat::fim::sequential::eclat_sequential;
use rdd_eclat::fim::types::{abs_min_sup, Transaction};
use rdd_eclat::sparklet::events::{CollectingListener, SparkletEvent};
use rdd_eclat::sparklet::{SparkletConf, SparkletContext};

fn sample_db() -> (Vec<Transaction>, u32) {
    let txns = Dataset::T10I4D100K.generate_scaled(42, 0.01); // ~1K txns
    let min_sup = abs_min_sup(0.02, txns.len());
    (txns, min_sup)
}

/// A conf wired to fork real worker processes from the repro binary.
fn mp_conf(app: &str, workers: usize, event_log: Option<&str>) -> SparkletConf {
    rdd_eclat::sparklet::remote::register_backend();
    rdd_eclat::fim::distributed::register_tasks();
    let mut conf = SparkletConf::new(app)
        .with_workers(workers)
        .unwrap()
        .with_worker_binary(env!("CARGO_BIN_EXE_repro"))
        .with_executor_backend("multi-process")
        .unwrap();
    if let Some(path) = event_log {
        conf = conf.with_event_log(path);
    }
    conf
}

fn temp_log(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("sparklet-mp-{name}-{}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn multi_process_mining_matches_sequential_oracle() {
    let (txns, min_sup) = sample_db();
    let oracle = eclat_sequential(&txns, min_sup);
    assert!(!oracle.is_empty());

    // Sequential-backend run: the single-process reference.
    let seq_sc = SparkletContext::new(
        SparkletConf::new("mp-oracle")
            .with_executor_backend("sequential")
            .unwrap(),
    );
    let seq = MiningSession::new("eclat-v3")
        .min_sup(min_sup)
        .p(4)
        .run_vec(&seq_sc, &txns)
        .unwrap();
    assert!(seq.result.same_as(&oracle));

    // Multi-process run: 2 forked workers, bottom-up tasks dispatched
    // over the socket, shuffle blocks fetched back from the driver.
    let log = temp_log("mine");
    let sc = SparkletContext::new(mp_conf("mp-e2e", 2, Some(&log)));
    assert_eq!(sc.executor().name(), "multi-process");
    assert!(sc.executor().supports_described());
    let got = MiningSession::new("eclat-v3")
        .min_sup(min_sup)
        .p(4)
        .run_vec(&sc, &txns)
        .unwrap();
    assert!(got.result.same_as(&oracle), "multi-process result diverged");
    drop(sc); // flush + close the event log

    let events = std::fs::read_to_string(&log).unwrap();
    let registered = events
        .lines()
        .filter(|l| l.contains("\"type\": \"WorkerRegistered\""))
        .count();
    assert!(registered >= 2, "want >= 2 worker registrations:\n{events}");
    assert!(
        events.contains("Described/fim.bottomup"),
        "described stage never ran:\n{events}"
    );
    assert!(
        events.contains("\"type\": \"RemoteFetch\""),
        "workers never fetched shuffle blocks from the driver:\n{events}"
    );
    // Task spans carry the worker id that ran them.
    assert!(
        events
            .lines()
            .any(|l| l.contains("\"type\": \"TaskEnd\"") && l.contains("\"worker\": \"w")),
        "no task span tagged with a worker id:\n{events}"
    );
    std::fs::remove_file(&log).ok();
}

#[test]
fn killed_worker_mid_stage_recovers_via_lineage() {
    let (txns, min_sup) = sample_db();
    let oracle = eclat_sequential(&txns, min_sup);

    // w0 dies (process exit) instead of reporting its first task result;
    // the dispatcher must surface WorkerLost, fail the in-flight task,
    // and the scheduler re-runs it from lineage on the survivor.
    let conf = mp_conf("mp-fault", 2, None).with_worker_fault("w0:1");
    let sc = SparkletContext::new(conf);
    let sink = CollectingListener::new();
    sc.events().register(Arc::new(sink.clone()));

    let got = MiningSession::new("eclat-v3")
        .min_sup(min_sup)
        .p(4)
        .run_vec(&sc, &txns)
        .unwrap();
    assert!(got.result.same_as(&oracle), "post-kill result diverged");

    let lost: Vec<String> = sink
        .snapshot()
        .into_iter()
        .filter_map(|(_, ev)| match ev {
            SparkletEvent::WorkerLost { worker, .. } => Some(worker),
            _ => None,
        })
        .collect();
    assert_eq!(lost, vec!["w0".to_string()], "w0 should die exactly once");
    assert!(
        sc.metrics().total_retries() > 0,
        "the killed worker's task should have retried"
    );
}

#[test]
fn closure_stages_still_run_on_the_multi_process_driver() {
    // Non-described task sets (ordinary RDD closures) execute inline on
    // the driver: the backend is a superset, not a replacement.
    let sc = SparkletContext::new(mp_conf("mp-closures", 2, None));
    let sum: u64 = sc
        .parallelize((0..1_000u64).collect::<Vec<_>>(), 4)
        .map(|x| x * 2)
        .map_to_pair(|x| (x % 7, x))
        .reduce_by_key(|a, b| a + b)
        .values()
        .collect()
        .iter()
        .sum();
    assert_eq!(sum, (0..1_000u64).map(|x| x * 2).sum::<u64>());
}
