//! Per-stage execution metrics (timings, task counts, retries, executor
//! backend counters).

use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What kind of stage produced the metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    ShuffleMap,
    Result,
    /// A driver-submitted streaming task set (e.g. the incremental
    /// miner's border-candidate recomputation) — no RDD behind it.
    Streaming,
}

#[derive(Debug, Clone)]
pub struct StageMetrics {
    pub kind: StageKind,
    pub rdd_id: usize,
    pub num_tasks: usize,
    pub wall: Duration,
    pub task_millis: Vec<f64>,
    pub retries: usize,
    /// Shuffle records written while this stage ran (map stages; 0 for
    /// pure result stages).
    pub shuffle_records: u64,
    /// **Exact** serialized shuffle bytes written while this stage ran
    /// (sum of block lengths — see `ShuffleManager::bytes_written`).
    pub shuffle_bytes: u64,
    /// Shuffle blocks spilled to disk under the memory budget while
    /// this stage ran.
    pub spilled_blocks: u64,
    /// Executor backend that ran the stage's task set.
    pub backend: &'static str,
    /// Tasks executed by a worker other than the one they were queued
    /// on (work-stealing backend; 0 elsewhere).
    pub steals: usize,
    /// Total time the stage's tasks sat queued before a worker picked
    /// them up, milliseconds.
    pub queue_wait_ms: f64,
}

impl StageMetrics {
    pub fn max_task_ms(&self) -> f64 {
        self.task_millis.iter().copied().fold(0.0, f64::max)
    }

    pub fn total_task_ms(&self) -> f64 {
        self.task_millis.iter().sum()
    }

    /// q-quantile of this stage's task durations (0 when no tasks).
    pub fn task_quantile(&self, q: f64) -> f64 {
        if self.task_millis.is_empty() {
            0.0
        } else {
            crate::util::stats::quantile(&self.task_millis, q)
        }
    }

    /// Skew factor: max/median task duration. 1.0 = perfectly balanced,
    /// 0 when unmeasured (no tasks, or all-zero timings).
    pub fn skew(&self) -> f64 {
        let med = crate::util::stats::median(&self.task_millis);
        if med <= 0.0 {
            0.0
        } else {
            self.max_task_ms() / med
        }
    }
}

/// EWMA smoothing factor for the per-partition cost feedback (higher =
/// faster adaptation to the latest run).
pub const PARTITION_COST_EWMA_ALPHA: f64 = 0.4;

/// Registry of all stages run by a context.
#[derive(Default)]
pub struct MetricsRegistry {
    stages: Mutex<Vec<StageMetrics>>,
    /// Gauge probing the executor's currently-running task count
    /// (wired by the context; surfaces `ThreadPool::active` & co.).
    active_source: Mutex<Option<Arc<dyn Fn() -> usize + Send + Sync>>>,
    /// EWMA of per-partition cost (task ms + amortized queue wait) from
    /// observed stages — the feedback `PartitionStrategy::Weighted`
    /// reads so class placement learns from the previous run/window.
    ewma_partition_ms: Mutex<Vec<f64>>,
    /// Accumulated intersection-kernel work, folded from
    /// `KernelSnapshot` events by the metrics listener: (intersections,
    /// in-kernel wall nanos). Kept here as plain totals so the registry
    /// can report kernel throughput without depending on `fim`.
    kernel_work: Mutex<(u64, u64)>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, m: StageMetrics) {
        self.stages.lock().unwrap().push(m);
    }

    /// Fold one `KernelSnapshot` delta (intersections, in-kernel wall
    /// nanos) into the registry's running totals. Called by the metrics
    /// listener, so the registry stays a pure derivation of the event
    /// stream.
    pub fn record_kernel(&self, intersections: u64, nanos: u64) {
        let mut k = self.kernel_work.lock().unwrap();
        k.0 += intersections;
        k.1 += nanos;
    }

    /// Accumulated (intersections, in-kernel wall nanos) across every
    /// mine this context ran.
    pub fn kernel_totals(&self) -> (u64, u64) {
        *self.kernel_work.lock().unwrap()
    }

    /// Intersection kernel throughput across the context's lifetime
    /// (0.0 when no kernel time was recorded).
    pub fn kernel_intersections_per_sec(&self) -> f64 {
        let (n, ns) = self.kernel_totals();
        if ns == 0 {
            0.0
        } else {
            n as f64 * 1e9 / ns as f64
        }
    }

    /// Wire the live active-task gauge (called by the context with the
    /// executor backend's `active()`).
    pub fn set_active_source(&self, f: impl Fn() -> usize + Send + Sync + 'static) {
        *self.active_source.lock().unwrap() = Some(Arc::new(f));
    }

    /// Tasks executing right now, per the wired gauge (0 when unwired).
    pub fn active_tasks(&self) -> usize {
        let probe = self.active_source.lock().unwrap().clone();
        probe.map(|f| f()).unwrap_or(0)
    }

    /// Total cross-worker task steals across all recorded stages.
    pub fn total_steals(&self) -> usize {
        self.stages.lock().unwrap().iter().map(|s| s.steals).sum()
    }

    /// Total shuffle blocks spilled across all recorded stages.
    pub fn total_spilled_blocks(&self) -> u64 {
        self.stages
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.spilled_blocks)
            .sum()
    }

    /// Fold one stage's per-partition execution signal (task wall ms
    /// plus the stage's queue wait amortized over its tasks) into the
    /// EWMA the weighted partitioner reads. Observations whose task
    /// count differs from the stored vector reset it — the placement
    /// geometry changed, so old per-partition history is meaningless.
    pub fn observe_partition_costs(&self, task_millis: &[f64], queue_wait_ms: f64) {
        let n = task_millis.len();
        if n == 0 {
            return;
        }
        let share = queue_wait_ms / n as f64;
        let mut ewma = self.ewma_partition_ms.lock().unwrap();
        if ewma.len() != n {
            *ewma = task_millis.iter().map(|&t| t + share).collect();
            return;
        }
        for (e, &t) in ewma.iter_mut().zip(task_millis) {
            *e = PARTITION_COST_EWMA_ALPHA * (t + share)
                + (1.0 - PARTITION_COST_EWMA_ALPHA) * *e;
        }
    }

    /// Normalized per-partition relative cost (mean 1.0) for a `p`-way
    /// placement, or `None` when there is no usable history (never
    /// observed, different partition count, or all-zero costs).
    pub fn partition_cost_weights(&self, p: usize) -> Option<Vec<f64>> {
        let ewma = self.ewma_partition_ms.lock().unwrap();
        if ewma.len() != p || p == 0 {
            return None;
        }
        let mean: f64 = ewma.iter().sum::<f64>() / p as f64;
        if mean <= 0.0 {
            return None;
        }
        Some(ewma.iter().map(|&e| (e / mean).max(f64::EPSILON)).collect())
    }

    pub fn stages(&self) -> Vec<StageMetrics> {
        self.stages.lock().unwrap().clone()
    }

    /// The most recently recorded stage, cloning only that entry (the
    /// per-mine feedback path reads this once per run — `stages()`
    /// would clone the context's whole history every time).
    pub fn last_stage(&self) -> Option<StageMetrics> {
        self.stages.lock().unwrap().last().cloned()
    }

    pub fn total_retries(&self) -> usize {
        self.stages.lock().unwrap().iter().map(|s| s.retries).sum()
    }

    /// Total shuffle records written across all recorded stages.
    pub fn total_shuffle_records(&self) -> u64 {
        self.stages
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.shuffle_records)
            .sum()
    }

    /// Total exact shuffle bytes written across all recorded stages —
    /// the volume signal streaming backpressure decisions read.
    pub fn total_shuffle_bytes(&self) -> u64 {
        self.stages
            .lock()
            .unwrap()
            .iter()
            .map(|s| s.shuffle_bytes)
            .sum()
    }

    /// One-line human-readable report of the recorded stages, plus the
    /// live active-task gauge.
    pub fn report(&self) -> String {
        let stages = self.stages.lock().unwrap();
        let mut maps = 0usize;
        let mut streaming = 0usize;
        let mut retries = 0usize;
        let mut steals = 0usize;
        let mut records = 0u64;
        let mut bytes = 0u64;
        let mut spilled = 0u64;
        let mut wall_ms = 0.0f64;
        for s in stages.iter() {
            match s.kind {
                StageKind::ShuffleMap => maps += 1,
                StageKind::Streaming => streaming += 1,
                StageKind::Result => {}
            }
            retries += s.retries;
            steals += s.steals;
            records += s.shuffle_records;
            bytes += s.shuffle_bytes;
            spilled += s.spilled_blocks;
            wall_ms += s.wall.as_secs_f64() * 1e3;
        }
        let n = stages.len();
        let all_tasks: Vec<f64> = stages
            .iter()
            .flat_map(|s| s.task_millis.iter().copied())
            .collect();
        drop(stages);
        let p95 = if all_tasks.is_empty() {
            0.0
        } else {
            crate::util::stats::quantile(&all_tasks, 0.95)
        };
        let med = crate::util::stats::median(&all_tasks);
        let skew = if med <= 0.0 {
            0.0
        } else {
            crate::util::stats::max(&all_tasks) / med
        };
        let (kernel_n, _) = self.kernel_totals();
        format!(
            "{n} stages ({maps} map, {} result, {streaming} streaming), {wall_ms:.1} ms wall, \
             {retries} retries, {steals} steals, shuffle: {records} records / {bytes} bytes \
             ({spilled} blocks spilled), kernel {kernel_n} ∩ @ {:.0} ∩/s, \
             p95 task {p95:.1} ms / skew {skew:.1}x, {} tasks active",
            n - maps - streaming,
            self.kernel_intersections_per_sec(),
            self.active_tasks(),
        )
    }

    pub fn total_wall(&self) -> Duration {
        self.stages.lock().unwrap().iter().map(|s| s.wall).sum()
    }

    /// Scheduler overhead estimate: wall time minus the critical path
    /// (max task per stage) as a fraction of wall. Used by the perf pass.
    pub fn overhead_fraction(&self) -> f64 {
        let stages = self.stages.lock().unwrap();
        let wall: f64 = stages.iter().map(|s| s.wall.as_secs_f64() * 1e3).sum();
        let critical: f64 = stages.iter().map(|s| s.max_task_ms()).sum();
        if wall <= 0.0 {
            0.0
        } else {
            ((wall - critical) / wall).max(0.0)
        }
    }

    pub fn clear(&self) {
        self.stages.lock().unwrap().clear();
    }

    /// Modeled wall-clock for a `cores`-wide executor, from the recorded
    /// per-task durations: per stage, the LPT (longest-processing-time)
    /// makespan of its tasks over `cores` machines; stages execute
    /// sequentially (Spark's barrier). Used on single-CPU hosts where a
    /// real thread sweep can't show parallel speedup — see DESIGN.md §3.
    pub fn modeled_makespan_ms(&self, cores: usize) -> f64 {
        let cores = cores.max(1);
        let stages = self.stages.lock().unwrap();
        stages
            .iter()
            .map(|s| lpt_makespan(&s.task_millis, cores))
            .sum()
    }
}

/// LPT list-scheduling makespan: sort tasks descending, place each on the
/// least-loaded machine.
pub fn lpt_makespan(tasks: &[f64], machines: usize) -> f64 {
    let mut sorted = tasks.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut load = vec![0.0f64; machines.max(1)];
    for t in sorted {
        let idx = load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        load[idx] += t;
    }
    load.iter().copied().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(kind: StageKind, wall_ms: u64, tasks: Vec<f64>, retries: usize) -> StageMetrics {
        StageMetrics {
            kind,
            rdd_id: 0,
            num_tasks: tasks.len(),
            wall: Duration::from_millis(wall_ms),
            task_millis: tasks,
            retries,
            shuffle_records: 0,
            shuffle_bytes: 0,
            spilled_blocks: 0,
            backend: "fifo",
            steals: 0,
            queue_wait_ms: 0.0,
        }
    }

    #[test]
    fn records_and_aggregates() {
        let r = MetricsRegistry::new();
        r.record(stage(StageKind::ShuffleMap, 10, vec![4.0, 8.0], 1));
        r.record(stage(StageKind::Result, 20, vec![15.0], 0));
        assert_eq!(r.stages().len(), 2);
        assert_eq!(r.total_retries(), 1);
        assert_eq!(r.total_wall(), Duration::from_millis(30));
    }

    #[test]
    fn shuffle_volume_aggregates_and_report() {
        let r = MetricsRegistry::new();
        let mut m = stage(StageKind::ShuffleMap, 5, vec![5.0], 0);
        m.shuffle_records = 100;
        m.shuffle_bytes = 1600;
        m.spilled_blocks = 3;
        r.record(m);
        r.record(stage(StageKind::Result, 5, vec![5.0], 0));
        assert_eq!(r.total_shuffle_records(), 100);
        assert_eq!(r.total_shuffle_bytes(), 1600);
        assert_eq!(r.total_spilled_blocks(), 3);
        let report = r.report();
        assert!(report.contains("100 records"), "{report}");
        assert!(report.contains("1600 bytes"), "{report}");
        assert!(report.contains("3 blocks spilled"), "{report}");
    }

    #[test]
    fn partition_cost_ewma_learns_and_normalizes() {
        let r = MetricsRegistry::new();
        // no history yet
        assert_eq!(r.partition_cost_weights(2), None);
        // first observation seeds the EWMA directly
        r.observe_partition_costs(&[30.0, 10.0], 0.0);
        let w = r.partition_cost_weights(2).unwrap();
        assert!((w[0] - 1.5).abs() < 1e-9 && (w[1] - 0.5).abs() < 1e-9, "{w:?}");
        assert!((w.iter().sum::<f64>() / 2.0 - 1.0).abs() < 1e-9, "mean 1");
        // later observations fold in with the EWMA alpha
        r.observe_partition_costs(&[10.0, 10.0], 0.0);
        let w2 = r.partition_cost_weights(2).unwrap();
        assert!(w2[0] > 1.0 && w2[0] < w[0], "moves toward balance: {w2:?}");
        // queue wait is amortized over the partitions
        r.observe_partition_costs(&[0.0, 0.0], 20.0);
        assert!(r.partition_cost_weights(2).is_some());
        // geometry change resets; mismatched p reads as no history
        assert_eq!(r.partition_cost_weights(3), None);
        r.observe_partition_costs(&[1.0, 2.0, 3.0], 0.0);
        assert_eq!(r.partition_cost_weights(2), None);
        assert_eq!(r.partition_cost_weights(3).unwrap().len(), 3);
        // all-zero history is unusable
        let z = MetricsRegistry::new();
        z.observe_partition_costs(&[0.0, 0.0], 0.0);
        assert_eq!(z.partition_cost_weights(2), None);
        // empty observation is a no-op
        z.observe_partition_costs(&[], 5.0);
        assert_eq!(z.partition_cost_weights(0), None);
    }

    #[test]
    fn report_surfaces_steals_streaming_and_active_gauge() {
        let r = MetricsRegistry::new();
        assert_eq!(r.active_tasks(), 0, "unwired gauge reads 0");
        r.set_active_source(|| 3);
        let mut m = stage(StageKind::Streaming, 5, vec![5.0, 5.0], 0);
        m.backend = "work-stealing";
        m.steals = 4;
        m.queue_wait_ms = 1.5;
        r.record(m);
        assert_eq!(r.total_steals(), 4);
        assert_eq!(r.active_tasks(), 3);
        let report = r.report();
        assert!(report.contains("1 streaming"), "{report}");
        assert!(report.contains("4 steals"), "{report}");
        assert!(report.contains("3 tasks active"), "{report}");
    }

    #[test]
    fn per_stage_quantiles_and_skew() {
        let m = stage(StageKind::Result, 10, vec![1.0, 2.0, 3.0, 12.0], 0);
        assert!((m.task_quantile(0.5) - 2.5).abs() < 1e-9);
        assert_eq!(m.task_quantile(1.0), 12.0);
        // median 2.5, max 12 -> skew 4.8
        assert!((m.skew() - 4.8).abs() < 1e-9);
        let empty = stage(StageKind::Result, 0, vec![], 0);
        assert_eq!(empty.task_quantile(0.5), 0.0);
        assert_eq!(empty.skew(), 0.0);
        assert_eq!(stage(StageKind::Result, 0, vec![0.0, 0.0], 0).skew(), 0.0);
    }

    #[test]
    fn report_surfaces_p95_and_skew() {
        let r = MetricsRegistry::new();
        r.record(stage(StageKind::Result, 10, vec![1.0, 1.0, 1.0, 4.0], 0));
        let report = r.report();
        // median 1.0, max 4.0 -> skew 4.0x
        assert!(report.contains("skew 4.0x"), "{report}");
        assert!(report.contains("p95 task"), "{report}");
        // empty registry still renders (zeros, no NaN)
        let report = MetricsRegistry::new().report();
        assert!(report.contains("p95 task 0.0 ms / skew 0.0x"), "{report}");
    }

    #[test]
    fn kernel_totals_accumulate_and_report_throughput() {
        let r = MetricsRegistry::new();
        assert_eq!(r.kernel_totals(), (0, 0));
        assert_eq!(r.kernel_intersections_per_sec(), 0.0, "no time, no rate");
        r.record_kernel(500, 1_000_000); // 500 ∩ in 1 ms
        r.record_kernel(500, 1_000_000);
        assert_eq!(r.kernel_totals(), (1000, 2_000_000));
        let per_sec = r.kernel_intersections_per_sec();
        assert!((per_sec - 500_000.0).abs() < 1e-6, "{per_sec}");
        let report = r.report();
        assert!(report.contains("kernel 1000 ∩ @ 500000 ∩/s"), "{report}");
    }

    #[test]
    fn overhead_fraction_bounds() {
        let r = MetricsRegistry::new();
        assert_eq!(r.overhead_fraction(), 0.0);
        r.record(stage(StageKind::Result, 100, vec![90.0], 0));
        let f = r.overhead_fraction();
        assert!(f > 0.0 && f < 0.2, "overhead {f}");
    }

    #[test]
    fn clear_resets() {
        let r = MetricsRegistry::new();
        r.record(stage(StageKind::Result, 5, vec![5.0], 0));
        r.clear();
        assert!(r.stages().is_empty());
    }

    #[test]
    fn lpt_makespan_basics() {
        // 4 equal tasks on 2 machines: 2 each
        assert_eq!(lpt_makespan(&[1.0, 1.0, 1.0, 1.0], 2), 2.0);
        // single machine: sum
        assert_eq!(lpt_makespan(&[3.0, 2.0, 1.0], 1), 6.0);
        // dominated by the largest task
        assert_eq!(lpt_makespan(&[10.0, 1.0, 1.0], 4), 10.0);
        // empty
        assert_eq!(lpt_makespan(&[], 3), 0.0);
    }

    #[test]
    fn modeled_makespan_decreases_with_cores() {
        let r = MetricsRegistry::new();
        r.record(stage(StageKind::Result, 0, vec![5.0; 16], 0));
        let m1 = r.modeled_makespan_ms(1);
        let m4 = r.modeled_makespan_ms(4);
        let m16 = r.modeled_makespan_ms(16);
        assert!(m1 > m4 && m4 > m16);
        assert_eq!(m1, 80.0);
        assert_eq!(m16, 5.0);
    }
}
