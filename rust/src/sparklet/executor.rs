//! Pluggable execution backends — the layer between the DAG scheduler
//! and the worker threads.
//!
//! The scheduler no longer talks to a thread pool directly: it builds a
//! first-class [`TaskSet`] (one boxed closure per partition, plus a
//! [`StageDesc`]) and submits it to whatever [`ExecutorBackend`] the
//! context was configured with. Submission is asynchronous — `submit`
//! returns a [`JobHandle`] immediately, so several task sets can be in
//! flight at once (the streaming miner exploits this to recompute
//! border candidates concurrently) — and every handle reports
//! [`TaskSetStats`]: how many tasks were stolen across workers and how
//! long tasks sat queued before a worker picked them up. Both counters
//! flow into [`super::metrics::StageMetrics`].
//!
//! Three backends ship, registered behind the string-keyed
//! [`ExecutorRegistry`] (mirroring `fim::engine::EngineRegistry`, so a
//! future multi-process backend is a one-line registration):
//!
//! * `fifo` — a shared FIFO queue over a fixed [`ThreadPool`]; today's
//!   behaviour, and the default.
//! * `work-stealing` — per-worker deques with idle-worker stealing.
//!   Eclat equivalence classes are heavily skewed (one class can hold
//!   most of the lattice), so a worker that drew short classes steals
//!   the long class's backlog instead of idling.
//! * `sequential` — runs every task inline on the submitting thread in
//!   submission order: deterministic, single-threaded, the right
//!   substrate for reproducible tests and debugging.
//!
//! Result delivery stays the submitter's concern: task closures capture
//! their own channels. The backend only guarantees that every task runs
//! exactly once (panics included — a panicking task is caught so worker
//! threads survive and the handle still completes; the submitter's own
//! `catch_unwind` is what turns the panic into a retryable error).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle as ThreadHandle;
use std::time::Instant;

use crate::util::text::closest;
use crate::util::ThreadPool;

use super::transport::TaskDescriptor;

/// A unit of work. Tasks deliver results through channels they capture;
/// the executor only runs them.
pub type TaskFn = Box<dyn FnOnce() + Send + 'static>;

/// Completion callback for a described task: the serialized result (or
/// task error) plus the remote-measured run time in milliseconds.
pub type DescribedSink = Box<dyn FnOnce(Result<Vec<u8>, String>, f64) + Send + 'static>;

/// One task inside a [`TaskSet`]: either an in-memory closure (the
/// in-process backends' native currency) or a serialized
/// [`TaskDescriptor`] a remote-capable backend can ship to a worker
/// process.
pub enum Task {
    Closure(TaskFn),
    Described {
        desc: TaskDescriptor,
        on_result: DescribedSink,
    },
}

impl Task {
    /// Degrade to a plain closure for backends without remote dispatch.
    /// The scheduler only emits `Described` tasks to backends that
    /// report [`ExecutorBackend::supports_described`], so hitting this
    /// on a described task means a backend contract violation — it
    /// completes the task with a typed error (feeding the normal retry
    /// accounting) instead of hanging the job or panicking a worker.
    fn into_runnable(self, backend: &'static str) -> TaskFn {
        match self {
            Self::Closure(f) => f,
            Self::Described { desc, on_result } => Box::new(move || {
                on_result(
                    Err(format!(
                        "backend '{backend}' cannot execute described task \
                         (stage {:x}, part {}, key '{}')",
                        desc.stage_tag, desc.part, desc.key
                    )),
                    0.0,
                )
            }),
        }
    }
}

/// Handles the driver gives an [`ExecutorBackend`] at context creation
/// ([`ExecutorBackend::attach`]): the shuffle manager whose blocks the
/// backend serves to remote workers, the event bus for
/// worker-lifecycle events, the context's fault-injection plane (so
/// driver-side transport sites fire on the same schedule tests
/// observe), and the resolved configuration (worker count, socket dir,
/// heartbeat/timeout knobs).
#[derive(Clone)]
pub struct BackendServices {
    pub shuffle: Arc<super::shuffle::ShuffleManager>,
    pub events: Arc<super::events::EventBus>,
    pub faults: Arc<super::faults::FaultPlane>,
    pub conf: super::conf::SparkletConf,
}

pub(crate) use crate::util::pool::panic_message;

// ------------------------------------------------------------ descriptors

/// What a [`TaskSet`] is for — carried into logs and metrics.
#[derive(Debug, Clone)]
pub struct StageDesc {
    /// Scheduler stage tag (ties executor diagnostics to stages).
    pub stage_tag: u64,
    /// Human-readable stage name, e.g. `"ShuffleMap/rdd3/attempt0"`.
    pub name: String,
}

/// A first-class description of one stage's tasks, built by the
/// scheduler (or any other driver-side submitter) and handed to an
/// [`ExecutorBackend`].
pub struct TaskSet {
    /// Descriptor for diagnostics.
    pub stage: StageDesc,
    tasks: Vec<Task>,
}

impl TaskSet {
    pub fn new(stage_tag: u64, name: impl Into<String>) -> Self {
        Self {
            stage: StageDesc {
                stage_tag,
                name: name.into(),
            },
            tasks: Vec::new(),
        }
    }

    /// Append one closure task.
    pub fn push(&mut self, task: impl FnOnce() + Send + 'static) {
        self.tasks.push(Task::Closure(Box::new(task)));
    }

    /// Append one serialized task descriptor. Only meaningful on
    /// backends reporting [`ExecutorBackend::supports_described`];
    /// elsewhere it completes with an error (see
    /// [`Task::into_runnable`]).
    pub fn push_described(
        &mut self,
        desc: TaskDescriptor,
        on_result: impl FnOnce(Result<Vec<u8>, String>, f64) + Send + 'static,
    ) {
        self.tasks.push(Task::Described {
            desc,
            on_result: Box::new(on_result),
        });
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub(crate) fn into_parts(self) -> (StageDesc, Vec<Task>) {
        (self.stage, self.tasks)
    }
}

// ------------------------------------------------------------- job handle

/// Execution counters of one task set, reported by [`JobHandle::wait`]
/// and recorded into `StageMetrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TaskSetStats {
    /// Tasks executed by a worker other than the one they were queued
    /// on (always 0 for `fifo` and `sequential`).
    pub steals: usize,
    /// Total time tasks spent queued before a worker picked them up,
    /// in milliseconds (summed over tasks).
    pub queue_wait_ms: f64,
}

pub(crate) struct JobState {
    total: usize,
    done: Mutex<usize>,
    all_done: Condvar,
    steals: AtomicUsize,
    queue_wait_us: AtomicU64,
}

impl JobState {
    pub(crate) fn new(total: usize) -> Self {
        Self {
            total,
            done: Mutex::new(0),
            all_done: Condvar::new(),
            steals: AtomicUsize::new(0),
            queue_wait_us: AtomicU64::new(0),
        }
    }

    /// Mark one task complete (runs even when the task panicked, so a
    /// handle can never hang).
    pub(crate) fn finish_task(&self) {
        let mut done = self.done.lock().unwrap();
        *done += 1;
        if *done >= self.total {
            self.all_done.notify_all();
        }
    }

    fn stats(&self) -> TaskSetStats {
        TaskSetStats {
            steals: self.steals.load(Ordering::Relaxed),
            queue_wait_ms: self.queue_wait_us.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }
}

/// Asynchronous handle on a submitted [`TaskSet`]. Dropping the handle
/// does *not* cancel the tasks; `wait` blocks until every task has run.
pub struct JobHandle {
    state: Arc<JobState>,
    stage: StageDesc,
}

impl JobHandle {
    pub(crate) fn new(state: Arc<JobState>, stage: StageDesc) -> Self {
        Self { state, stage }
    }

    pub fn stage(&self) -> &StageDesc {
        &self.stage
    }

    /// Have all tasks of the set finished?
    pub fn is_complete(&self) -> bool {
        *self.state.done.lock().unwrap() >= self.state.total
    }

    /// Block until every task of the set has run, then return the set's
    /// execution counters.
    pub fn wait(&self) -> TaskSetStats {
        let mut done = self.state.done.lock().unwrap();
        while *done < self.state.total {
            done = self.state.all_done.wait(done).unwrap();
        }
        drop(done);
        self.state.stats()
    }
}

// ----------------------------------------------------------------- trait

/// An execution substrate tasks are submitted to. Implementations must
/// run every task of a submitted set exactly once and survive task
/// panics.
pub trait ExecutorBackend: Send + Sync {
    /// Canonical registry name (kebab-case, e.g. `"work-stealing"`).
    fn name(&self) -> &'static str;

    /// Worker parallelism (1 for `sequential`).
    fn cores(&self) -> usize;

    /// Submit a task set for execution. Returns immediately; use the
    /// returned [`JobHandle`] to await completion. Multiple submitted
    /// sets may be in flight concurrently.
    fn submit(&self, tasks: TaskSet) -> JobHandle;

    /// Tasks currently executing (metrics gauge; best-effort).
    fn active(&self) -> usize {
        0
    }

    /// Can this backend execute serialized [`TaskDescriptor`]s
    /// (dispatching them to remote workers)? The scheduler degrades
    /// described stages to local closures when this is `false`.
    fn supports_described(&self) -> bool {
        false
    }

    /// Late-binding hook called once by the context after the shuffle
    /// manager and event bus exist: remote-capable backends spawn and
    /// register their workers here. The default is a no-op so
    /// in-process backends stay untouched.
    fn attach(&self, services: BackendServices) -> Result<(), String> {
        let _ = services;
        Ok(())
    }
}

/// Shared per-task bookkeeping: record queue wait, run under
/// `catch_unwind`, mark the job state done.
fn run_task(task: TaskFn, state: &JobState, enqueued: Instant, stolen: bool) {
    state
        .queue_wait_us
        .fetch_add(enqueued.elapsed().as_micros() as u64, Ordering::Relaxed);
    if stolen {
        state.steals.fetch_add(1, Ordering::Relaxed);
    }
    let _ = catch_unwind(AssertUnwindSafe(task));
    state.finish_task();
}

// ------------------------------------------------------------------ fifo

/// Today's executor: a shared FIFO queue drained by a fixed
/// [`ThreadPool`] ("one executor JVM, `threads` = executor cores").
pub struct FifoBackend {
    pool: ThreadPool,
}

impl FifoBackend {
    pub fn new(cores: usize) -> Self {
        Self {
            pool: ThreadPool::new(cores.max(1)),
        }
    }
}

impl ExecutorBackend for FifoBackend {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn cores(&self) -> usize {
        self.pool.size()
    }

    fn active(&self) -> usize {
        self.pool.active()
    }

    fn submit(&self, tasks: TaskSet) -> JobHandle {
        let (stage, tasks) = tasks.into_parts();
        let state = Arc::new(JobState::new(tasks.len()));
        for task in tasks {
            let task = task.into_runnable("fifo");
            let st = Arc::clone(&state);
            let enqueued = Instant::now();
            self.pool.execute(move || run_task(task, &st, enqueued, false));
        }
        JobHandle::new(state, stage)
    }
}

// -------------------------------------------------------------- sequential

/// Deterministic single-thread backend: tasks run inline on the
/// submitting thread, in submission order. `submit` returns an
/// already-completed handle.
#[derive(Default)]
pub struct SequentialBackend {
    active: AtomicUsize,
}

impl SequentialBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ExecutorBackend for SequentialBackend {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn cores(&self) -> usize {
        1
    }

    fn active(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    fn submit(&self, tasks: TaskSet) -> JobHandle {
        let (stage, tasks) = tasks.into_parts();
        let state = Arc::new(JobState::new(tasks.len()));
        for task in tasks {
            let task = task.into_runnable("sequential");
            self.active.fetch_add(1, Ordering::Relaxed);
            run_task(task, &state, Instant::now(), false);
            self.active.fetch_sub(1, Ordering::Relaxed);
        }
        JobHandle::new(state, stage)
    }
}

// ----------------------------------------------------------- work-stealing

struct WorkItem {
    task: TaskFn,
    state: Arc<JobState>,
    enqueued: Instant,
}

struct StealShared {
    /// One deque per worker. Owners pop the front (submission order);
    /// thieves pop the back, so a thief and the owner contend on
    /// opposite ends of the deque.
    queues: Vec<Mutex<VecDeque<WorkItem>>>,
    /// Queued-but-not-started items. Guards the sleep/wake protocol:
    /// submitters increment under this lock before notifying, workers
    /// only sleep after seeing 0 under it, so wakeups cannot be lost.
    pending: Mutex<usize>,
    available: Condvar,
    shutdown: AtomicBool,
    active: AtomicUsize,
}

/// Per-worker deques with idle-worker stealing. Better than `fifo`
/// when task durations are skewed: short-task workers drain their own
/// deque and then steal the long tail instead of idling behind a
/// single shared queue's head-of-line order.
pub struct WorkStealingBackend {
    shared: Arc<StealShared>,
    workers: Vec<ThreadHandle<()>>,
    next: AtomicUsize,
    size: usize,
}

impl WorkStealingBackend {
    pub fn new(cores: usize) -> Self {
        let size = cores.max(1);
        let shared = Arc::new(StealShared {
            queues: (0..size).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: Mutex::new(0),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sparklet-steal-{i}"))
                    .spawn(move || steal_worker_loop(shared, i))
                    .expect("spawn work-stealing worker")
            })
            .collect();
        Self {
            shared,
            workers,
            next: AtomicUsize::new(0),
            size,
        }
    }
}

/// Pop from the worker's own deque, else steal from another's tail.
/// Returns the item and whether it was stolen.
fn take_item(shared: &StealShared, me: usize) -> Option<(WorkItem, bool)> {
    if let Some(item) = shared.queues[me].lock().unwrap().pop_front() {
        return Some((item, false));
    }
    let n = shared.queues.len();
    for off in 1..n {
        let victim = (me + off) % n;
        if let Some(item) = shared.queues[victim].lock().unwrap().pop_back() {
            return Some((item, true));
        }
    }
    None
}

fn steal_worker_loop(shared: Arc<StealShared>, me: usize) {
    loop {
        match take_item(&shared, me) {
            Some((item, stolen)) => {
                *shared.pending.lock().unwrap() -= 1;
                shared.active.fetch_add(1, Ordering::Relaxed);
                run_task(item.task, &item.state, item.enqueued, stolen);
                shared.active.fetch_sub(1, Ordering::Relaxed);
            }
            None => {
                let pending = shared.pending.lock().unwrap();
                if *pending == 0 {
                    if shared.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    // Sleep until a submitter (who increments `pending`
                    // under this same lock) notifies. A wakeup with no
                    // item left (another worker raced us) just loops.
                    let _guard = shared.available.wait(pending).unwrap();
                }
                // pending > 0 but the scan found nothing: another worker
                // holds the item in flight — retry the scan.
            }
        }
    }
}

impl ExecutorBackend for WorkStealingBackend {
    fn name(&self) -> &'static str {
        "work-stealing"
    }

    fn cores(&self) -> usize {
        self.size
    }

    fn active(&self) -> usize {
        self.shared.active.load(Ordering::Relaxed)
    }

    fn submit(&self, tasks: TaskSet) -> JobHandle {
        let (stage, tasks) = tasks.into_parts();
        let state = Arc::new(JobState::new(tasks.len()));
        for task in tasks {
            let slot = self.next.fetch_add(1, Ordering::Relaxed) % self.size;
            let item = WorkItem {
                task: task.into_runnable("work-stealing"),
                state: Arc::clone(&state),
                enqueued: Instant::now(),
            };
            // Increment `pending` *before* the item becomes visible: a
            // racing worker that pops it decrements immediately, and the
            // counter must never underflow.
            *self.shared.pending.lock().unwrap() += 1;
            self.shared.queues[slot].lock().unwrap().push_back(item);
            self.shared.available.notify_one();
        }
        JobHandle::new(state, stage)
    }
}

impl Drop for WorkStealingBackend {
    fn drop(&mut self) {
        {
            // Store + notify under the `pending` lock: a worker that
            // just saw shutdown=false re-acquires this lock before it
            // can sleep, so the notify cannot fall between its check
            // and its wait (lost wakeup ⇒ join would hang).
            let _pending = self.shared.pending.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.available.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// -------------------------------------------------------------- registry

/// Factory building a backend for a given core count.
pub type BackendFactory = Arc<dyn Fn(usize) -> Arc<dyn ExecutorBackend> + Send + Sync>;

struct BackendEntry {
    name: &'static str,
    aliases: &'static [&'static str],
    describe: &'static str,
    factory: BackendFactory,
}

static EXECUTORS: OnceLock<Mutex<Vec<BackendEntry>>> = OnceLock::new();

fn builtin_backends() -> Vec<BackendEntry> {
    vec![
        BackendEntry {
            name: "fifo",
            aliases: &["pool", "threadpool"],
            describe: "shared FIFO queue over a fixed thread pool (default)",
            factory: Arc::new(|cores| Arc::new(FifoBackend::new(cores))),
        },
        BackendEntry {
            name: "work-stealing",
            aliases: &["steal", "ws", "workstealing"],
            describe: "per-worker deques with idle-worker stealing (skew-tolerant)",
            factory: Arc::new(|cores| Arc::new(WorkStealingBackend::new(cores))),
        },
        BackendEntry {
            name: "sequential",
            aliases: &["seq", "inline"],
            describe: "deterministic single-thread inline execution (tests/debugging)",
            factory: Arc::new(|_| Arc::new(SequentialBackend::new())),
        },
    ]
}

fn executors() -> &'static Mutex<Vec<BackendEntry>> {
    EXECUTORS.get_or_init(|| Mutex::new(builtin_backends()))
}

/// Case/punctuation-insensitive lookup key ("WorkStealing" ==
/// "work-stealing"), same normalization as the engine registry.
fn normalize(name: &str) -> String {
    name.chars()
        .filter(|c| *c != '-' && *c != '_')
        .flat_map(|c| c.to_lowercase())
        .collect()
}

/// Typed executor-layer errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecutorError {
    /// The named backend is not registered.
    UnknownBackend {
        name: String,
        suggestion: Option<String>,
    },
}

impl std::fmt::Display for ExecutorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::UnknownBackend { name, suggestion } => {
                write!(f, "unknown executor backend {name:?}")?;
                if let Some(s) = suggestion {
                    write!(f, " — did you mean {s:?}?")?;
                }
                write!(f, " (registered: {})", ExecutorRegistry::names().join(", "))
            }
        }
    }
}

impl std::error::Error for ExecutorError {}

/// The static backend registry: name → factory, mirroring
/// `EngineRegistry`. Additional backends (e.g. a multi-process
/// executor) call [`ExecutorRegistry::register`] once and immediately
/// become addressable from `SparkletConf`, the CLI `--executor` flag,
/// the bench sweep, and the cross-backend test suites.
pub struct ExecutorRegistry;

impl ExecutorRegistry {
    /// Canonical names of all registered backends, in registration
    /// order.
    pub fn names() -> Vec<&'static str> {
        executors().lock().unwrap().iter().map(|e| e.name).collect()
    }

    /// Resolve a (possibly aliased/misspelled-case) name to its
    /// canonical registered form.
    pub fn canonical(name: &str) -> Option<&'static str> {
        let key = normalize(name);
        let reg = executors().lock().unwrap();
        reg.iter()
            .find(|e| normalize(e.name) == key)
            .or_else(|| {
                reg.iter()
                    .find(|e| e.aliases.iter().any(|a| normalize(a) == key))
            })
            .map(|e| e.name)
    }

    /// Build a backend instance by name for `cores` workers.
    pub fn create(name: &str, cores: usize) -> Result<Arc<dyn ExecutorBackend>, ExecutorError> {
        let key = normalize(name);
        let reg = executors().lock().unwrap();
        let entry = reg
            .iter()
            .find(|e| normalize(e.name) == key)
            .or_else(|| {
                reg.iter()
                    .find(|e| e.aliases.iter().any(|a| normalize(a) == key))
            })
            .ok_or_else(|| ExecutorError::UnknownBackend {
                name: name.to_string(),
                suggestion: Self::suggest_locked(&reg, name),
            })?;
        Ok((entry.factory)(cores))
    }

    /// Register a backend factory (replacing any same-name entry) —
    /// the one-line hook future backends use.
    pub fn register(
        name: &'static str,
        describe: &'static str,
        factory: impl Fn(usize) -> Arc<dyn ExecutorBackend> + Send + Sync + 'static,
    ) {
        let mut reg = executors().lock().unwrap();
        let key = normalize(name);
        reg.retain(|e| normalize(e.name) != key);
        reg.push(BackendEntry {
            name,
            aliases: &[],
            describe,
            factory: Arc::new(factory),
        });
    }

    fn suggest_locked(reg: &[BackendEntry], name: &str) -> Option<String> {
        let candidates: Vec<&'static str> = reg
            .iter()
            .flat_map(|e| std::iter::once(e.name).chain(e.aliases.iter().copied()))
            .collect();
        closest(&name.to_lowercase(), candidates, 3).map(str::to_string)
    }

    /// Closest registered name/alias to a misspelled input.
    pub fn suggest(name: &str) -> Option<String> {
        Self::suggest_locked(&executors().lock().unwrap(), name)
    }

    /// `name — description` lines for `--help`.
    pub fn describe_all() -> String {
        let reg = executors().lock().unwrap();
        let mut out = String::new();
        for e in reg.iter() {
            out.push_str(&format!("  {:<14} {}\n", e.name, e.describe));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// The built-in backends by name. Sibling tests iterate this fixed
    /// list rather than `ExecutorRegistry::names()`: the registry is
    /// process-global and `custom_backend_registers_in_one_line`
    /// mutates it concurrently, which would make names()-driven
    /// coverage order-dependent.
    const BUILTINS: [&str; 3] = ["fifo", "work-stealing", "sequential"];

    fn backend(name: &str, cores: usize) -> Arc<dyn ExecutorBackend> {
        ExecutorRegistry::create(name, cores).unwrap()
    }

    /// Run n squaring tasks through a backend and collect results.
    fn run_squares(ex: &dyn ExecutorBackend, n: usize) -> Vec<usize> {
        let (tx, rx) = channel();
        let mut ts = TaskSet::new(1, "squares");
        for i in 0..n {
            let tx = tx.clone();
            ts.push(move || {
                let _ = tx.send((i, i * i));
            });
        }
        drop(tx);
        let handle = ex.submit(ts);
        let stats = handle.wait();
        assert!(handle.is_complete());
        assert!(stats.queue_wait_ms >= 0.0);
        let mut out = vec![0usize; n];
        for (i, sq) in rx.try_iter() {
            out[i] = sq;
        }
        out
    }

    #[test]
    fn every_builtin_backend_runs_all_tasks() {
        for name in BUILTINS {
            let ex = backend(name, 3);
            let got = run_squares(ex.as_ref(), 50);
            let want: Vec<usize> = (0..50).map(|i| i * i).collect();
            assert_eq!(got, want, "{name}");
        }
    }

    #[test]
    fn handles_are_asynchronous_and_concurrent() {
        // Two task sets in flight at once on one backend; both complete.
        for name in ["fifo", "work-stealing"] {
            let ex = backend(name, 2);
            let (tx, rx) = channel();
            let mut a = TaskSet::new(1, "a");
            let mut b = TaskSet::new(2, "b");
            for i in 0..8 {
                let txa = tx.clone();
                a.push(move || {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    let _ = txa.send(("a", i));
                });
                let txb = tx.clone();
                b.push(move || {
                    let _ = txb.send(("b", i));
                });
            }
            drop(tx);
            let ha = ex.submit(a);
            let hb = ex.submit(b); // submitted before ha completes
            hb.wait();
            ha.wait();
            let got: Vec<_> = rx.try_iter().collect();
            assert_eq!(got.len(), 16, "{name}");
        }
    }

    #[test]
    fn sequential_backend_is_deterministic_submission_order() {
        let ex = backend("sequential", 4); // cores ignored
        assert_eq!(ex.cores(), 1);
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut ts = TaskSet::new(1, "order");
        for i in 0..20 {
            let order = Arc::clone(&order);
            ts.push(move || order.lock().unwrap().push(i));
        }
        // Handle is already complete when submit returns.
        let handle = ex.submit(ts);
        assert!(handle.is_complete());
        handle.wait();
        assert_eq!(*order.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn work_stealing_steals_under_skew() {
        // Round-robin puts the long tasks on worker 0's deque; worker 1
        // drains its short tasks and must steal from worker 0's tail.
        let ex = WorkStealingBackend::new(2);
        let mut ts = TaskSet::new(1, "skew");
        for i in 0..10 {
            ts.push(move || {
                // Even submissions (worker 0's deque) are the slow ones.
                let ms = if i % 2 == 0 { 30 } else { 1 };
                std::thread::sleep(std::time::Duration::from_millis(ms));
            });
        }
        let stats = ex.submit(ts).wait();
        assert!(stats.steals > 0, "no steals under skew: {stats:?}");
    }

    #[test]
    fn panicking_task_completes_the_handle_and_workers_survive() {
        for name in BUILTINS {
            let ex = backend(name, 2);
            let mut ts = TaskSet::new(1, "boom");
            ts.push(|| panic!("boom"));
            ts.push(|| {});
            let stats = ex.submit(ts).wait(); // must not hang
            assert!(stats.queue_wait_ms >= 0.0);
            // Backend still works afterwards.
            let got = run_squares(ex.as_ref(), 4);
            assert_eq!(got, vec![0, 1, 4, 9], "{name}");
        }
    }

    #[test]
    fn registry_lookup_aliases_and_suggestions() {
        assert_eq!(ExecutorRegistry::canonical("fifo"), Some("fifo"));
        assert_eq!(ExecutorRegistry::canonical("WS"), Some("work-stealing"));
        assert_eq!(
            ExecutorRegistry::canonical("WorkStealing"),
            Some("work-stealing")
        );
        assert_eq!(ExecutorRegistry::canonical("seq"), Some("sequential"));
        assert_eq!(ExecutorRegistry::canonical("tokio"), None);
        let err = ExecutorRegistry::create("work-staling", 2).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown executor backend"), "{msg}");
        assert!(msg.contains("did you mean"), "{msg}");
        assert!(msg.contains("work-stealing"), "{msg}");
    }

    #[test]
    fn custom_backend_registers_in_one_line() {
        ExecutorRegistry::register("test-inline", "unit-test backend", |_| {
            Arc::new(SequentialBackend::new())
        });
        assert!(ExecutorRegistry::names().contains(&"test-inline"));
        let ex = backend("test-inline", 8);
        assert_eq!(run_squares(ex.as_ref(), 5), vec![0, 1, 4, 9, 16]);
    }

    #[test]
    fn queue_wait_is_measured_when_workers_are_busy() {
        let ex = FifoBackend::new(1);
        let mut ts = TaskSet::new(1, "wait");
        for _ in 0..4 {
            ts.push(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        }
        let stats = ex.submit(ts).wait();
        // With one worker, tasks 2..4 each waited >= ~10ms.
        assert!(
            stats.queue_wait_ms >= 10.0,
            "queue wait not measured: {stats:?}"
        );
    }

    #[test]
    fn described_task_on_local_backend_completes_with_typed_error() {
        // Local backends can't ship descriptors to workers; they must
        // complete the task with an error (never hang the handle).
        for name in BUILTINS {
            let ex = backend(name, 2);
            let (tx, rx) = channel();
            let mut ts = TaskSet::new(3, "described");
            ts.push_described(
                TaskDescriptor {
                    job_id: 1,
                    stage_tag: 3,
                    part: 0,
                    attempt: 0,
                    key: "nope".into(),
                    payload: vec![],
                },
                move |result, run_ms| {
                    let _ = tx.send((result, run_ms));
                },
            );
            ex.submit(ts).wait();
            let (result, _) = rx.try_iter().next().expect("sink must be called");
            let err = result.unwrap_err();
            assert!(err.contains(name), "{name}: {err}");
            assert!(err.contains("described task"), "{name}: {err}");
        }
    }

    #[test]
    fn empty_task_set_completes_immediately() {
        for name in BUILTINS {
            let ex = backend(name, 2);
            let handle = ex.submit(TaskSet::new(9, "empty"));
            assert!(handle.is_complete(), "{name}");
            assert_eq!(handle.wait(), TaskSetStats::default());
            assert_eq!(handle.stage().stage_tag, 9);
        }
    }
}
