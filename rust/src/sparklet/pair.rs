//! Pair-RDD operations and the shuffle boundary machinery.
//!
//! A wide dependency is a [`ShuffleDependency`]: it owns the parent RDD,
//! the partitioner, and (optionally) a map-side combine aggregator. The
//! scheduler only sees the object-safe [`ShuffleDepObj`] — `run_map_task`
//! is type-erased, so the DAG walk never needs the key/value types.
//!
//! Everything that crosses the boundary is **serialized**: map tasks
//! encode each bucket into an owned byte block
//! ([`super::serde::encode_records`]) and reduce tasks decode it back,
//! so shuffle byte accounting is exact, blocks can spill to disk under
//! the memory budget, and no `Arc`-shared payload survives a stage
//! boundary (asserted in shared-nothing mode). The price is a `SerDe`
//! bound on shuffled key/value/combiner types — narrow transformations
//! stay bound-free.

use std::hash::Hash;
use std::sync::Arc;

use super::context::SparkletContext;
use super::partitioner::{FnPartitioner, HashPartitioner, Partitioner, RangePartitioner};
use super::rdd::{materialize, Data, Dep, DepNode, Rdd, RddBase, TaskContext};
use super::serde::{decode_records, encode_records, SerDe};
use super::shuffle::ShuffleManager;
use crate::util::hash::FxHashMap;

/// Object-safe view of a shuffle dependency for the scheduler.
pub trait ShuffleDepObj: Send + Sync {
    fn shuffle_id(&self) -> usize;
    fn num_map_partitions(&self) -> usize;
    fn num_reduce_partitions(&self) -> usize;
    fn parent_node(&self) -> Arc<dyn DepNode>;
    /// Execute one map task: compute the parent partition, bucket it by
    /// the partitioner (with optional map-side combine), and register the
    /// buckets with the shuffle manager. All buckets are written at the
    /// end so a retried task never half-writes.
    fn run_map_task(&self, map_part: usize, ctx: &TaskContext);
}

/// Map-side / reduce-side combine functions (Spark's `Aggregator`).
pub struct Aggregator<K, V, C> {
    pub create: Arc<dyn Fn(V) -> C + Send + Sync>,
    pub merge_value: Arc<dyn Fn(&mut C, V) + Send + Sync>,
    pub merge_combiners: Arc<dyn Fn(&mut C, C) + Send + Sync>,
    _k: std::marker::PhantomData<fn() -> K>,
}

impl<K, V, C> Aggregator<K, V, C> {
    pub fn new(
        create: impl Fn(V) -> C + Send + Sync + 'static,
        merge_value: impl Fn(&mut C, V) + Send + Sync + 'static,
        merge_combiners: impl Fn(&mut C, C) + Send + Sync + 'static,
    ) -> Self {
        Self {
            create: Arc::new(create),
            merge_value: Arc::new(merge_value),
            merge_combiners: Arc::new(merge_combiners),
            _k: std::marker::PhantomData,
        }
    }
}

impl<K, V, C> Clone for Aggregator<K, V, C> {
    fn clone(&self) -> Self {
        Self {
            create: Arc::clone(&self.create),
            merge_value: Arc::clone(&self.merge_value),
            merge_combiners: Arc::clone(&self.merge_combiners),
            _k: std::marker::PhantomData,
        }
    }
}

/// A wide dependency: parent pair-RDD → partitioned, serialized blocks.
pub struct ShuffleDependency<K: Data + Hash + Eq + SerDe, V: Data + SerDe, C: Data + SerDe> {
    shuffle_id: usize,
    parent: Arc<dyn RddBase<(K, V)>>,
    partitioner: Arc<dyn Partitioner<K>>,
    aggregator: Option<Aggregator<K, V, C>>,
    map_side_combine: bool,
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe, C: Data + SerDe> ShuffleDependency<K, V, C> {
    pub fn new(
        ctx: &SparkletContext,
        parent: Arc<dyn RddBase<(K, V)>>,
        partitioner: Arc<dyn Partitioner<K>>,
        aggregator: Option<Aggregator<K, V, C>>,
        map_side_combine: bool,
    ) -> Self {
        assert!(
            !map_side_combine || aggregator.is_some(),
            "map-side combine requires an aggregator"
        );
        Self {
            shuffle_id: ctx.shuffle_manager().new_shuffle_id(),
            parent,
            partitioner,
            aggregator,
            map_side_combine,
        }
    }
}

/// Serialize each non-empty bucket and register it with the shuffle
/// manager. Under shared-nothing mode every block is decode-verified
/// right after encoding: the block must reconstruct from its bytes
/// alone (self-contained, process-boundary-ready), which is what rules
/// out any `Arc`-shared payload escaping the map side.
fn write_buckets<T: SerDe>(
    mgr: &ShuffleManager,
    shuffle_id: usize,
    map_part: usize,
    buckets: Vec<Vec<T>>,
    shared_nothing: bool,
) {
    for (p, bucket) in buckets.into_iter().enumerate() {
        if bucket.is_empty() {
            continue;
        }
        let n = bucket.len();
        let bytes = encode_records(&bucket);
        if shared_nothing {
            let verified = decode_records::<T>(&bytes).unwrap_or_else(|e| {
                panic!(
                    "shared-nothing check: shuffle {shuffle_id} map {map_part} -> reduce {p} \
                     block does not reconstruct from its bytes: {e}"
                )
            });
            assert_eq!(
                verified.len(),
                n,
                "shared-nothing check: record count drift in shuffle {shuffle_id} block"
            );
        }
        mgr.write_block(shuffle_id, p, map_part, bytes, n);
    }
}

/// Fetch and decode every block of a reduce partition, invoking `sink`
/// per record. Fetch-before-completion and corrupt blocks both panic:
/// inside a task, a panic is a task failure the scheduler surfaces.
fn read_blocks<T: SerDe>(
    mgr: &ShuffleManager,
    shuffle_id: usize,
    reduce_part: usize,
    mut sink: impl FnMut(T),
) {
    let blocks = mgr
        .fetch(shuffle_id, reduce_part)
        .unwrap_or_else(|e| panic!("shuffle fetch failed: {e}"));
    for block in blocks {
        let records: Vec<T> = decode_records(&block.bytes).unwrap_or_else(|e| {
            panic!("corrupt shuffle block (shuffle {shuffle_id}, reduce {reduce_part}): {e}")
        });
        debug_assert_eq!(records.len(), block.records, "block record count drift");
        for rec in records {
            sink(rec);
        }
    }
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe, C: Data + SerDe> ShuffleDepObj
    for ShuffleDependency<K, V, C>
{
    fn shuffle_id(&self) -> usize {
        self.shuffle_id
    }

    fn num_map_partitions(&self) -> usize {
        self.parent.num_partitions()
    }

    fn num_reduce_partitions(&self) -> usize {
        self.partitioner.num_partitions()
    }

    fn parent_node(&self) -> Arc<dyn DepNode> {
        Arc::clone(&self.parent) as Arc<dyn DepNode>
    }

    fn run_map_task(&self, map_part: usize, ctx: &TaskContext) {
        let records = materialize(&self.parent, map_part, ctx);
        let nr = self.num_reduce_partitions();
        let mgr = ctx.context().shuffle_manager();
        let shared_nothing = ctx.context().conf().shared_nothing;
        if self.map_side_combine {
            let agg = self.aggregator.as_ref().unwrap();
            // Combine locally, then bucket and serialize combiners.
            let mut combined: FxHashMap<K, C> = FxHashMap::default();
            for (k, v) in records {
                match combined.get_mut(&k) {
                    Some(c) => (agg.merge_value)(c, v),
                    None => {
                        combined.insert(k, (agg.create)(v));
                    }
                }
            }
            let mut buckets: Vec<Vec<(K, C)>> = (0..nr).map(|_| Vec::new()).collect();
            for (k, c) in combined {
                let p = self.partitioner.partition(&k);
                buckets[p].push((k, c));
            }
            write_buckets(mgr, self.shuffle_id, map_part, buckets, shared_nothing);
        } else {
            let mut buckets: Vec<Vec<(K, V)>> = (0..nr).map(|_| Vec::new()).collect();
            for (k, v) in records {
                let p = self.partitioner.partition(&k);
                buckets[p].push((k, v));
            }
            write_buckets(mgr, self.shuffle_id, map_part, buckets, shared_nothing);
        }
    }
}

// -------------------------------------------------------------- ShuffledRdd

/// Post-shuffle RDD with combine semantics: output is `(K, C)`.
pub struct ShuffledRdd<K: Data + Hash + Eq + SerDe, V: Data + SerDe, C: Data + SerDe> {
    id: usize,
    ctx: SparkletContext,
    dep: Arc<ShuffleDependency<K, V, C>>,
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe, C: Data + SerDe> DepNode
    for ShuffledRdd<K, V, C>
{
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        vec![Dep::Shuffle(
            Arc::clone(&self.dep) as Arc<dyn ShuffleDepObj>
        )]
    }
    fn node_label(&self) -> &'static str {
        "shuffled"
    }
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe, C: Data + SerDe> RddBase<(K, C)>
    for ShuffledRdd<K, V, C>
{
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.ctx.clone()
    }
    fn num_partitions(&self) -> usize {
        self.dep.num_reduce_partitions()
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<(K, C)> {
        let mgr = ctx.context().shuffle_manager();
        let agg = self.dep.aggregator.as_ref().expect("shuffled rdd aggregator");
        let mut merged: FxHashMap<K, C> = FxHashMap::default();
        if self.dep.map_side_combine {
            read_blocks::<(K, C)>(mgr, self.dep.shuffle_id, part, |(k, c)| {
                match merged.get_mut(&k) {
                    Some(acc) => (agg.merge_combiners)(acc, c),
                    None => {
                        merged.insert(k, c);
                    }
                }
            });
        } else {
            read_blocks::<(K, V)>(mgr, self.dep.shuffle_id, part, |(k, v)| {
                match merged.get_mut(&k) {
                    Some(acc) => (agg.merge_value)(acc, v),
                    None => {
                        merged.insert(k, (agg.create)(v));
                    }
                }
            });
        }
        merged.into_iter().collect()
    }
}

// ----------------------------------------------------------- PartitionedRdd

/// Post-shuffle RDD *without* aggregation: `partitionBy` — records land on
/// the partition their key hashes to, values untouched.
pub struct PartitionedRdd<K: Data + Hash + Eq + SerDe, V: Data + SerDe> {
    id: usize,
    ctx: SparkletContext,
    dep: Arc<ShuffleDependency<K, V, V>>,
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe> DepNode for PartitionedRdd<K, V> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        vec![Dep::Shuffle(
            Arc::clone(&self.dep) as Arc<dyn ShuffleDepObj>
        )]
    }
    fn node_label(&self) -> &'static str {
        "partitionBy"
    }
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe> RddBase<(K, V)> for PartitionedRdd<K, V> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.ctx.clone()
    }
    fn num_partitions(&self) -> usize {
        self.dep.num_reduce_partitions()
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<(K, V)> {
        let mgr = ctx.context().shuffle_manager();
        let mut out = Vec::new();
        read_blocks::<(K, V)>(mgr, self.dep.shuffle_id, part, |kv| out.push(kv));
        out
    }
}

// ------------------------------------------------------------ PairRdd trait

/// Key-value operations on `Rdd<(K, V)>` — the `JavaPairRDD` surface the
/// paper's pseudo-code uses. All of these (except the narrow
/// projections) cross a shuffle, so keys, values, and combiners must be
/// [`SerDe`].
pub trait PairRdd<K: Data + Hash + Eq + SerDe, V: Data + SerDe> {
    fn combine_by_key<C: Data + SerDe>(
        &self,
        aggregator: Aggregator<K, V, C>,
        partitioner: Arc<dyn Partitioner<K>>,
        map_side_combine: bool,
    ) -> Rdd<(K, C)>;

    fn reduce_by_key(&self, f: impl Fn(V, V) -> V + Send + Sync + 'static) -> Rdd<(K, V)>;

    fn reduce_by_key_with_partitions(
        &self,
        f: impl Fn(V, V) -> V + Send + Sync + 'static,
        num_partitions: usize,
    ) -> Rdd<(K, V)>;

    fn group_by_key(&self) -> Rdd<(K, Vec<V>)>;

    fn group_by_key_with_partitions(&self, num_partitions: usize) -> Rdd<(K, Vec<V>)>;

    fn partition_by(&self, partitioner: Arc<dyn Partitioner<K>>) -> Rdd<(K, V)>;

    fn map_values<W: Data>(&self, f: impl Fn(V) -> W + Send + Sync + 'static) -> Rdd<(K, W)>;

    fn keys(&self) -> Rdd<K>;

    fn values(&self) -> Rdd<V>;

    fn count_by_key(&self) -> std::collections::HashMap<K, usize>;

    fn collect_as_map(&self) -> std::collections::HashMap<K, V>;

    fn sort_by_key(&self) -> Rdd<(K, V)>
    where
        K: Ord;

    fn join<W: Data + SerDe>(&self, other: &Rdd<(K, W)>) -> Rdd<(K, (V, W))>;

    /// Spark's `aggregateByKey`: zero value + per-value merge + combiner
    /// merge (map-side combined).
    fn aggregate_by_key<C: Data + SerDe>(
        &self,
        zero: C,
        seq_op: impl Fn(&mut C, V) + Send + Sync + 'static,
        comb_op: impl Fn(&mut C, C) + Send + Sync + 'static,
    ) -> Rdd<(K, C)>;

    /// Spark's `foldByKey`: `aggregate_by_key` with C = V.
    fn fold_by_key(
        &self,
        zero: V,
        f: impl Fn(V, V) -> V + Send + Sync + 'static,
    ) -> Rdd<(K, V)>;

    /// Group both RDDs by key in one pass (Spark's `cogroup`).
    fn cogroup<W: Data + SerDe>(&self, other: &Rdd<(K, W)>) -> Rdd<(K, (Vec<V>, Vec<W>))>;
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe> PairRdd<K, V> for Rdd<(K, V)> {
    fn combine_by_key<C: Data + SerDe>(
        &self,
        aggregator: Aggregator<K, V, C>,
        partitioner: Arc<dyn Partitioner<K>>,
        map_side_combine: bool,
    ) -> Rdd<(K, C)> {
        let ctx = self.context();
        let dep = Arc::new(ShuffleDependency::new(
            &ctx,
            Arc::clone(&self.base),
            partitioner,
            Some(aggregator),
            map_side_combine,
        ));
        Rdd::from_base(Arc::new(ShuffledRdd {
            id: ctx.new_rdd_id(),
            ctx,
            dep,
        }))
    }

    fn reduce_by_key(&self, f: impl Fn(V, V) -> V + Send + Sync + 'static) -> Rdd<(K, V)> {
        let n = self.context().conf().shuffle_partitions;
        self.reduce_by_key_with_partitions(f, n)
    }

    fn reduce_by_key_with_partitions(
        &self,
        f: impl Fn(V, V) -> V + Send + Sync + 'static,
        num_partitions: usize,
    ) -> Rdd<(K, V)> {
        let f = Arc::new(f);
        let f2 = Arc::clone(&f);
        let agg = Aggregator::new(
            |v: V| v,
            move |c: &mut V, v: V| {
                let old = c.clone();
                *c = f(old, v);
            },
            move |c: &mut V, o: V| {
                let old = c.clone();
                *c = f2(old, o);
            },
        );
        self.combine_by_key(agg, Arc::new(HashPartitioner::new(num_partitions)), true)
    }

    fn group_by_key(&self) -> Rdd<(K, Vec<V>)> {
        let n = self.context().conf().shuffle_partitions;
        self.group_by_key_with_partitions(n)
    }

    fn group_by_key_with_partitions(&self, num_partitions: usize) -> Rdd<(K, Vec<V>)> {
        let agg = Aggregator::new(
            |v: V| vec![v],
            |c: &mut Vec<V>, v: V| c.push(v),
            |c: &mut Vec<V>, mut o: Vec<V>| c.append(&mut o),
        );
        // Spark does not map-side combine groupByKey (it would buffer the
        // same data anyway); we keep that behaviour.
        self.combine_by_key(agg, Arc::new(HashPartitioner::new(num_partitions)), false)
    }

    fn partition_by(&self, partitioner: Arc<dyn Partitioner<K>>) -> Rdd<(K, V)> {
        let ctx = self.context();
        let dep = Arc::new(ShuffleDependency::<K, V, V>::new(
            &ctx,
            Arc::clone(&self.base),
            partitioner,
            None,
            false,
        ));
        Rdd::from_base(Arc::new(PartitionedRdd {
            id: ctx.new_rdd_id(),
            ctx,
            dep,
        }))
    }

    fn map_values<W: Data>(&self, f: impl Fn(V) -> W + Send + Sync + 'static) -> Rdd<(K, W)> {
        self.map(move |(k, v)| (k, f(v)))
    }

    fn keys(&self) -> Rdd<K> {
        self.map(|(k, _)| k)
    }

    fn values(&self) -> Rdd<V> {
        self.map(|(_, v)| v)
    }

    fn count_by_key(&self) -> std::collections::HashMap<K, usize> {
        let mut out = std::collections::HashMap::new();
        for (k, _) in self.collect() {
            *out.entry(k).or_insert(0) += 1;
        }
        out
    }

    fn collect_as_map(&self) -> std::collections::HashMap<K, V> {
        self.collect().into_iter().collect()
    }

    fn sort_by_key(&self) -> Rdd<(K, V)>
    where
        K: Ord,
    {
        // Sample keys, build range bounds, shuffle, sort per partition.
        let n = self.context().conf().shuffle_partitions.max(1);
        let sample: Vec<K> = self
            .context()
            .run_job(self, |_, items: Vec<(K, V)>| {
                items
                    .iter()
                    .step_by((items.len() / 20).max(1))
                    .map(|(k, _)| k.clone())
                    .collect::<Vec<K>>()
            })
            .into_iter()
            .flatten()
            .collect();
        let rp = Arc::new(RangePartitioner::from_sample(sample, n));
        self.partition_by(rp)
            .map_partitions(|_, mut items: Vec<(K, V)>| {
                items.sort_by(|a, b| a.0.cmp(&b.0));
                items
            })
    }

    fn join<W: Data + SerDe>(&self, other: &Rdd<(K, W)>) -> Rdd<(K, (V, W))> {
        self.cogroup(other).flat_map(|(k, (vs, ws))| {
            let mut out = Vec::with_capacity(vs.len() * ws.len());
            for v in &vs {
                for w in &ws {
                    out.push((k.clone(), (v.clone(), w.clone())));
                }
            }
            out
        })
    }

    fn aggregate_by_key<C: Data + SerDe>(
        &self,
        zero: C,
        seq_op: impl Fn(&mut C, V) + Send + Sync + 'static,
        comb_op: impl Fn(&mut C, C) + Send + Sync + 'static,
    ) -> Rdd<(K, C)> {
        let seq = Arc::new(seq_op);
        let seq2 = Arc::clone(&seq);
        let agg = Aggregator::new(
            move |v: V| {
                let mut c = zero.clone();
                seq(&mut c, v);
                c
            },
            move |c: &mut C, v: V| seq2(c, v),
            comb_op,
        );
        let n = self.context().conf().shuffle_partitions;
        self.combine_by_key(agg, Arc::new(HashPartitioner::new(n)), true)
    }

    fn fold_by_key(
        &self,
        zero: V,
        f: impl Fn(V, V) -> V + Send + Sync + 'static,
    ) -> Rdd<(K, V)> {
        let f = Arc::new(f);
        let f2 = Arc::clone(&f);
        self.aggregate_by_key(
            zero,
            move |c: &mut V, v: V| {
                let old = c.clone();
                *c = f(old, v);
            },
            move |c: &mut V, o: V| {
                let old = c.clone();
                *c = f2(old, o);
            },
        )
    }

    fn cogroup<W: Data + SerDe>(&self, other: &Rdd<(K, W)>) -> Rdd<(K, (Vec<V>, Vec<W>))> {
        // Tag sides, union, group once; split per key.
        let left = self.map_values(|v| (Some(v), None::<W>));
        let right = other.map_values(|w| (None::<V>, Some(w)));
        let both = left.union(&right);
        both.group_by_key().map(|(k, pairs)| {
            let mut vs = Vec::new();
            let mut ws = Vec::new();
            for (v, w) in pairs {
                if let Some(v) = v {
                    vs.push(v);
                }
                if let Some(w) = w {
                    ws.push(w);
                }
            }
            (k, (vs, ws))
        })
    }
}

/// Convenience: the paper's `defaultPartitioner(n)` — modulo over a dense
/// integer key space (equivalence-class prefix ranks).
pub fn default_partitioner(n: usize) -> Arc<FnPartitioner<usize>> {
    Arc::new(FnPartitioner::new(n.max(1), move |k: &usize| k % n.max(1)))
}
