//! Bench target: Fig. 5 — execution time vs executor cores:
//! (a) BMS_WebView_2 at min_sup = 0.001, (b) T40I10D100K at 0.01.

use rdd_eclat::coordinator::{experiments, report, ExperimentConfig};
use rdd_eclat::data::Dataset;

fn main() {
    let cfg = ExperimentConfig::default();
    let a = experiments::fig_cores(Dataset::Bms2, 0.001, &cfg);
    a.finish();
    let b = experiments::fig_cores(Dataset::T40I10D100K, 0.01, &cfg);
    b.finish();
    let checks = vec![
        report::check_core_scaling(&a),
        report::check_core_scaling(&b),
    ];
    println!("{}", report::render_claims(&checks));
}
