"""L2 graph correctness: model steps = kernel composition semantics."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import cooccurrence_ref, intersect_ref


def test_cooc_step_tuple_and_value():
    rng = np.random.default_rng(3)
    a = (rng.random((128, 512)) < 0.25).astype(np.float32)
    out = model.cooc_step(a)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_array_equal(
        np.asarray(out[0]), np.asarray(cooccurrence_ref(jnp.asarray(a)))
    )


def test_intersect_step_matches_ref():
    rng = np.random.default_rng(4)
    x = rng.integers(-(2**31), 2**31, size=(64, 256), dtype=np.int64).astype(
        np.int32
    )
    y = rng.integers(-(2**31), 2**31, size=(64, 256), dtype=np.int64).astype(
        np.int32
    )
    gi, gs = model.intersect_step(x, y)
    wi, ws = intersect_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


def test_intersect_minsup_mask():
    # Construct rows with known supports 0, 32, 64 and threshold at 32.
    x = np.zeros((64, 2), np.int32)
    x[1, 0] = -1  # 32 bits
    x[2, :] = -1  # 64 bits
    inter, sup, mask = model.intersect_minsup_step(x, x, np.int32(32))
    np.testing.assert_array_equal(np.asarray(inter), x)
    s = np.asarray(sup)
    m = np.asarray(mask)
    assert s[0] == 0 and m[0] == 0
    assert s[1] == 32 and m[1] == 1
    assert s[2] == 64 and m[2] == 1


def test_intersect_minsup_threshold_is_runtime_operand():
    x = np.full((64, 1), -1, np.int32)  # every row support = 32
    for thr, expect in [(0, 1), (32, 1), (33, 0)]:
        _, _, mask = model.intersect_minsup_step(x, x, np.int32(thr))
        assert int(np.asarray(mask)[0]) == expect
