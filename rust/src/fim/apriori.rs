//! RDD-Apriori — the YAFIM baseline (Qiu et al. [6]) the paper compares
//! against in Figs. 1(a)–4(a).
//!
//! Two-phase structure, faithful to YAFIM:
//!  * Phase-1: frequent items by word-count (`flatMap` → `reduceByKey`).
//!  * Phase-2 (iterated for k ≥ 2): the driver generates candidate
//!    k-itemsets from L_{k-1} (join + prune), broadcasts them in a
//!    prefix trie (YAFIM's hash tree), every partition counts subset
//!    occurrences locally, counts are combined with `reduceByKey`, and
//!    survivors form L_k.
//!
//! The per-iteration broadcast + full database re-scan is exactly the
//! cost the paper's Eclat variants avoid — the benches reproduce that
//! gap.

use crate::sparklet::{PairRdd, Rdd, SparkletContext};

use super::sequential::apriori_gen;
use super::trie::ItemTrie;
use super::types::{FrequentItemset, Item, MiningResult, Transaction};

/// Run RDD-Apriori (YAFIM) over a transactions RDD.
pub fn mine_apriori_rdd(
    sc: &SparkletContext,
    txns: &Rdd<Transaction>,
    min_sup: u32,
) -> MiningResult {
    let txns = txns.cache();

    // ---- Phase 1: L1
    let mut frequent: Vec<FrequentItemset> = txns
        .flat_map(|t| t)
        .map_to_pair(|item| (item, 1u32))
        .reduce_by_key(|a, b| a + b)
        .filter(move |(_, c)| *c >= min_sup)
        .collect()
        .into_iter()
        .map(|(item, c)| FrequentItemset::new(vec![item], c))
        .collect();
    let mut level: Vec<Vec<Item>> = frequent.iter().map(|f| f.items.clone()).collect();
    level.sort();

    // ---- Phase 2: iterate candidate generation + counting
    while !level.is_empty() {
        let candidates = apriori_gen(&level);
        if candidates.is_empty() {
            break;
        }
        let mut trie = ItemTrie::new();
        for c in &candidates {
            trie.insert(c);
        }
        let b_trie = sc.broadcast(trie);
        // Each partition counts candidates locally against its slice of
        // the database, then emits (itemset, count) pairs for the global
        // reduceByKey — the YAFIM map/reduce shape.
        let counted = txns
            .map_partitions(move |_, part_txns| {
                let mut local = b_trie.value().clone();
                for t in &part_txns {
                    local.count_subsets(t);
                }
                local
                    .counts()
                    .into_iter()
                    .filter(|(_, c)| *c > 0)
                    .collect::<Vec<(Vec<Item>, u32)>>()
            })
            .reduce_by_key(|a, b| a + b)
            .filter(move |(_, c)| *c >= min_sup);
        let mut next: Vec<Vec<Item>> = Vec::new();
        for (items, count) in counted.collect() {
            frequent.push(FrequentItemset::new(items.clone(), count));
            next.push(items);
        }
        next.sort();
        level = next;
    }
    MiningResult::new(frequent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::engine::MiningSession;
    use crate::fim::sequential::{apriori_sequential, eclat_sequential};

    /// Mine an in-memory database through the unified session API.
    fn mine_vec(sc: &SparkletContext, txns: Vec<Transaction>, min_sup: u32) -> MiningResult {
        MiningSession::new("apriori")
            .min_sup(min_sup)
            .run_vec(sc, &txns)
            .unwrap()
            .result
    }

    fn demo_db() -> Vec<Transaction> {
        vec![
            vec![1, 2, 5],
            vec![2, 4],
            vec![2, 3],
            vec![1, 2, 4],
            vec![1, 3],
            vec![2, 3],
            vec![1, 3],
            vec![1, 2, 3, 5],
            vec![1, 2, 3],
        ]
    }

    #[test]
    fn matches_sequential_apriori() {
        let sc = SparkletContext::local(4);
        for min_sup in [1u32, 2, 3, 5] {
            let got = mine_vec(&sc, demo_db(), min_sup);
            let want = apriori_sequential(&demo_db(), min_sup);
            assert!(got.same_as(&want), "min_sup={min_sup}");
        }
    }

    #[test]
    fn matches_eclat_oracle() {
        let sc = SparkletContext::local(2);
        let got = mine_vec(&sc, demo_db(), 2);
        assert!(got.same_as(&eclat_sequential(&demo_db(), 2)));
    }

    #[test]
    fn empty_db() {
        let sc = SparkletContext::local(2);
        assert!(mine_vec(&sc, Vec::new(), 1).is_empty());
    }

    #[test]
    fn partition_count_invariant() {
        // result must not depend on how the db is partitioned
        let base = apriori_sequential(&demo_db(), 2);
        for cores in [1usize, 2, 5] {
            let sc = SparkletContext::local(cores);
            let got = mine_vec(&sc, demo_db(), 2);
            assert!(got.same_as(&base), "cores={cores}");
        }
    }
}
