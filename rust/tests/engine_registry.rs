//! Registry-driven cross-engine agreement: **every** registered engine ×
//! both tidset representations × weighted/fixed partitioning must return
//! exactly the sequential oracle's itemsets on random databases.
//!
//! This subsumes the per-algorithm agreement checks: an engine added to
//! the `EngineRegistry` is automatically held to the oracle here with no
//! test changes — which is the point of registering engines once.

use std::sync::Arc;

use rdd_eclat::fim::engine::{
    EngineRegistry, FimEngine, FimError, MiningConfig, MiningSession, PartitionStrategy,
    PostStage, TidsetRepr,
};
use rdd_eclat::fim::sequential::eclat_sequential;
use rdd_eclat::fim::types::{MiningResult, Transaction};
use rdd_eclat::sparklet::{ExecutorRegistry, Rdd, SparkletConf, SparkletContext};
use rdd_eclat::util::prop::{forall, gen};

#[test]
fn registry_exposes_the_full_paper_family() {
    let names = EngineRegistry::names();
    for want in [
        "eclat-v1",
        "eclat-v2",
        "eclat-v3",
        "eclat-v4",
        "eclat-v5",
        "eclat-v6",
        "apriori",
        "fpgrowth",
        "sequential",
    ] {
        assert!(names.contains(&want), "registry missing {want}: {names:?}");
    }
}

#[test]
fn prop_full_registry_agrees_with_oracle_across_axes() {
    let sc = SparkletContext::local(2);
    forall(4, gen::database(20, 8, 0.35), |db| {
        let oracle = eclat_sequential(db, 2);
        for engine in EngineRegistry::names() {
            for repr in [
                TidsetRepr::Vec,
                TidsetRepr::Bitmap,
                TidsetRepr::Diffset,
                TidsetRepr::Hybrid,
                TidsetRepr::Auto,
            ] {
                for strategy in [PartitionStrategy::Weighted, PartitionStrategy::EngineDefault] {
                    let got = MiningSession::new(engine)
                        .min_sup(2)
                        .tidset(repr)
                        .partitioning(strategy)
                        .p(3)
                        .run_vec(&sc, db)
                        .unwrap();
                    if !got.result.same_as(&oracle) {
                        eprintln!(
                            "{engine} tidset={} partitioning={}: {} itemsets, want {}",
                            repr.name(),
                            strategy.name(),
                            got.result.len(),
                            oracle.len()
                        );
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_engines_agree_with_oracle_under_every_executor_backend() {
    // The executor axis joins the sweep: every registered engine ×
    // both tidset representations × every registered executor backend
    // must equal the sequential oracle. A backend registered later is
    // automatically held to the oracle here, mirroring how engines are.
    for backend in ExecutorRegistry::names() {
        let conf = SparkletConf::new("backend-sweep")
            .with_cores(2)
            .unwrap()
            .with_executor_backend(backend)
            .unwrap();
        let sc = SparkletContext::new(conf);
        forall(2, gen::database(16, 7, 0.35), |db| {
            let oracle = eclat_sequential(db, 2);
            for engine in EngineRegistry::names() {
                for repr in [
                    TidsetRepr::Vec,
                    TidsetRepr::Bitmap,
                    TidsetRepr::Diffset,
                    TidsetRepr::Hybrid,
                    TidsetRepr::Auto,
                ] {
                    let got = MiningSession::new(engine)
                        .min_sup(2)
                        .tidset(repr)
                        .p(3)
                        .run_vec(&sc, db)
                        .unwrap();
                    if !got.result.same_as(&oracle) {
                        eprintln!(
                            "{engine} tidset={} backend={backend}: {} itemsets, want {}",
                            repr.name(),
                            got.result.len(),
                            oracle.len()
                        );
                        return false;
                    }
                }
            }
            true
        });
    }
}

#[test]
fn auto_tidset_is_exact_for_every_engine() {
    let sc = SparkletContext::local(2);
    forall(4, gen::database(18, 7, 0.4), |db| {
        let oracle = eclat_sequential(db, 2);
        EngineRegistry::names().into_iter().all(|engine| {
            MiningSession::new(engine)
                .min_sup(2)
                .tidset(TidsetRepr::Auto)
                .run_vec(&sc, db)
                .unwrap()
                .result
                .same_as(&oracle)
        })
    });
}

#[test]
fn newly_registered_engine_joins_the_agreement_sweep() {
    // A "new backend" registered in one line: it must immediately be
    // addressable and held to the oracle by the same sweep loop.
    struct OracleBackend;
    impl FimEngine for OracleBackend {
        fn name(&self) -> &'static str {
            "test-oracle-backend"
        }
        fn mine(
            &self,
            _sc: &SparkletContext,
            txns: &Rdd<Transaction>,
            cfg: &MiningConfig,
        ) -> Result<MiningResult, FimError> {
            Ok(eclat_sequential(&txns.collect(), cfg.min_sup))
        }
    }
    EngineRegistry::register(Arc::new(OracleBackend));
    assert!(EngineRegistry::names().contains(&"test-oracle-backend"));
    let sc = SparkletContext::local(2);
    let db: Vec<Transaction> = vec![vec![1, 2], vec![1, 2, 3], vec![2, 3], vec![1, 3]];
    for engine in EngineRegistry::names() {
        let got = MiningSession::new(engine)
            .min_sup(2)
            .run_vec(&sc, &db)
            .unwrap();
        assert!(
            got.result.same_as(&eclat_sequential(&db, 2)),
            "{engine} disagrees after registration"
        );
    }
}

#[test]
fn kernel_counters_populate_reports_per_repr() {
    // Every representation reports kernel work; the adaptive ones can
    // additionally report representation switches on a dense database.
    let sc = SparkletContext::local(2);
    let db: Vec<Transaction> = (0..12u32)
        .map(|i| {
            let mut t = vec![1, 2, 3, 4, 5];
            t.push(6 + i % 3);
            t
        })
        .collect();
    for repr in [
        TidsetRepr::Vec,
        TidsetRepr::Bitmap,
        TidsetRepr::Diffset,
        TidsetRepr::Hybrid,
    ] {
        let report = MiningSession::new("eclat-v3")
            .min_sup(2)
            .tidset(repr)
            .run_vec(&sc, &db)
            .unwrap();
        assert!(
            report.kernel.intersections > 0,
            "{}: {:?}",
            repr.name(),
            report.kernel
        );
        assert!(report.result.same_as(&eclat_sequential(&db, 2)), "{}", repr.name());
    }
}

#[test]
fn post_stages_compose_on_any_engine() {
    let sc = SparkletContext::local(2);
    let db: Vec<Transaction> = vec![
        vec![1, 2, 3],
        vec![1, 2, 3],
        vec![1, 2],
        vec![2, 3],
        vec![1, 3],
    ];
    for engine in ["eclat-v4", "apriori", "fpgrowth"] {
        let full = MiningSession::new(engine)
            .min_sup(2)
            .run_vec(&sc, &db)
            .unwrap()
            .result;
        let maximal = MiningSession::new(engine)
            .min_sup(2)
            .post(PostStage::Maximal)
            .run_vec(&sc, &db)
            .unwrap()
            .result;
        assert!(maximal.len() <= full.len(), "{engine}");
        let top2 = MiningSession::new(engine)
            .min_sup(2)
            .post(PostStage::TopK(2))
            .run_vec(&sc, &db)
            .unwrap()
            .result;
        assert_eq!(top2.len(), 2, "{engine}");
    }
}

#[test]
fn unknown_engine_fails_with_suggestion_not_defaults() {
    let sc = SparkletContext::local(2);
    let err = MiningSession::new("eclat_v44")
        .min_sup(2)
        .run_vec(&sc, &[vec![1, 2]])
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("unknown engine"), "{msg}");
    assert!(msg.contains("did you mean"), "{msg}");
}
