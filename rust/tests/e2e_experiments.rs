//! Small-scale smoke of the experiment drivers: every figure driver runs
//! end-to-end and the paper's qualitative claims hold at reduced scale.
//! (The full-scale regeneration is `cargo bench`; see EXPERIMENTS.md.)

use rdd_eclat::coordinator::{experiments, report, ExperimentConfig};
use rdd_eclat::data::Dataset;

fn tiny() -> ExperimentConfig {
    // keep the whole file < ~2 min on one core
    ExperimentConfig {
        seed: 2019,
        scale: 0.03,
        cores: 2,
        p: 6,
    }
}

fn one_rep() {
    std::env::set_var("REPRO_BENCH_REPS", "1");
    std::env::set_var("REPRO_BENCH_WARMUP", "0");
}

#[test]
fn fig3_t10_claims_hold_at_small_scale() {
    one_rep();
    let suite = experiments::fig_minsup(3, Dataset::T10I4D100K, true, &tiny());
    let c1 = report::check_eclat_beats_apriori(&suite);
    assert!(c1.holds, "{}: {}", c1.claim, c1.detail);
    // the gap-widens and V4/V5 claims are asserted at full scale in the
    // benches; here we only require Eclat's win, which is scale-stable.
}

#[test]
fn fig1_bms1_driver_runs() {
    one_rep();
    let suite = experiments::fig_minsup(1, Dataset::Bms1, false, &tiny());
    // all 5 variants at 5 sweep points
    assert_eq!(suite.measurements().len(), 25);
}

#[test]
fn fig5_core_model_monotone() {
    one_rep();
    let suite = experiments::fig_cores(Dataset::Bms2, 0.002, &tiny());
    let check = report::check_core_scaling(&suite);
    assert!(check.holds, "{}", check.detail);
    // modeled makespans must be non-increasing in cores for each variant
    for v in ["EclatV1", "EclatV4"] {
        let m2 = suite.median(v, 2.0).unwrap();
        let m10 = suite.median(v, 10.0).unwrap();
        assert!(m10 <= m2 * 1.05, "{v}: {m2:.1} -> {m10:.1}");
    }
}

#[test]
fn fig6_scaling_linear() {
    one_rep();
    let cfg = ExperimentConfig {
        scale: 0.02,
        ..tiny()
    };
    let suite = experiments::fig_scaling(&cfg);
    let check = report::check_linear_scaling(&suite);
    assert!(check.holds, "{}", check.detail);
}

#[test]
fn table1_scales_with_config() {
    let t = experiments::table1(&tiny());
    assert!(t.contains("BMS_WebView_1"));
    assert!(t.contains("T40I10D100K"));
}
