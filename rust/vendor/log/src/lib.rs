//! Offline shim for the `log` crate: the five level macros, no logger
//! registry. `error!`/`warn!` go to stderr (task failures must be
//! visible); `info!`/`debug!`/`trace!` compile their arguments but emit
//! nothing. Swap for the real crate in `rust/Cargo.toml` if a full
//! logging facade is ever needed.

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        eprintln!("[ERROR] {}", format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        eprintln!("[WARN] {}", format!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if false {
            eprintln!("[INFO] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if false {
            eprintln!("[DEBUG] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        if false {
            eprintln!("[TRACE] {}", format!($($arg)*));
        }
    };
}
