//! FxHash-style fast hashing (the std SipHash is measurably slow in the
//! shuffle hot loop; FxHash is the rustc-internal multiply-xor hash).

use std::hash::{BuildHasherDefault, Hash, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The rustc FxHasher: word-at-a-time multiply-rotate.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

/// Hash a single value with FxHash (used by hash partitioners).
#[inline]
pub fn fx_hash<T: Hash>(value: &T) -> u64 {
    let mut h = FxHasher::default();
    value.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(fx_hash(&"hello"), fx_hash(&"hello"));
        assert_eq!(fx_hash(&12345u64), fx_hash(&12345u64));
    }

    #[test]
    fn distinguishes_values() {
        assert_ne!(fx_hash(&1u32), fx_hash(&2u32));
        assert_ne!(fx_hash(&"a"), fx_hash(&"b"));
    }

    #[test]
    fn map_works() {
        let mut m: FxHashMap<String, u32> = FxHashMap::default();
        m.insert("x".into(), 1);
        m.insert("y".into(), 2);
        assert_eq!(m["x"], 1);
        assert_eq!(m["y"], 2);
    }

    #[test]
    fn spreads_small_ints() {
        // partition-id quality check: consecutive ints should not all
        // collide mod small p.
        let p = 10;
        let mut buckets = vec![0usize; p];
        for i in 0..1000u32 {
            buckets[(fx_hash(&i) % p as u64) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap();
        let min = *buckets.iter().min().unwrap();
        assert!(max < 3 * min.max(1), "skewed buckets: {buckets:?}");
    }
}
