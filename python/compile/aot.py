"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the crate-side
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifacts are compiled for fixed shapes; the rust coordinator tiles/pads
its bitmaps to match. Emitted set (plus ``manifest.txt``):

  cooc_{I}x{K}.hlo.txt              cooc_step          f32[I,K] -> f32[I,I]
  intersect_{R}x{W}.hlo.txt         intersect_step     2x i32[R,W] -> (i32[R,W], i32[R])
  intersect_minsup_{R}x{W}.hlo.txt  intersect_minsup_step (+ scalar i32 min_sup)
  model.hlo.txt                     alias of the default intersect artifact
                                    (the Makefile's staleness stamp)

Usage: ``cd python && python -m compile.aot --out ../artifacts/model.hlo.txt``
"""

import argparse
import os
import shutil

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (items, txn_chunk) shapes for the co-occurrence artifact.
COOC_SHAPES = [(256, 2048), (128, 512)]
# (rows, words) shapes for the intersection artifacts.
INTERSECT_SHAPES = [(256, 1024), (64, 256)]
DEFAULT_MODEL = "intersect_256x1024.hlo.txt"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cooc(items: int, chunk: int) -> str:
    spec = jax.ShapeDtypeStruct((items, chunk), jnp.float32)
    return to_hlo_text(jax.jit(model.cooc_step).lower(spec))


def lower_cooc_pair(items: int, chunk: int) -> str:
    spec = jax.ShapeDtypeStruct((items, chunk), jnp.float32)
    return to_hlo_text(jax.jit(model.cooc_pair_step).lower(spec, spec))


def lower_intersect(rows: int, words: int) -> str:
    spec = jax.ShapeDtypeStruct((rows, words), jnp.int32)
    return to_hlo_text(jax.jit(model.intersect_step).lower(spec, spec))


def lower_intersect_minsup(rows: int, words: int) -> str:
    spec = jax.ShapeDtypeStruct((rows, words), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return to_hlo_text(
        jax.jit(model.intersect_minsup_step).lower(spec, spec, scalar)
    )


def emit_all(outdir: str) -> list[str]:
    os.makedirs(outdir, exist_ok=True)
    written = []

    def write(name: str, text: str):
        path = os.path.join(outdir, name)
        with open(path, "w") as f:
            f.write(text)
        written.append(name)
        print(f"  {name}: {len(text)} chars")

    for items, chunk in COOC_SHAPES:
        write(f"cooc_{items}x{chunk}.hlo.txt", lower_cooc(items, chunk))
        write(f"cooc_pair_{items}x{chunk}.hlo.txt", lower_cooc_pair(items, chunk))
    for rows, words in INTERSECT_SHAPES:
        write(f"intersect_{rows}x{words}.hlo.txt", lower_intersect(rows, words))
        write(
            f"intersect_minsup_{rows}x{words}.hlo.txt",
            lower_intersect_minsup(rows, words),
        )

    shutil.copyfile(
        os.path.join(outdir, DEFAULT_MODEL), os.path.join(outdir, "model.hlo.txt")
    )
    written.append("model.hlo.txt")
    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(written) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts/model.hlo.txt",
        help="path of the stamp artifact; all artifacts go to its directory",
    )
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    print(f"emitting HLO artifacts to {outdir}")
    written = emit_all(outdir)
    print(f"wrote {len(written)} artifacts")


if __name__ == "__main__":
    main()
