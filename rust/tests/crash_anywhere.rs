//! "Crash anywhere, answer identical": the fault plane's payoff suite.
//!
//! Over 100+ seeded fault schedules spanning every injection site —
//! spill write/read in the block store, frame write/read/corrupt on the
//! worker transport, task panics in the scheduler, worker kill and
//! heartbeat stall in the remote executor — a mine must either return a
//! result identical to the sequential oracle or fail with a typed
//! [`FimError`]. Never a wrong answer, never a hang, never a leaked
//! shuffle byte or orphaned spill file.
//!
//! Schedules are composed from the plan grammar per seed, so a failing
//! seed prints its exact `--fault-plan` spec and replays bit-for-bit
//! from the CLI.

use std::sync::Arc;

use rdd_eclat::fim::engine::{FimError, MiningSession, TidsetRepr};
use rdd_eclat::fim::sequential::eclat_sequential;
use rdd_eclat::fim::types::Transaction;
use rdd_eclat::sparklet::events::{CollectingListener, SparkletEvent};
use rdd_eclat::sparklet::{FaultSite, SparkletConf, SparkletContext, THREAD_WORKERS};
use rdd_eclat::util::prop::gen;
use rdd_eclat::util::rng::SplitMix64;

const ENGINES: [&str; 8] = [
    "eclat-v1", "eclat-v2", "eclat-v3", "eclat-v4", "eclat-v5", "eclat-v6", "apriori", "fpgrowth",
];
const REPRS: [TidsetRepr; 5] = [
    TidsetRepr::Vec,
    TidsetRepr::Bitmap,
    TidsetRepr::Diffset,
    TidsetRepr::Hybrid,
    TidsetRepr::Auto,
];

/// A seed-deterministic transaction database.
fn db_for(seed: u64) -> Vec<Transaction> {
    let mut rng = SplitMix64::new(seed ^ 0xC0FF_EE00_D15E_A5ED);
    gen::database(24, 8, 0.4)(&mut rng)
}

/// The per-run outcome dichotomy: identical to the oracle, or a typed
/// execution error. Anything else — a divergent answer, a non-execution
/// error from a fault schedule — fails the property.
fn assert_oracle_or_typed(
    seed: u64,
    spec: &str,
    engine: &str,
    got: Result<rdd_eclat::fim::engine::MiningReport, FimError>,
    oracle: &rdd_eclat::fim::types::MiningResult,
) -> bool {
    match got {
        Ok(report) => {
            assert!(
                report.result.same_as(oracle),
                "seed {seed} ({engine}, plan {spec:?}): survived the fault schedule \
                 with a WRONG answer ({} itemsets, oracle has {})",
                report.result.len(),
                oracle.len()
            );
            true
        }
        Err(FimError::Execution { reason }) => {
            assert!(
                !reason.is_empty(),
                "seed {seed}: typed failure with an empty reason"
            );
            false
        }
        Err(other) => panic!("seed {seed} (plan {spec:?}): non-execution error: {other}"),
    }
}

/// No leaked shuffle state after teardown: a faulted run may abandon
/// blocks mid-stage, but `reset_state` must reclaim every byte and
/// delete every spill file.
fn assert_no_leaks(seed: u64, sc: &SparkletContext) {
    sc.reset_state();
    assert_eq!(
        sc.shuffle_manager().used_bytes(),
        0,
        "seed {seed}: leaked shuffle bytes after reset"
    );
    assert_eq!(
        sc.shuffle_manager().spill_file_count(),
        0,
        "seed {seed}: orphaned spill files after reset"
    );
}

/// Compose a 1–3 clause schedule from the local-path site menu. The
/// menu mixes triggers that recover under retry (nth, low p) with ones
/// that exhaust it (always), so the sweep exercises both arms of the
/// dichotomy.
fn local_spec(seed: u64) -> String {
    const MENU: [&str; 12] = [
        "spill_write:always",
        "spill_write:nth=1",
        "spill_write:p=0.5",
        "spill_read:nth=1",
        "spill_read:every=3",
        "spill_read:p=0.2",
        "spill_read:always",
        "task_panic:nth=1",
        "task_panic:nth=2",
        "task_panic:every=4",
        "task_panic:p=0.15",
        "task_panic:always",
    ];
    let mut rng = SplitMix64::new(seed);
    let n = 1 + rng.gen_range(3);
    let mut clauses = vec![format!("seed={seed}")];
    for _ in 0..n {
        clauses.push(MENU[rng.gen_range(MENU.len())].to_string());
    }
    clauses.join("; ")
}

#[test]
fn prop_crash_anywhere_local_100_seeded_schedules() {
    let mut oks = 0usize;
    let mut typed_failures = 0usize;
    let mut fired_spill = 0u64;
    let mut fired_panic = 0u64;
    for seed in 0..100u64 {
        let db = db_for(seed);
        let oracle = eclat_sequential(&db, 2);
        let spec = local_spec(seed);
        // A 512-byte budget forces constant spill traffic, so the
        // spill_write/spill_read sites actually arm.
        let mut conf = SparkletConf::new(&format!("crash-local-{seed}"))
            .with_cores(2)
            .unwrap()
            .with_memory_budget_bytes(512)
            .unwrap()
            .with_fault_plan(&spec)
            .unwrap();
        conf.retry_backoff_ms = 0; // keep the 100-run sweep fast
        let sc = SparkletContext::new(conf);
        let engine = ENGINES[(seed as usize) % ENGINES.len()];
        let repr = REPRS[(seed as usize) % REPRS.len()];
        let got = MiningSession::new(engine)
            .min_sup(2)
            .tidset(repr)
            .p(3)
            .run_vec(&sc, &db);
        if assert_oracle_or_typed(seed, &spec, engine, got, &oracle) {
            oks += 1;
        } else {
            typed_failures += 1;
        }
        fired_spill += sc.faults().injected(FaultSite::SpillWrite)
            + sc.faults().injected(FaultSite::SpillRead);
        fired_panic += sc.faults().injected(FaultSite::TaskPanic);
        assert_no_leaks(seed, &sc);
    }
    // The sweep proves nothing unless both outcomes and the targeted
    // sites actually occurred.
    assert!(oks > 0, "no schedule ever recovered to the oracle answer");
    assert!(
        typed_failures > 0,
        "no schedule ever exhausted retries into a typed failure"
    );
    assert!(fired_panic > 0, "task_panic never fired across 100 schedules");
    assert!(
        fired_spill > 0,
        "spill faults never fired across 100 schedules — is the budget arming spills?"
    );
}

/// Thread-mode multi-process conf (workers are in-process threads over
/// a real unix socket), with a fault plan attached.
fn mp_conf(app: &str, spec: &str) -> SparkletConf {
    rdd_eclat::sparklet::remote::register_backend();
    rdd_eclat::fim::distributed::register_tasks();
    let mut conf = SparkletConf::new(app)
        .with_workers(2)
        .unwrap()
        .with_worker_binary(THREAD_WORKERS)
        .with_worker_timeouts(50, 2_000)
        .with_executor_backend("multi-process")
        .unwrap()
        .with_fault_plan(spec)
        .unwrap();
    conf.retry_backoff_ms = 0;
    conf
}

#[test]
fn prop_crash_anywhere_multiprocess_transport_and_worker_faults() {
    // Deterministic schedules over the remote-path sites. frame_read
    // sticks to nth triggers: a probabilistic clause could fail BOTH
    // workers' registration reads, and a worker that never registers is
    // not counted dead (there is nothing to recover), which would park
    // the job forever — a hang, which this suite exists to forbid.
    let schedules: [&str; 13] = [
        "seed=0; worker_kill=w0:1",
        "seed=1; worker_kill=w1:2",
        "seed=2; frame_write:nth=2",
        "seed=3; frame_write:every=3",
        "seed=4; frame_read:nth=2",
        "seed=5; frame_read:nth=4",
        "seed=6; frame_corrupt:nth=1",
        "seed=7; frame_corrupt:every=2",
        "seed=8; task_panic:nth=1",
        "seed=9; task_panic:always",
        "seed=10; worker_kill=w0:1; frame_write:nth=3",
        "seed=11; worker_kill=w0:1; worker_kill=w1:1",
        "seed=12; heartbeat_stall=w0:1", // lost via the watchdog, not EOF
    ];
    let db = db_for(7);
    let oracle = eclat_sequential(&db, 2);
    let mut oks = 0usize;
    let mut typed_failures = 0usize;
    let mut fired_frames = 0u64;
    for (i, spec) in schedules.iter().enumerate() {
        let seed = i as u64;
        let sc = SparkletContext::new(mp_conf(&format!("crash-mp-{seed}"), spec));
        assert_eq!(sc.executor().name(), "multi-process");
        let got = MiningSession::new("eclat-v3")
            .min_sup(2)
            .p(3)
            .run_vec(&sc, &db);
        if assert_oracle_or_typed(seed, spec, "eclat-v3", got, &oracle) {
            oks += 1;
        } else {
            typed_failures += 1;
        }
        // Driver-side frame counters only: worker threads arm their own
        // plane instances parsed from the shipped plan string.
        fired_frames += sc.faults().injected(FaultSite::FrameWrite)
            + sc.faults().injected(FaultSite::FrameRead)
            + sc.faults().injected(FaultSite::FrameCorrupt);
        assert_no_leaks(seed, &sc);
        drop(sc); // join worker threads before the next schedule
    }
    assert!(oks > 0, "no multi-process schedule recovered to the oracle");
    assert!(
        typed_failures > 0,
        "no multi-process schedule failed typed (worker_kill=w0+w1 at least must)"
    );
    assert!(
        fired_frames > 0,
        "no driver-side frame fault ever fired across the schedules"
    );
}

#[test]
fn plan_grammar_worker_kill_is_as_deterministic_as_the_legacy_knob() {
    // The legacy `with_worker_fault("w0:1")` contract, re-expressed
    // through the plan grammar: w0 dies exactly once, the in-flight
    // task re-runs from lineage on the survivor, and the answer is
    // byte-identical to the oracle.
    let db = db_for(42);
    let oracle = eclat_sequential(&db, 2);
    let sc = SparkletContext::new(mp_conf("crash-kill-det", "worker_kill=w0:1"));
    let sink = CollectingListener::new();
    sc.events().register(Arc::new(sink.clone()));

    let got = MiningSession::new("eclat-v3")
        .min_sup(2)
        .p(3)
        .run_vec(&sc, &db)
        .expect("a single worker kill must recover via lineage");
    assert!(got.result.same_as(&oracle), "post-kill result diverged");

    let lost: Vec<String> = sink
        .snapshot()
        .into_iter()
        .filter_map(|(_, ev)| match ev {
            SparkletEvent::WorkerLost { worker, .. } => Some(worker),
            _ => None,
        })
        .collect();
    assert_eq!(lost, vec!["w0".to_string()], "w0 should die exactly once");
    assert!(
        sc.metrics().total_retries() > 0,
        "the killed worker's task should have retried"
    );
}

#[test]
fn spill_write_failure_degrades_but_answers_identically() {
    // A disk that refuses every spill write leaves blocks resident
    // (budget overrun, not data loss): the mine must still equal the
    // oracle, and the site counter must prove the fault actually fired.
    let db = db_for(3);
    let oracle = eclat_sequential(&db, 2);
    let conf = SparkletConf::new("crash-spill-write")
        .with_cores(2)
        .unwrap()
        .with_memory_budget_bytes(512)
        .unwrap()
        .with_fault_plan("spill_write:always")
        .unwrap();
    let sc = SparkletContext::new(conf);
    let got = MiningSession::new("eclat-v2")
        .min_sup(2)
        .p(3)
        .run_vec(&sc, &db)
        .expect("failed spills degrade memory accounting, never the answer");
    assert!(got.result.same_as(&oracle));
    assert!(
        sc.faults().injected(FaultSite::SpillWrite) > 0,
        "the tiny budget never attempted a spill — the test proved nothing"
    );
    assert_no_leaks(3, &sc);
}

#[test]
fn spill_read_failure_recovers_once_and_exhausts_when_persistent() {
    let db = db_for(4);
    let oracle = eclat_sequential(&db, 2);
    // One failed reload: the spill file is intact (injection happens
    // before I/O), so the task retry re-fetches and recovers.
    let mut conf = SparkletConf::new("crash-spill-read-once")
        .with_cores(2)
        .unwrap()
        .with_memory_budget_bytes(512)
        .unwrap()
        .with_fault_plan("spill_read:nth=1")
        .unwrap();
    conf.retry_backoff_ms = 0;
    let sc = SparkletContext::new(conf);
    let got = MiningSession::new("eclat-v3")
        .min_sup(2)
        .p(3)
        .run_vec(&sc, &db)
        .expect("a single spill-read fault must recover under retry");
    assert!(got.result.same_as(&oracle));
    if sc.faults().injected(FaultSite::SpillRead) > 0 {
        assert!(sc.metrics().total_retries() > 0, "recovery implies a retry");
    }
    assert_no_leaks(4, &sc);

    // An unreadable disk forever: retries exhaust into a typed error
    // whose display names the policy, not a panic or a wrong answer.
    let mut conf = SparkletConf::new("crash-spill-read-always")
        .with_cores(2)
        .unwrap()
        .with_memory_budget_bytes(512)
        .unwrap()
        .with_fault_plan("spill_read:always")
        .unwrap();
    conf.retry_backoff_ms = 0;
    let sc = SparkletContext::new(conf);
    let got = MiningSession::new("eclat-v3")
        .min_sup(2)
        .p(3)
        .run_vec(&sc, &db);
    match got {
        Err(FimError::Execution { reason }) => {
            assert!(
                sc.faults().injected(FaultSite::SpillRead) > 0,
                "typed failure without any injected fault"
            );
            assert!(
                reason.contains("retries exhausted"),
                "want the unified retry policy's display, got: {reason}"
            );
        }
        Ok(report) => {
            // Nothing spilled on this run's layout — legal only if the
            // site never armed AND the answer is exact.
            assert_eq!(sc.faults().injected(FaultSite::SpillRead), 0);
            assert!(report.result.same_as(&oracle));
        }
        Err(other) => panic!("non-execution error: {other}"),
    }
    assert_no_leaks(4, &sc);
}

#[test]
fn task_panic_exhaustion_and_job_deadline_are_typed() {
    let db = db_for(5);
    // Every attempt panics: the retry policy exhausts and the session
    // boundary re-types the panic into FimError::Execution.
    let mut conf = SparkletConf::new("crash-panic-always")
        .with_cores(2)
        .unwrap()
        .with_fault_plan("task_panic:always")
        .unwrap();
    conf.retry_backoff_ms = 0;
    let sc = SparkletContext::new(conf);
    let err = MiningSession::new("eclat-v1")
        .min_sup(2)
        .p(3)
        .run_vec(&sc, &db)
        .expect_err("a task that always panics cannot produce a result");
    let msg = err.to_string();
    assert!(msg.contains("mining failed"), "{msg}");
    assert!(msg.contains("retries exhausted"), "{msg}");
    assert!(sc.faults().injected(FaultSite::TaskPanic) > 0);
    assert_no_leaks(5, &sc);

    // Same schedule under a 1 ms job deadline with real backoff: the
    // deadline check between attempts fires before exhaustion can.
    let conf = SparkletConf::new("crash-deadline")
        .with_cores(2)
        .unwrap()
        .with_fault_plan("task_panic:always")
        .unwrap()
        .with_job_deadline_ms(1)
        .unwrap();
    let sc = SparkletContext::new(conf); // default 10 ms backoff
    let err = MiningSession::new("eclat-v1")
        .min_sup(2)
        .p(3)
        .run_vec(&sc, &db)
        .expect_err("a 1 ms budget cannot absorb panicking attempts");
    let msg = err.to_string();
    assert!(
        msg.contains("deadline exceeded") || msg.contains("retries exhausted"),
        "want a typed policy error, got: {msg}"
    );
    assert_no_leaks(5, &sc);
}

#[test]
fn fault_schedules_replay_identically_for_the_same_seed() {
    // The whole point of seeding: one seed, one schedule, one outcome —
    // run twice, the injection counters and the answer both repeat.
    let db = db_for(6);
    let run = |app: &str| {
        let mut conf = SparkletConf::new(app)
            .with_cores(2)
            .unwrap()
            .with_memory_budget_bytes(512)
            .unwrap()
            .with_fault_plan("seed=9; spill_read:p=0.3; task_panic:p=0.1")
            .unwrap();
        conf.retry_backoff_ms = 0;
        let sc = SparkletContext::new(conf);
        let got = MiningSession::new("eclat-v4")
            .min_sup(2)
            .p(3)
            .run_vec(&sc, &db)
            .map(|r| r.result)
            .map_err(|e| e.to_string());
        let counters: Vec<u64> = FaultSite::ALL
            .iter()
            .map(|&s| sc.faults().injected(s))
            .collect();
        (got, counters)
    };
    let (a, ca) = run("crash-replay-a");
    let (b, cb) = run("crash-replay-b");
    match (&a, &b) {
        (Ok(ra), Ok(rb)) => assert!(ra.same_as(rb), "same seed, different answers"),
        (Err(ea), Err(eb)) => assert_eq!(ea, eb, "same seed, different typed errors"),
        _ => panic!("same seed, different outcome kinds: {a:?} vs {b:?}"),
    }
    assert_eq!(ca, cb, "same seed, different injection schedules");
}
