//! Engine configuration — the `SparkConf` analog.
//!
//! Builders validate instead of `assert!`ing: a bad value (zero cores,
//! unknown executor backend, garbage in a `SPARKLET_*` env var) comes
//! back as a typed [`ConfError`] the caller can surface, not a process
//! abort.

use super::executor::{ExecutorError, ExecutorRegistry};
use super::faults::FaultPlan;

/// Typed configuration errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfError {
    /// `executor_cores` must be >= 1.
    InvalidCores { value: String },
    /// `shuffle_partitions` must be >= 1.
    InvalidShufflePartitions { value: String },
    /// `memory_budget` must be >= 1 (use `None` for unlimited).
    InvalidMemoryBudget { value: String },
    /// The named executor backend is not in the `ExecutorRegistry`
    /// (the registry's own error, with its did-you-mean suggestion).
    Backend(ExecutorError),
    /// A `SPARKLET_*` environment override did not parse.
    InvalidEnv {
        var: &'static str,
        value: String,
        reason: String,
    },
    /// The `event_log` path could not be opened for appending.
    EventLog { path: String, reason: String },
    /// `multiprocess_workers` must be >= 1.
    InvalidWorkers { value: String },
    /// The executor backend failed to start its runtime services (for
    /// the multi-process backend: socket bind or worker spawn failed).
    BackendAttach { backend: String, reason: String },
    /// `serve_queue_depth` must be >= 1.
    InvalidQueueDepth { value: String },
    /// `serve_tenant_rate` must be finite and >= 0 (0 disables shedding).
    InvalidTenantRate { value: String },
    /// `serve_cache_budget` must be >= 1 (use `None` for unlimited).
    InvalidCacheBudget { value: String },
    /// `event_log_max_bytes` must be >= 1 (use `None` for uncapped).
    InvalidEventLogCap { value: String },
    /// The fault-plan spec did not parse against the
    /// [`FaultPlan`](super::faults::FaultPlan) grammar.
    InvalidFaultPlan { value: String, reason: String },
    /// A deadline must be >= 1 ms (use `None` for unbounded).
    InvalidDeadline {
        what: &'static str,
        value: String,
    },
}

impl From<ExecutorError> for ConfError {
    fn from(e: ExecutorError) -> Self {
        Self::Backend(e)
    }
}

impl std::fmt::Display for ConfError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::InvalidCores { value } => {
                write!(f, "executor_cores must be >= 1 (got {value})")
            }
            Self::InvalidShufflePartitions { value } => {
                write!(f, "shuffle_partitions must be >= 1 (got {value})")
            }
            Self::InvalidMemoryBudget { value } => {
                write!(f, "memory budget must be >= 1 MiB (got {value})")
            }
            Self::Backend(e) => e.fmt(f),
            Self::InvalidEnv { var, value, reason } => {
                write!(f, "invalid {var}={value:?}: {reason}")
            }
            Self::EventLog { path, reason } => {
                write!(f, "cannot open event log {path:?}: {reason}")
            }
            Self::InvalidWorkers { value } => {
                write!(f, "multiprocess_workers must be >= 1 (got {value})")
            }
            Self::BackendAttach { backend, reason } => {
                write!(f, "executor backend {backend:?} failed to start: {reason}")
            }
            Self::InvalidQueueDepth { value } => {
                write!(f, "serve_queue_depth must be >= 1 (got {value})")
            }
            Self::InvalidTenantRate { value } => {
                write!(f, "serve_tenant_rate must be finite and >= 0 (got {value})")
            }
            Self::InvalidCacheBudget { value } => {
                write!(f, "serve cache budget must be >= 1 MiB (got {value})")
            }
            Self::InvalidEventLogCap { value } => {
                write!(f, "event log size cap must be >= 1 MiB (got {value})")
            }
            Self::InvalidFaultPlan { value, reason } => {
                write!(f, "invalid fault plan {value:?}: {reason}")
            }
            Self::InvalidDeadline { what, value } => {
                write!(f, "{what} must be >= 1 ms (got {value})")
            }
        }
    }
}

impl std::error::Error for ConfError {}

/// Configuration for a [`super::SparkletContext`].
#[derive(Debug, Clone)]
pub struct SparkletConf {
    /// Application name (metrics / logs).
    pub app_name: String,
    /// Worker threads in the executor pool — `spark.executor.cores`.
    /// Also the default parallelism for `parallelize` and shuffles.
    pub executor_cores: usize,
    /// Executor backend name, resolved against the `ExecutorRegistry`
    /// when the context is built (`fifo` | `work-stealing` |
    /// `sequential`, plus anything registered later).
    pub executor_backend: String,
    /// Default number of shuffle partitions (when a partitioner is not
    /// given explicitly). `spark.sql.shuffle.partitions` analog.
    pub shuffle_partitions: usize,
    /// Max attempts per task before the job fails (`spark.task.maxFailures`).
    pub max_task_failures: usize,
    /// Fault injection: probability a task panics on its first attempt.
    /// 0.0 disables. Deterministic per (stage, partition) given the seed.
    pub task_failure_rate: f64,
    /// Seed for failure injection.
    pub failure_seed: u64,
    /// Capture per-stage metrics (cheap; on by default).
    pub collect_metrics: bool,
    /// In-memory shuffle block budget in **bytes** (`None` = unlimited).
    /// When the resident serialized blocks exceed it, the coldest are
    /// LRU-spilled to temp files and reloaded transparently on fetch.
    /// Set via [`SparkletConf::with_memory_budget_mb`], the
    /// `SPARKLET_MEMORY_MB` env override, or the CLI `--memory-budget`.
    pub memory_budget: Option<usize>,
    /// Persist the structured event stream ([`super::events`]) as JSONL
    /// to this path. The file is opened in **append** mode when the
    /// context is built (so the contexts of a bench sweep share one
    /// log); CLI handlers truncate it once per invocation. `None`
    /// disables persistence — the in-process [`super::EventBus`] runs
    /// either way.
    pub event_log: Option<String>,
    /// Shared-nothing assertion mode: the shuffle verifies every block
    /// handed to a reduce task is an exclusively-owned byte buffer (no
    /// `Arc`-shared payload crosses a stage boundary) and every written
    /// block reconstructs from its bytes alone. Defaults to on in debug
    /// builds; `SPARKLET_SHARED_NOTHING=0|1` overrides.
    pub shared_nothing: bool,
    /// Worker processes for the `multi-process` executor backend
    /// (`SPARKLET_WORKERS`). Ignored by in-process backends.
    pub multiprocess_workers: usize,
    /// Directory for the driver's Unix domain socket (`SPARKLET_SOCKET_DIR`;
    /// `None` = the system temp dir). The backend creates a unique
    /// per-context socket file inside it and unlinks it on drop.
    pub socket_dir: Option<String>,
    /// Worker heartbeat interval in milliseconds (`SPARKLET_HEARTBEAT_MS`).
    pub heartbeat_ms: u64,
    /// Driver-side liveness timeout: a worker silent for this long is
    /// declared lost and its in-flight tasks are reassigned
    /// (`SPARKLET_WORKER_TIMEOUT_MS`).
    pub worker_timeout_ms: u64,
    /// Path of the binary to spawn as a worker (`SPARKLET_WORKER_BINARY`).
    /// `None` re-execs the current binary. The sentinel `"<thread>"`
    /// runs workers as in-process threads speaking the same socket
    /// protocol — used by unit tests, where the current binary is the
    /// libtest harness and must not be re-exec'd.
    pub worker_binary: Option<String>,
    /// Fault injection for the multi-process backend: `"w1:2"` makes
    /// worker `w1` exit abruptly after completing 2 tasks. Passed to
    /// the spawned worker via its hidden `--fault` flag; used by the
    /// kill-a-worker recovery tests. Subsumed by the general
    /// `fault_plan` (a spec here becomes a `worker_kill=` clause via
    /// [`SparkletConf::effective_fault_plan`]); kept as its own knob for
    /// compatibility with the original kill tests.
    pub worker_fault: Option<String>,
    /// Deterministic fault-injection plan (`SPARKLET_FAULT_PLAN`,
    /// `--fault-plan`), in the [`FaultPlan`](super::faults::FaultPlan)
    /// grammar: `seed=42; spill_read:nth=1; worker_kill=w0:1`. Parsed
    /// and armed when the context is built; `None` disables injection.
    pub fault_plan: Option<String>,
    /// Base of the deterministic exponential backoff between task/job
    /// retry attempts, milliseconds (`SPARKLET_RETRY_BACKOFF_MS`).
    /// Attempt `a` sleeps `base * 2^(a-1)`, capped at
    /// [`super::faults::BACKOFF_CAP_MS`]. `0` disables sleeping
    /// (fast tests).
    pub retry_backoff_ms: u64,
    /// Per-job wall-clock deadline, milliseconds
    /// (`SPARKLET_JOB_DEADLINE_MS`). A job whose retry schedule is
    /// still failing past this budget stops with a typed
    /// `DeadlineExceeded` instead of burning the remaining attempts.
    /// `None` = unbounded.
    pub job_deadline_ms: Option<u64>,
    /// Per-request deadline for serve mode, milliseconds
    /// (`SPARKLET_SERVE_DEADLINE_MS`, `--deadline-ms`). Measured from
    /// request receipt; a request still queued past it is rejected
    /// typed with its admission ticket released. `None` = unbounded.
    pub serve_deadline_ms: Option<u64>,
    /// Rotate the event log once it exceeds this many **bytes**: the
    /// current file is renamed to `<path>.1` (replacing any previous
    /// generation) and a fresh file is started, bounding a long-lived
    /// process's log at roughly twice the cap. `None` = never rotate
    /// (the pre-serve behavior, fine for one-shot CLI runs).
    pub event_log_max_bytes: Option<u64>,
    /// Unix socket path the `serve` command listens on
    /// (`SPARKLET_SERVE_SOCKET`; `None` = derive a default under the
    /// system temp dir).
    pub serve_socket: Option<String>,
    /// Bound on the serve-mode admission queue: at most this many
    /// requests may wait for the mining slot before new arrivals are
    /// rejected with `Overloaded` (`SPARKLET_SERVE_QUEUE_DEPTH`).
    pub serve_queue_depth: usize,
    /// Per-tenant token-bucket refill rate in requests/second for the
    /// serve-mode load shedder. `0.0` disables per-tenant shedding
    /// (`SPARKLET_SERVE_TENANT_RATE`).
    pub serve_tenant_rate: f64,
    /// Byte budget for the serve-mode result cache (`None` =
    /// unlimited). Cached bytes are charged as *external* usage against
    /// the shuffle `BlockStore` accounting, so admission control sees
    /// cache pressure too (`SPARKLET_SERVE_CACHE_MB`).
    pub serve_cache_budget: Option<usize>,
}

impl Default for SparkletConf {
    fn default() -> Self {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self {
            app_name: "sparklet-app".into(),
            executor_cores: cores,
            executor_backend: "fifo".into(),
            shuffle_partitions: cores,
            max_task_failures: 4,
            task_failure_rate: 0.0,
            failure_seed: 0,
            collect_metrics: true,
            memory_budget: None,
            event_log: None,
            shared_nothing: cfg!(debug_assertions),
            multiprocess_workers: 2,
            socket_dir: None,
            heartbeat_ms: 500,
            worker_timeout_ms: 5_000,
            worker_binary: None,
            worker_fault: None,
            fault_plan: None,
            retry_backoff_ms: 10,
            job_deadline_ms: None,
            serve_deadline_ms: None,
            event_log_max_bytes: None,
            serve_socket: None,
            serve_queue_depth: 16,
            serve_tenant_rate: 0.0,
            serve_cache_budget: None,
        }
    }
}

impl SparkletConf {
    pub fn new(app_name: &str) -> Self {
        Self {
            app_name: app_name.into(),
            ..Default::default()
        }
    }

    /// Defaults with the `SPARKLET_*` environment overrides applied.
    pub fn from_env() -> Result<Self, ConfError> {
        Self::default().with_env_overrides()
    }

    /// Set executor cores (also resets `shuffle_partitions` to match).
    pub fn with_cores(mut self, cores: usize) -> Result<Self, ConfError> {
        if cores == 0 {
            return Err(ConfError::InvalidCores { value: "0".into() });
        }
        self.executor_cores = cores;
        self.shuffle_partitions = cores;
        Ok(self)
    }

    /// Select the executor backend by registry name (canonicalized, so
    /// aliases like `ws` or `seq` work).
    pub fn with_executor_backend(mut self, name: &str) -> Result<Self, ConfError> {
        match ExecutorRegistry::canonical(name) {
            Some(canonical) => {
                self.executor_backend = canonical.to_string();
                Ok(self)
            }
            None => Err(ConfError::Backend(ExecutorError::UnknownBackend {
                name: name.to_string(),
                suggestion: ExecutorRegistry::suggest(name),
            })),
        }
    }

    pub fn with_shuffle_partitions(mut self, n: usize) -> Result<Self, ConfError> {
        if n == 0 {
            return Err(ConfError::InvalidShufflePartitions { value: "0".into() });
        }
        self.shuffle_partitions = n;
        Ok(self)
    }

    pub fn with_failure_injection(mut self, rate: f64, seed: u64) -> Self {
        self.task_failure_rate = rate;
        self.failure_seed = seed;
        self
    }

    pub fn with_max_task_failures(mut self, n: usize) -> Self {
        self.max_task_failures = n.max(1);
        self
    }

    /// Cap the in-memory shuffle block set at `mb` MiB (0 is an error;
    /// unset means unlimited).
    pub fn with_memory_budget_mb(mut self, mb: usize) -> Result<Self, ConfError> {
        if mb == 0 {
            return Err(ConfError::InvalidMemoryBudget { value: "0".into() });
        }
        self.memory_budget = Some(mb * 1024 * 1024);
        Ok(self)
    }

    /// Byte-granular budget (tests and tooling; the MiB builder is the
    /// user-facing knob).
    pub fn with_memory_budget_bytes(mut self, bytes: usize) -> Result<Self, ConfError> {
        if bytes == 0 {
            return Err(ConfError::InvalidMemoryBudget { value: "0".into() });
        }
        self.memory_budget = Some(bytes);
        Ok(self)
    }

    /// Persist the event stream as JSONL at `path` (appending). Path
    /// problems surface as `ConfError::EventLog` when the context is
    /// built, not here — the file is only opened by
    /// `SparkletContext::try_new`.
    pub fn with_event_log(mut self, path: &str) -> Self {
        self.event_log = Some(path.to_string());
        self
    }

    /// Toggle the shared-nothing shuffle assertions.
    pub fn with_shared_nothing(mut self, on: bool) -> Self {
        self.shared_nothing = on;
        self
    }

    /// Worker process count for the `multi-process` backend.
    pub fn with_workers(mut self, n: usize) -> Result<Self, ConfError> {
        if n == 0 {
            return Err(ConfError::InvalidWorkers { value: "0".into() });
        }
        self.multiprocess_workers = n;
        Ok(self)
    }

    /// Directory for the driver's Unix domain socket.
    pub fn with_socket_dir(mut self, dir: &str) -> Self {
        self.socket_dir = Some(dir.to_string());
        self
    }

    /// Heartbeat interval and liveness timeout (both milliseconds).
    pub fn with_worker_timeouts(mut self, heartbeat_ms: u64, timeout_ms: u64) -> Self {
        self.heartbeat_ms = heartbeat_ms.max(1);
        self.worker_timeout_ms = timeout_ms.max(self.heartbeat_ms);
        self
    }

    /// Binary to spawn as a worker process (`"<thread>"` = in-process
    /// thread workers, for tests).
    pub fn with_worker_binary(mut self, path: &str) -> Self {
        self.worker_binary = Some(path.to_string());
        self
    }

    /// Inject a worker fault: `"<worker-id>:<after-n-tasks>"`.
    pub fn with_worker_fault(mut self, spec: &str) -> Self {
        self.worker_fault = Some(spec.to_string());
        self
    }

    /// Set the deterministic fault-injection plan. The spec is parsed
    /// here so a typo fails the conf, not silently injects nothing.
    pub fn with_fault_plan(mut self, spec: &str) -> Result<Self, ConfError> {
        FaultPlan::parse(spec).map_err(|reason| ConfError::InvalidFaultPlan {
            value: spec.to_string(),
            reason,
        })?;
        self.fault_plan = Some(spec.to_string());
        Ok(self)
    }

    /// Base backoff between retry attempts, milliseconds (0 disables
    /// sleeping).
    pub fn with_retry_backoff_ms(mut self, ms: u64) -> Self {
        self.retry_backoff_ms = ms;
        self
    }

    /// Per-job wall-clock deadline in milliseconds (0 is an error;
    /// unset means unbounded).
    pub fn with_job_deadline_ms(mut self, ms: u64) -> Result<Self, ConfError> {
        if ms == 0 {
            return Err(ConfError::InvalidDeadline {
                what: "job deadline",
                value: "0".into(),
            });
        }
        self.job_deadline_ms = Some(ms);
        Ok(self)
    }

    /// Per-request serve-mode deadline in milliseconds (0 is an error;
    /// unset means unbounded).
    pub fn with_serve_deadline_ms(mut self, ms: u64) -> Result<Self, ConfError> {
        if ms == 0 {
            return Err(ConfError::InvalidDeadline {
                what: "serve deadline",
                value: "0".into(),
            });
        }
        self.serve_deadline_ms = Some(ms);
        Ok(self)
    }

    /// The fault plan with the legacy `worker_fault` spec folded in as
    /// a `worker_kill=` clause — the single string handed to the
    /// context's [`FaultPlane`](super::faults::FaultPlane) and to
    /// spawned workers via `--fault`. `None` when neither knob is set.
    pub fn effective_fault_plan(&self) -> Option<String> {
        match (&self.fault_plan, &self.worker_fault) {
            (None, None) => None,
            (Some(plan), None) => Some(plan.clone()),
            (None, Some(w)) => Some(format!("worker_kill={w}")),
            (Some(plan), Some(w)) => Some(format!("{plan}; worker_kill={w}")),
        }
    }

    /// Rotate the event log to `<path>.1` once it exceeds `mb` MiB
    /// (0 is an error; unset means never rotate).
    pub fn with_event_log_max_mb(mut self, mb: usize) -> Result<Self, ConfError> {
        if mb == 0 {
            return Err(ConfError::InvalidEventLogCap { value: "0".into() });
        }
        self.event_log_max_bytes = Some(mb as u64 * 1024 * 1024);
        Ok(self)
    }

    /// Byte-granular rotation cap (tests; the MiB builder is the
    /// user-facing knob).
    pub fn with_event_log_max_bytes(mut self, bytes: u64) -> Result<Self, ConfError> {
        if bytes == 0 {
            return Err(ConfError::InvalidEventLogCap { value: "0".into() });
        }
        self.event_log_max_bytes = Some(bytes);
        Ok(self)
    }

    /// Unix socket path for the `serve` command.
    pub fn with_serve_socket(mut self, path: &str) -> Self {
        self.serve_socket = Some(path.to_string());
        self
    }

    /// Bound the serve-mode admission queue at `n` waiting requests.
    pub fn with_serve_queue_depth(mut self, n: usize) -> Result<Self, ConfError> {
        if n == 0 {
            return Err(ConfError::InvalidQueueDepth { value: "0".into() });
        }
        self.serve_queue_depth = n;
        Ok(self)
    }

    /// Per-tenant token-bucket rate in requests/second (`0.0` disables
    /// shedding; negative or non-finite rates are errors).
    pub fn with_serve_tenant_rate(mut self, rate: f64) -> Result<Self, ConfError> {
        if !rate.is_finite() || rate < 0.0 {
            return Err(ConfError::InvalidTenantRate {
                value: format!("{rate}"),
            });
        }
        self.serve_tenant_rate = rate;
        Ok(self)
    }

    /// Cap the serve-mode result cache at `mb` MiB (0 is an error;
    /// unset means unlimited).
    pub fn with_serve_cache_budget_mb(mut self, mb: usize) -> Result<Self, ConfError> {
        if mb == 0 {
            return Err(ConfError::InvalidCacheBudget { value: "0".into() });
        }
        self.serve_cache_budget = Some(mb * 1024 * 1024);
        Ok(self)
    }

    /// Byte-granular cache budget (tests and tooling).
    pub fn with_serve_cache_budget_bytes(mut self, bytes: usize) -> Result<Self, ConfError> {
        if bytes == 0 {
            return Err(ConfError::InvalidCacheBudget { value: "0".into() });
        }
        self.serve_cache_budget = Some(bytes);
        Ok(self)
    }

    /// Apply the `SPARKLET_CORES`, `SPARKLET_BACKEND`,
    /// `SPARKLET_SHUFFLE_PARTITIONS`, `SPARKLET_MEMORY_MB`,
    /// `SPARKLET_SHARED_NOTHING`, `SPARKLET_WORKERS`,
    /// `SPARKLET_SOCKET_DIR`, `SPARKLET_HEARTBEAT_MS`,
    /// `SPARKLET_WORKER_TIMEOUT_MS`, `SPARKLET_WORKER_BINARY`,
    /// `SPARKLET_EVENT_LOG_MAX_MB`, `SPARKLET_SERVE_SOCKET`,
    /// `SPARKLET_SERVE_QUEUE_DEPTH`, `SPARKLET_SERVE_TENANT_RATE`,
    /// `SPARKLET_SERVE_CACHE_MB`, `SPARKLET_FAULT_PLAN`,
    /// `SPARKLET_RETRY_BACKOFF_MS`, `SPARKLET_JOB_DEADLINE_MS`, and
    /// `SPARKLET_SERVE_DEADLINE_MS`
    /// environment overrides on top of the current values (empty/unset
    /// variables are ignored). Cores are applied before shuffle
    /// partitions, so setting both honours the explicit partition count.
    pub fn with_env_overrides(mut self) -> Result<Self, ConfError> {
        if let Some(cores) = env_usize("SPARKLET_CORES")? {
            self = self.with_cores(cores)?;
        }
        if let Some(name) = env_str("SPARKLET_BACKEND") {
            self = self.with_executor_backend(&name)?;
        }
        if let Some(n) = env_usize("SPARKLET_SHUFFLE_PARTITIONS")? {
            self = self.with_shuffle_partitions(n)?;
        }
        if let Some(mb) = env_usize("SPARKLET_MEMORY_MB")? {
            self = self.with_memory_budget_mb(mb)?;
        }
        if let Some(on) = env_bool("SPARKLET_SHARED_NOTHING")? {
            self = self.with_shared_nothing(on);
        }
        if let Some(n) = env_usize("SPARKLET_WORKERS")? {
            self = self.with_workers(n)?;
        }
        if let Some(dir) = env_str("SPARKLET_SOCKET_DIR") {
            self = self.with_socket_dir(&dir);
        }
        if let Some(hb) = env_usize("SPARKLET_HEARTBEAT_MS")? {
            self.heartbeat_ms = hb as u64;
        }
        if let Some(t) = env_usize("SPARKLET_WORKER_TIMEOUT_MS")? {
            self.worker_timeout_ms = t as u64;
        }
        if let Some(bin) = env_str("SPARKLET_WORKER_BINARY") {
            self = self.with_worker_binary(&bin);
        }
        if let Some(mb) = env_usize("SPARKLET_EVENT_LOG_MAX_MB")? {
            self = self.with_event_log_max_mb(mb)?;
        }
        if let Some(path) = env_str("SPARKLET_SERVE_SOCKET") {
            self = self.with_serve_socket(&path);
        }
        if let Some(n) = env_usize("SPARKLET_SERVE_QUEUE_DEPTH")? {
            self = self.with_serve_queue_depth(n)?;
        }
        if let Some(rate) = env_f64("SPARKLET_SERVE_TENANT_RATE")? {
            self = self.with_serve_tenant_rate(rate)?;
        }
        if let Some(mb) = env_usize("SPARKLET_SERVE_CACHE_MB")? {
            self = self.with_serve_cache_budget_mb(mb)?;
        }
        if let Some(spec) = env_str("SPARKLET_FAULT_PLAN") {
            self = self.with_fault_plan(&spec)?;
        }
        if let Some(ms) = env_usize("SPARKLET_RETRY_BACKOFF_MS")? {
            self = self.with_retry_backoff_ms(ms as u64);
        }
        if let Some(ms) = env_usize("SPARKLET_JOB_DEADLINE_MS")? {
            self = self.with_job_deadline_ms(ms as u64)?;
        }
        if let Some(ms) = env_usize("SPARKLET_SERVE_DEADLINE_MS")? {
            self = self.with_serve_deadline_ms(ms as u64)?;
        }
        Ok(self)
    }
}

fn env_str(var: &'static str) -> Option<String> {
    std::env::var(var).ok().filter(|v| !v.is_empty())
}

fn env_bool(var: &'static str) -> Result<Option<bool>, ConfError> {
    match env_str(var) {
        None => Ok(None),
        Some(value) => match value.to_lowercase().as_str() {
            "1" | "true" | "on" | "yes" => Ok(Some(true)),
            "0" | "false" | "off" | "no" => Ok(Some(false)),
            _ => Err(ConfError::InvalidEnv {
                var,
                value,
                reason: "not a boolean (use 0/1)".into(),
            }),
        },
    }
}

fn env_f64(var: &'static str) -> Result<Option<f64>, ConfError> {
    match env_str(var) {
        None => Ok(None),
        Some(value) => match value.parse::<f64>() {
            Ok(v) if v.is_finite() && v >= 0.0 => Ok(Some(v)),
            Ok(_) => Err(ConfError::InvalidEnv {
                var,
                value,
                reason: "must be finite and >= 0".into(),
            }),
            Err(_) => Err(ConfError::InvalidEnv {
                var,
                value,
                reason: "not a number".into(),
            }),
        },
    }
}

fn env_usize(var: &'static str) -> Result<Option<usize>, ConfError> {
    match env_str(var) {
        None => Ok(None),
        Some(value) => match value.parse::<usize>() {
            Ok(0) => Err(ConfError::InvalidEnv {
                var,
                value,
                reason: "must be >= 1".into(),
            }),
            Ok(n) => Ok(Some(n)),
            Err(_) => Err(ConfError::InvalidEnv {
                var,
                value,
                reason: "not an unsigned integer".into(),
            }),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = SparkletConf::default();
        assert!(c.executor_cores >= 1);
        assert_eq!(c.executor_backend, "fifo");
        assert_eq!(c.task_failure_rate, 0.0);
        assert!(c.max_task_failures >= 1);
    }

    #[test]
    fn builders_chain() {
        let c = SparkletConf::new("t")
            .with_cores(3)
            .unwrap()
            .with_shuffle_partitions(7)
            .unwrap()
            .with_executor_backend("work-stealing")
            .unwrap()
            .with_failure_injection(0.5, 9)
            .with_max_task_failures(2);
        assert_eq!(c.executor_cores, 3);
        assert_eq!(c.shuffle_partitions, 7);
        assert_eq!(c.executor_backend, "work-stealing");
        assert_eq!(c.task_failure_rate, 0.5);
        assert_eq!(c.max_task_failures, 2);
    }

    #[test]
    fn zero_values_are_errors_not_aborts() {
        let err = SparkletConf::default().with_cores(0).unwrap_err();
        assert!(matches!(err, ConfError::InvalidCores { .. }));
        assert!(err.to_string().contains("executor_cores"), "{err}");
        let err = SparkletConf::default()
            .with_shuffle_partitions(0)
            .unwrap_err();
        assert!(matches!(err, ConfError::InvalidShufflePartitions { .. }));
        let err = SparkletConf::default().with_memory_budget_mb(0).unwrap_err();
        assert!(matches!(err, ConfError::InvalidMemoryBudget { .. }));
        let err = SparkletConf::default()
            .with_memory_budget_bytes(0)
            .unwrap_err();
        assert!(matches!(err, ConfError::InvalidMemoryBudget { .. }));
    }

    #[test]
    fn memory_budget_and_shared_nothing_builders() {
        let c = SparkletConf::default();
        assert_eq!(c.memory_budget, None, "unlimited by default");
        let c = c.with_memory_budget_mb(64).unwrap();
        assert_eq!(c.memory_budget, Some(64 * 1024 * 1024));
        let c = c.with_memory_budget_bytes(4096).unwrap();
        assert_eq!(c.memory_budget, Some(4096));
        let c = c.with_shared_nothing(true);
        assert!(c.shared_nothing);
        assert!(!c.with_shared_nothing(false).shared_nothing);
    }

    #[test]
    fn event_log_builder_sets_path() {
        let c = SparkletConf::default();
        assert_eq!(c.event_log, None, "off by default");
        let c = c.with_event_log("/tmp/events.jsonl");
        assert_eq!(c.event_log.as_deref(), Some("/tmp/events.jsonl"));
        let err = ConfError::EventLog {
            path: "/nope/events.jsonl".into(),
            reason: "denied".into(),
        };
        assert!(err.to_string().contains("cannot open event log"), "{err}");
    }

    #[test]
    fn serve_knobs_default_and_validate() {
        let c = SparkletConf::default();
        assert_eq!(c.serve_socket, None);
        assert_eq!(c.serve_queue_depth, 16);
        assert_eq!(c.serve_tenant_rate, 0.0, "shedding off by default");
        assert_eq!(c.serve_cache_budget, None);
        assert_eq!(c.event_log_max_bytes, None, "no rotation by default");

        let c = c
            .with_serve_socket("/tmp/s.sock")
            .with_serve_queue_depth(4)
            .unwrap()
            .with_serve_tenant_rate(2.5)
            .unwrap()
            .with_serve_cache_budget_mb(8)
            .unwrap()
            .with_event_log_max_mb(2)
            .unwrap();
        assert_eq!(c.serve_socket.as_deref(), Some("/tmp/s.sock"));
        assert_eq!(c.serve_queue_depth, 4);
        assert_eq!(c.serve_tenant_rate, 2.5);
        assert_eq!(c.serve_cache_budget, Some(8 * 1024 * 1024));
        assert_eq!(c.event_log_max_bytes, Some(2 * 1024 * 1024));
        let c = c
            .with_serve_cache_budget_bytes(4096)
            .unwrap()
            .with_event_log_max_bytes(512)
            .unwrap();
        assert_eq!(c.serve_cache_budget, Some(4096));
        assert_eq!(c.event_log_max_bytes, Some(512));

        let err = SparkletConf::default()
            .with_serve_queue_depth(0)
            .unwrap_err();
        assert!(matches!(err, ConfError::InvalidQueueDepth { .. }));
        assert!(err.to_string().contains("serve_queue_depth"), "{err}");
        let err = SparkletConf::default()
            .with_serve_tenant_rate(-1.0)
            .unwrap_err();
        assert!(matches!(err, ConfError::InvalidTenantRate { .. }));
        let err = SparkletConf::default()
            .with_serve_tenant_rate(f64::NAN)
            .unwrap_err();
        assert!(matches!(err, ConfError::InvalidTenantRate { .. }));
        let err = SparkletConf::default()
            .with_serve_cache_budget_mb(0)
            .unwrap_err();
        assert!(matches!(err, ConfError::InvalidCacheBudget { .. }));
        let err = SparkletConf::default().with_event_log_max_mb(0).unwrap_err();
        assert!(matches!(err, ConfError::InvalidEventLogCap { .. }));
        // Rate 0 is valid — it means "shedding disabled", not "no requests".
        let c = SparkletConf::default().with_serve_tenant_rate(0.0).unwrap();
        assert_eq!(c.serve_tenant_rate, 0.0);
    }

    #[test]
    fn backend_names_validate_with_suggestions() {
        // Aliases canonicalize.
        let c = SparkletConf::default().with_executor_backend("ws").unwrap();
        assert_eq!(c.executor_backend, "work-stealing");
        let c = SparkletConf::default().with_executor_backend("seq").unwrap();
        assert_eq!(c.executor_backend, "sequential");
        // Unknown names fail with a suggestion.
        let err = SparkletConf::default()
            .with_executor_backend("fifa")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown executor backend"), "{msg}");
        assert!(msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn env_overrides_apply_and_validate() {
        // One test touches all three vars sequentially: env is
        // process-global, so splitting this across #[test] fns would
        // race under the parallel test runner.
        let clear = || {
            std::env::remove_var("SPARKLET_CORES");
            std::env::remove_var("SPARKLET_BACKEND");
            std::env::remove_var("SPARKLET_SHUFFLE_PARTITIONS");
            std::env::remove_var("SPARKLET_MEMORY_MB");
            std::env::remove_var("SPARKLET_SHARED_NOTHING");
            std::env::remove_var("SPARKLET_WORKERS");
            std::env::remove_var("SPARKLET_SOCKET_DIR");
            std::env::remove_var("SPARKLET_HEARTBEAT_MS");
            std::env::remove_var("SPARKLET_WORKER_TIMEOUT_MS");
            std::env::remove_var("SPARKLET_WORKER_BINARY");
            std::env::remove_var("SPARKLET_EVENT_LOG_MAX_MB");
            std::env::remove_var("SPARKLET_SERVE_SOCKET");
            std::env::remove_var("SPARKLET_SERVE_QUEUE_DEPTH");
            std::env::remove_var("SPARKLET_SERVE_TENANT_RATE");
            std::env::remove_var("SPARKLET_SERVE_CACHE_MB");
            std::env::remove_var("SPARKLET_FAULT_PLAN");
            std::env::remove_var("SPARKLET_RETRY_BACKOFF_MS");
            std::env::remove_var("SPARKLET_JOB_DEADLINE_MS");
            std::env::remove_var("SPARKLET_SERVE_DEADLINE_MS");
        };
        clear();

        // Unset vars leave the conf untouched.
        let base = SparkletConf::new("env").with_cores(2).unwrap();
        let same = base.clone().with_env_overrides().unwrap();
        assert_eq!(same.executor_cores, 2);
        assert_eq!(same.executor_backend, "fifo");

        // Valid overrides apply; explicit partitions beat the cores reset.
        std::env::set_var("SPARKLET_CORES", "3");
        std::env::set_var("SPARKLET_BACKEND", "steal");
        std::env::set_var("SPARKLET_SHUFFLE_PARTITIONS", "11");
        let c = base.clone().with_env_overrides().unwrap();
        assert_eq!(c.executor_cores, 3);
        assert_eq!(c.executor_backend, "work-stealing");
        assert_eq!(c.shuffle_partitions, 11);

        // Garbage values are typed errors, not panics.
        std::env::set_var("SPARKLET_CORES", "many");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(
            matches!(err, ConfError::InvalidEnv { var: "SPARKLET_CORES", .. }),
            "{err}"
        );
        std::env::set_var("SPARKLET_CORES", "0");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(err.to_string().contains("must be >= 1"), "{err}");
        std::env::set_var("SPARKLET_CORES", "2");
        std::env::set_var("SPARKLET_BACKEND", "tokio");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(matches!(err, ConfError::Backend(_)), "{err}");

        // Empty values count as unset.
        std::env::set_var("SPARKLET_BACKEND", "");
        let c = base.clone().with_env_overrides().unwrap();
        assert_eq!(c.executor_backend, "fifo");

        // Memory budget + shared-nothing overrides.
        std::env::set_var("SPARKLET_MEMORY_MB", "2");
        std::env::set_var("SPARKLET_SHARED_NOTHING", "0");
        let c = base.clone().with_env_overrides().unwrap();
        assert_eq!(c.memory_budget, Some(2 * 1024 * 1024));
        assert!(!c.shared_nothing);
        std::env::set_var("SPARKLET_SHARED_NOTHING", "true");
        let c = base.clone().with_env_overrides().unwrap();
        assert!(c.shared_nothing);
        std::env::set_var("SPARKLET_MEMORY_MB", "0");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(
            matches!(err, ConfError::InvalidEnv { var: "SPARKLET_MEMORY_MB", .. }),
            "{err}"
        );
        std::env::set_var("SPARKLET_MEMORY_MB", "2");
        std::env::set_var("SPARKLET_SHARED_NOTHING", "maybe");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(
            matches!(err, ConfError::InvalidEnv { var: "SPARKLET_SHARED_NOTHING", .. }),
            "{err}"
        );
        std::env::set_var("SPARKLET_SHARED_NOTHING", "1");

        // Multi-process knobs.
        std::env::set_var("SPARKLET_WORKERS", "3");
        std::env::set_var("SPARKLET_SOCKET_DIR", "/tmp/sparklet-socks");
        std::env::set_var("SPARKLET_HEARTBEAT_MS", "100");
        std::env::set_var("SPARKLET_WORKER_TIMEOUT_MS", "900");
        std::env::set_var("SPARKLET_WORKER_BINARY", "/usr/bin/true");
        let c = base.clone().with_env_overrides().unwrap();
        assert_eq!(c.multiprocess_workers, 3);
        assert_eq!(c.socket_dir.as_deref(), Some("/tmp/sparklet-socks"));
        assert_eq!(c.heartbeat_ms, 100);
        assert_eq!(c.worker_timeout_ms, 900);
        assert_eq!(c.worker_binary.as_deref(), Some("/usr/bin/true"));
        std::env::set_var("SPARKLET_WORKERS", "0");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(
            matches!(err, ConfError::InvalidEnv { var: "SPARKLET_WORKERS", .. }),
            "{err}"
        );
        std::env::set_var("SPARKLET_WORKERS", "3");

        // Serve + rotation knobs.
        std::env::set_var("SPARKLET_EVENT_LOG_MAX_MB", "2");
        std::env::set_var("SPARKLET_SERVE_SOCKET", "/tmp/serve.sock");
        std::env::set_var("SPARKLET_SERVE_QUEUE_DEPTH", "9");
        std::env::set_var("SPARKLET_SERVE_TENANT_RATE", "1.5");
        std::env::set_var("SPARKLET_SERVE_CACHE_MB", "3");
        let c = base.clone().with_env_overrides().unwrap();
        assert_eq!(c.event_log_max_bytes, Some(2 * 1024 * 1024));
        assert_eq!(c.serve_socket.as_deref(), Some("/tmp/serve.sock"));
        assert_eq!(c.serve_queue_depth, 9);
        assert_eq!(c.serve_tenant_rate, 1.5);
        assert_eq!(c.serve_cache_budget, Some(3 * 1024 * 1024));
        std::env::set_var("SPARKLET_SERVE_TENANT_RATE", "-2");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(
            matches!(
                err,
                ConfError::InvalidEnv { var: "SPARKLET_SERVE_TENANT_RATE", .. }
            ),
            "{err}"
        );
        std::env::set_var("SPARKLET_SERVE_TENANT_RATE", "fast");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(err.to_string().contains("not a number"), "{err}");
        std::env::set_var("SPARKLET_SERVE_TENANT_RATE", "1.5");

        // Fault-injection and retry knobs.
        std::env::set_var("SPARKLET_FAULT_PLAN", "seed=7; spill_read:nth=1");
        std::env::set_var("SPARKLET_RETRY_BACKOFF_MS", "25");
        std::env::set_var("SPARKLET_JOB_DEADLINE_MS", "30000");
        std::env::set_var("SPARKLET_SERVE_DEADLINE_MS", "2000");
        let c = base.clone().with_env_overrides().unwrap();
        assert_eq!(c.fault_plan.as_deref(), Some("seed=7; spill_read:nth=1"));
        assert_eq!(c.retry_backoff_ms, 25);
        assert_eq!(c.job_deadline_ms, Some(30_000));
        assert_eq!(c.serve_deadline_ms, Some(2_000));
        std::env::set_var("SPARKLET_FAULT_PLAN", "spill_read:whenever");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(
            matches!(err, ConfError::InvalidFaultPlan { .. }),
            "{err}"
        );
        std::env::set_var("SPARKLET_FAULT_PLAN", "seed=7");
        std::env::set_var("SPARKLET_JOB_DEADLINE_MS", "soon");
        let err = base.clone().with_env_overrides().unwrap_err();
        assert!(
            matches!(err, ConfError::InvalidEnv { var: "SPARKLET_JOB_DEADLINE_MS", .. }),
            "{err}"
        );

        clear();
    }

    #[test]
    fn fault_plan_knobs_validate_and_merge_with_worker_fault() {
        let c = SparkletConf::default();
        assert_eq!(c.fault_plan, None, "no injection by default");
        assert_eq!(c.retry_backoff_ms, 10);
        assert_eq!(c.job_deadline_ms, None);
        assert_eq!(c.serve_deadline_ms, None);
        assert_eq!(c.effective_fault_plan(), None);

        let c = c
            .with_fault_plan("seed=3; spill_write:every=2")
            .unwrap()
            .with_retry_backoff_ms(0)
            .with_job_deadline_ms(5_000)
            .unwrap()
            .with_serve_deadline_ms(250)
            .unwrap();
        assert_eq!(c.fault_plan.as_deref(), Some("seed=3; spill_write:every=2"));
        assert_eq!(c.retry_backoff_ms, 0);
        assert_eq!(c.job_deadline_ms, Some(5_000));
        assert_eq!(c.serve_deadline_ms, Some(250));
        assert_eq!(
            c.effective_fault_plan().as_deref(),
            Some("seed=3; spill_write:every=2")
        );

        // The legacy worker_fault spec folds in as a worker_kill clause,
        // alone or merged after an explicit plan.
        let legacy = SparkletConf::default().with_worker_fault("w0:1");
        assert_eq!(
            legacy.effective_fault_plan().as_deref(),
            Some("worker_kill=w0:1")
        );
        let both = legacy.with_fault_plan("spill_read:nth=1").unwrap();
        assert_eq!(
            both.effective_fault_plan().as_deref(),
            Some("spill_read:nth=1; worker_kill=w0:1")
        );

        // Bad values are typed errors.
        let err = SparkletConf::default()
            .with_fault_plan("spill_read:nth=zero")
            .unwrap_err();
        assert!(matches!(err, ConfError::InvalidFaultPlan { .. }));
        assert!(err.to_string().contains("invalid fault plan"), "{err}");
        let err = SparkletConf::default().with_job_deadline_ms(0).unwrap_err();
        assert!(
            matches!(err, ConfError::InvalidDeadline { what: "job deadline", .. }),
            "{err}"
        );
        let err = SparkletConf::default()
            .with_serve_deadline_ms(0)
            .unwrap_err();
        assert!(err.to_string().contains("serve deadline"), "{err}");
    }

    #[test]
    fn multiprocess_builders_validate() {
        let c = SparkletConf::default();
        assert_eq!(c.multiprocess_workers, 2, "two workers by default");
        assert!(c.worker_timeout_ms >= c.heartbeat_ms);
        let c = c.with_workers(4).unwrap();
        assert_eq!(c.multiprocess_workers, 4);
        let err = SparkletConf::default().with_workers(0).unwrap_err();
        assert!(matches!(err, ConfError::InvalidWorkers { .. }));
        assert!(err.to_string().contains("multiprocess_workers"), "{err}");
        // Timeout is clamped to at least the heartbeat interval.
        let c = SparkletConf::default().with_worker_timeouts(200, 50);
        assert_eq!(c.heartbeat_ms, 200);
        assert_eq!(c.worker_timeout_ms, 200);
        let c = SparkletConf::default()
            .with_worker_binary("<thread>")
            .with_worker_fault("w0:1")
            .with_socket_dir("/tmp/x");
        assert_eq!(c.worker_binary.as_deref(), Some("<thread>"));
        assert_eq!(c.worker_fault.as_deref(), Some("w0:1"));
        assert_eq!(c.socket_dir.as_deref(), Some("/tmp/x"));
        let err = ConfError::BackendAttach {
            backend: "multi-process".into(),
            reason: "bind failed".into(),
        };
        assert!(err.to_string().contains("failed to start"), "{err}");
    }
}
