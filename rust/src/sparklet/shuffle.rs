//! In-memory hash shuffle — the wide-dependency data plane.
//!
//! Map tasks partition their output into `num_reduce` buckets and
//! register each bucket here; reduce tasks fetch and concatenate the
//! buckets for their partition. Buckets are type-erased (`Box<dyn Any>`)
//! because the shuffle manager is shared across all shuffles of a
//! context; the typed shuffle dependency downcasts on read.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

type Bucket = Arc<dyn Any + Send + Sync>;

/// Shuffle data + completion registry for one context.
#[derive(Default)]
pub struct ShuffleManager {
    /// (shuffle_id, reduce_partition) -> one bucket per completed map task.
    buckets: Mutex<HashMap<(usize, usize), Vec<Bucket>>>,
    /// Shuffle ids whose map stage has fully completed.
    completed: Mutex<std::collections::HashSet<usize>>,
    next_shuffle_id: AtomicUsize,
    /// Total records moved through the shuffle (metrics).
    records_written: AtomicU64,
    /// Estimated bytes moved through the shuffle: records × the static
    /// size of the record type (heap payloads like `Vec` count as their
    /// header only — an estimate, but a monotone, cheap one; enough for
    /// backpressure decisions in the streaming layer).
    bytes_written: AtomicU64,
}

impl ShuffleManager {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn new_shuffle_id(&self) -> usize {
        self.next_shuffle_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Write one map task's bucket for `reduce_part`. `records` is the
    /// bucket length and `bytes` the estimated payload size (records ×
    /// size hint), both tracked for metrics.
    pub fn write_bucket(
        &self,
        shuffle_id: usize,
        reduce_part: usize,
        bucket: Bucket,
        records: usize,
        bytes: usize,
    ) {
        self.records_written
            .fetch_add(records as u64, Ordering::Relaxed);
        self.bytes_written.fetch_add(bytes as u64, Ordering::Relaxed);
        self.buckets
            .lock()
            .unwrap()
            .entry((shuffle_id, reduce_part))
            .or_default()
            .push(bucket);
    }

    /// Fetch all buckets for a reduce partition (empty if none).
    pub fn fetch(&self, shuffle_id: usize, reduce_part: usize) -> Vec<Bucket> {
        self.buckets
            .lock()
            .unwrap()
            .get(&(shuffle_id, reduce_part))
            .cloned()
            .unwrap_or_default()
    }

    /// Clear any partial buckets for a shuffle (before re-running its map
    /// stage after a failure, so retries don't double-write).
    pub fn clear_shuffle(&self, shuffle_id: usize) {
        self.buckets
            .lock()
            .unwrap()
            .retain(|(sid, _), _| *sid != shuffle_id);
        self.completed.lock().unwrap().remove(&shuffle_id);
    }

    pub fn mark_completed(&self, shuffle_id: usize) {
        self.completed.lock().unwrap().insert(shuffle_id);
    }

    pub fn is_completed(&self, shuffle_id: usize) -> bool {
        self.completed.lock().unwrap().contains(&shuffle_id)
    }

    pub fn records_written(&self) -> u64 {
        self.records_written.load(Ordering::Relaxed)
    }

    /// Estimated bytes written through the shuffle (see `bytes_written`
    /// field note: static record size × records).
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.load(Ordering::Relaxed)
    }

    /// Drop all shuffle data (job teardown / memory reclamation).
    pub fn clear_all(&self) {
        self.buckets.lock().unwrap().clear();
        self.completed.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_fetch_roundtrip() {
        let m = ShuffleManager::new();
        let sid = m.new_shuffle_id();
        let rec = std::mem::size_of::<(u32, &str)>();
        m.write_bucket(sid, 0, Arc::new(vec![(1u32, "a")]), 1, rec);
        m.write_bucket(sid, 0, Arc::new(vec![(2u32, "b")]), 1, rec);
        m.write_bucket(sid, 1, Arc::new(vec![(3u32, "c")]), 1, rec);
        let got = m.fetch(sid, 0);
        assert_eq!(got.len(), 2);
        let first = got[0]
            .downcast_ref::<Vec<(u32, &str)>>()
            .expect("type roundtrip");
        assert_eq!(first, &vec![(1u32, "a")]);
        assert_eq!(m.fetch(sid, 1).len(), 1);
        assert_eq!(m.fetch(sid, 2).len(), 0);
        assert_eq!(m.records_written(), 3);
        assert_eq!(m.bytes_written(), 3 * rec as u64);
    }

    #[test]
    fn completion_registry() {
        let m = ShuffleManager::new();
        let sid = m.new_shuffle_id();
        assert!(!m.is_completed(sid));
        m.mark_completed(sid);
        assert!(m.is_completed(sid));
        m.clear_shuffle(sid);
        assert!(!m.is_completed(sid));
    }

    #[test]
    fn clear_shuffle_scopes_to_id() {
        let m = ShuffleManager::new();
        let a = m.new_shuffle_id();
        let b = m.new_shuffle_id();
        m.write_bucket(a, 0, Arc::new(vec![1u32]), 1, 4);
        m.write_bucket(b, 0, Arc::new(vec![2u32]), 1, 4);
        m.clear_shuffle(a);
        assert_eq!(m.fetch(a, 0).len(), 0);
        assert_eq!(m.fetch(b, 0).len(), 1);
    }

    #[test]
    fn distinct_ids() {
        let m = ShuffleManager::new();
        assert_ne!(m.new_shuffle_id(), m.new_shuffle_id());
    }
}
