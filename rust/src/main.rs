//! `repro` — the RDD-Eclat leader binary.
//!
//! Commands:
//!   table1                         regenerate Table 1 (dataset properties)
//!   fig --id N [--panel a|b]       regenerate Fig N (1..6)
//!   mine --dataset D --min-sup F   run one algorithm on one dataset
//!        [--variant v1..v5|apriori] [--cores N] [--p N] [--scale F]
//!   claims --id N                  run Fig N and check the paper's claims
//!   stream --dataset D --min-sup F --window N --slide N
//!                                  micro-batch sliding-window mining
//!   xla-smoke                      load + execute the AOT artifacts
//!   all                            table1 + every figure (long)
//!   help
//!
//! Shared env overrides: REPRO_SCALE, REPRO_SEED, REPRO_CORES,
//! REPRO_BENCH_REPS, REPRO_BENCH_WARMUP, REPRO_ARTIFACTS.

use anyhow::{bail, Result};

use rdd_eclat::cli::Args;
use rdd_eclat::coordinator::{experiments, report, ExperimentConfig};
use rdd_eclat::data::Dataset;
use rdd_eclat::fim::eclat::EclatVariant;
use rdd_eclat::fim::types::abs_min_sup;

fn main() -> Result<()> {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            print_help();
            std::process::exit(2);
        }
    };
    let mut cfg = ExperimentConfig::default();
    if let Some(scale) = args.get_parse::<f64>("scale").map_err(anyhow::Error::msg)? {
        cfg.scale = scale;
    }
    if let Some(cores) = args.get_parse::<usize>("cores").map_err(anyhow::Error::msg)? {
        cfg.cores = cores;
    }
    if let Some(p) = args.get_parse::<usize>("p").map_err(anyhow::Error::msg)? {
        cfg.p = p;
    }

    match args.command.as_str() {
        "table1" => println!("{}", experiments::table1(&cfg)),
        "fig" => run_fig(&args, &cfg)?,
        "claims" => run_claims(&args, &cfg)?,
        "mine" => run_mine(&args, &cfg)?,
        "generate" => run_generate(&args, &cfg)?,
        "rules" => run_rules(&args, &cfg)?,
        "stream" => run_stream(&args, &cfg)?,
        "xla-smoke" => xla_smoke()?,
        "all" => {
            println!("{}", experiments::table1(&cfg));
            for id in 1..=6 {
                run_fig_id(id, None, &cfg)?;
            }
        }
        _ => print_help(),
    }
    Ok(())
}

fn parse_dataset(name: &str) -> Result<Dataset> {
    Ok(match name.to_lowercase().as_str() {
        "bms1" | "bms_webview_1" => Dataset::Bms1,
        "bms2" | "bms_webview_2" => Dataset::Bms2,
        "t10" | "t10i4d100k" => Dataset::T10I4D100K,
        "t40" | "t40i10d100k" => Dataset::T40I10D100K,
        other => bail!("unknown dataset {other} (bms1|bms2|t10|t40)"),
    })
}

fn fig_dataset(id: usize) -> Result<Dataset> {
    Ok(match id {
        1 => Dataset::Bms1,
        2 => Dataset::Bms2,
        3 => Dataset::T10I4D100K,
        4 => Dataset::T40I10D100K,
        _ => bail!("figures 1-4 are min_sup sweeps; got {id}"),
    })
}

fn run_fig(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let id: usize = args
        .get_parse("id")
        .map_err(anyhow::Error::msg)?
        .ok_or_else(|| anyhow::anyhow!("--id 1..6 required"))?;
    let panel = args.get("panel").map(|s| s.to_string());
    run_fig_id(id, panel, cfg)
}

fn run_fig_id(id: usize, panel: Option<String>, cfg: &ExperimentConfig) -> Result<()> {
    match id {
        1..=4 => {
            let d = fig_dataset(id)?;
            let panels: Vec<bool> = match panel.as_deref() {
                Some("a") => vec![true],
                Some("b") => vec![false],
                _ => vec![true, false],
            };
            for with_apriori in panels {
                experiments::fig_minsup(id, d, with_apriori, cfg).finish();
            }
        }
        5 => {
            experiments::fig_cores(Dataset::Bms2, 0.001, cfg).finish();
            experiments::fig_cores(Dataset::T40I10D100K, 0.01, cfg).finish();
        }
        6 => experiments::fig_scaling(cfg).finish(),
        _ => bail!("--id must be 1..6"),
    }
    Ok(())
}

fn run_claims(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let id: usize = args
        .get_parse("id")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(3);
    match id {
        1..=4 => {
            let d = fig_dataset(id)?;
            let suite = experiments::fig_minsup(id, d, true, cfg);
            suite.finish();
            let checks = vec![
                report::check_eclat_beats_apriori(&suite),
                report::check_gap_widens(&suite),
                report::check_v45_beat_v23(&suite),
            ];
            println!("{}", report::render_claims(&checks));
        }
        5 => {
            let suite = experiments::fig_cores(Dataset::Bms2, 0.001, cfg);
            suite.finish();
            println!(
                "{}",
                report::render_claims(&[report::check_core_scaling(&suite)])
            );
        }
        6 => {
            let suite = experiments::fig_scaling(cfg);
            suite.finish();
            println!(
                "{}",
                report::render_claims(&[report::check_linear_scaling(&suite)])
            );
        }
        _ => bail!("--id must be 1..6"),
    }
    Ok(())
}

fn run_mine(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let dataset = parse_dataset(args.get_or("dataset", "t10"))?;
    let min_sup_frac: f64 = args
        .get_parse("min-sup")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(0.01);
    let variant = args.get_or("variant", "v4").to_lowercase();
    let txns = dataset.generate_scaled(cfg.seed, cfg.scale);
    let min_sup = abs_min_sup(min_sup_frac, txns.len());
    let algo = match variant.as_str() {
        "apriori" => experiments::Algo::Apriori,
        "v1" => experiments::Algo::Eclat(EclatVariant::V1),
        "v2" => experiments::Algo::Eclat(EclatVariant::V2),
        "v3" => experiments::Algo::Eclat(EclatVariant::V3),
        "v4" => experiments::Algo::Eclat(EclatVariant::V4),
        "v5" => experiments::Algo::Eclat(EclatVariant::V5),
        other => bail!("unknown variant {other}"),
    };
    println!(
        "mining {} ({} txns, scale {}) at min_sup {} ({} abs) with {} on {} cores",
        dataset.name(),
        txns.len(),
        cfg.scale,
        min_sup_frac,
        min_sup,
        algo.name(),
        cfg.cores
    );
    let (result, ms) = experiments::run_algo(algo, &txns, min_sup, dataset.tri_matrix_mode(), cfg);
    println!(
        "found {} frequent itemsets (max length {}) in {:.1} ms",
        result.len(),
        result.max_length(),
        ms
    );
    let hist = result.histogram();
    for (k, count) in hist.iter().enumerate() {
        println!("  L{}: {count}", k + 1);
    }
    Ok(())
}

/// Write a generated benchmark dataset to disk in FIMI format.
fn run_generate(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let dataset = parse_dataset(args.get_or("dataset", "t10"))?;
    let out = args.get_or("out", "dataset.txt").to_string();
    let txns = dataset.generate_scaled(
        args.get_parse::<u64>("seed")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(cfg.seed),
        cfg.scale,
    );
    rdd_eclat::data::write_transactions(&out, &txns)?;
    let stats = rdd_eclat::data::DatasetStats::compute(&txns);
    println!("wrote {out}: {stats}");
    Ok(())
}

/// Mine + derive association rules from a dataset (generated or a file
/// via --input).
fn run_rules(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    use rdd_eclat::fim::eclat::{mine_eclat_vec, EclatConfig};
    use rdd_eclat::fim::rules::generate_rules;
    use rdd_eclat::sparklet::SparkletContext;
    let txns = if let Some(path) = args.get("input") {
        rdd_eclat::data::read_transactions(path)?
    } else {
        parse_dataset(args.get_or("dataset", "t10"))?.generate_scaled(cfg.seed, cfg.scale)
    };
    let min_sup_frac: f64 = args
        .get_parse("min-sup")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(0.01);
    let min_conf: f64 = args
        .get_parse("min-conf")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(0.5);
    let top: usize = args
        .get_parse("top")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(20);
    let min_sup = abs_min_sup(min_sup_frac, txns.len());
    let sc = SparkletContext::local(cfg.cores);
    let result = mine_eclat_vec(
        &sc,
        txns.clone(),
        &EclatConfig::new(EclatVariant::V5, min_sup).with_p(cfg.p),
    );
    let rules = generate_rules(&result, min_conf, txns.len());
    println!(
        "{} itemsets, {} rules (min_sup={min_sup_frac}, min_conf={min_conf}); top {top}:",
        result.len(),
        rules.len()
    );
    for r in rules.iter().take(top) {
        println!("  {r}");
    }
    Ok(())
}

/// Micro-batch streaming mine: a generator-driven DStream of transaction
/// batches, sliding-window incremental Eclat per window, checked and
/// timed against a from-scratch re-mine of the same window.
fn run_stream(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    use rdd_eclat::fim::eclat::EclatConfig;
    use rdd_eclat::fim::streaming::{attach_checked_incremental_eclat, StreamingEclatConfig};
    use rdd_eclat::sparklet::{SparkletContext, StreamContext};

    let dataset = parse_dataset(args.get_or("dataset", "bms2"))?;
    let min_sup_frac: f64 = args
        .get_parse("min-sup")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(0.005);
    let window: usize = args
        .get_parse("window")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(4);
    let slide: usize = args
        .get_parse("slide")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(2);
    let n_batches: usize = args
        .get_parse("batches")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(10);
    let batch_size: usize = args
        .get_parse("batch-size")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(2_000);

    let min_sup = abs_min_sup(min_sup_frac, window * batch_size);
    println!(
        "streaming {}: {} batches x {} txns, window {} slide {} (batches), \
         min_sup {} ({} abs/window), {} cores",
        dataset.name(),
        n_batches,
        batch_size,
        window,
        slide,
        min_sup_frac,
        min_sup,
        cfg.cores
    );

    let sc = SparkletContext::local(cfg.cores);
    let ssc = StreamContext::new(sc.clone());
    let batch_scale = batch_size as f64 / dataset.table1_row().0 as f64;
    let seed = cfg.seed;
    let source = ssc.generator_stream(cfg.cores.max(1), move |t| {
        dataset.generate_scaled(seed.wrapping_add(t as u64).wrapping_mul(0x9E37_79B9), batch_scale)
    });

    let miner = attach_checked_incremental_eclat(
        &source,
        StreamingEclatConfig::new(min_sup, window, slide),
        EclatConfig::new(EclatVariant::V5, min_sup)
            .with_tri_matrix(dataset.tri_matrix_mode()),
        |w| {
            println!(
                "  window @t={:<3} {:>6} txns  {:>6} itemsets  incremental {:>8.1} ms  \
                 full {:>8.1} ms  ({:.1}x)",
                w.tick,
                w.n_txns,
                w.itemsets.len(),
                w.inc_ms,
                w.full_ms,
                w.full_ms / w.inc_ms.max(0.001)
            );
        },
    );
    ssc.run_batches(n_batches);

    println!("incremental miner: {}", miner.lock().unwrap().stats());
    println!("engine: {}", sc.metrics().report());
    Ok(())
}

fn xla_smoke() -> Result<()> {
    use rdd_eclat::runtime::{artifacts_dir, XlaFim};
    use rdd_eclat::util::Bitmap;
    let mut fim = XlaFim::load(&artifacts_dir())?;
    println!("PJRT platform: {}", fim.platform());
    let mut a = Bitmap::new(1000);
    let mut b = Bitmap::new(1000);
    for i in (0..1000).step_by(3) {
        a.set(i);
    }
    for i in (0..1000).step_by(5) {
        b.set(i);
    }
    let (inter, sup) = fim.intersect_batch(&[&a], &[&b])?;
    println!(
        "intersect smoke: |a|={} |b|={} |a∩b|={} (expect 67)",
        a.count(),
        b.count(),
        sup[0]
    );
    assert_eq!(sup[0], 67);
    assert_eq!(inter[0].count(), 67);
    println!("xla-smoke OK");
    Ok(())
}

fn print_help() {
    println!(
        "repro — RDD-Eclat reproduction (see README.md)\n\
         \n\
         USAGE: repro <command> [flags]\n\
         \n\
         COMMANDS:\n\
           table1                       dataset properties (Table 1)\n\
           fig --id N [--panel a|b]     regenerate figure N in 1..6\n\
           claims --id N                figure N + paper-claim checks\n\
           mine --dataset D --min-sup F --variant V   one mining run\n\
           stream --dataset D --min-sup F --window N --slide N\n\
                  --batches N --batch-size N          micro-batch sliding-window mine\n\
           xla-smoke                    verify the XLA/PJRT artifact path\n\
           all                          everything (long)\n\
         \n\
         FLAGS: --scale F  --cores N  --p N\n\
         ENV:   REPRO_SCALE REPRO_SEED REPRO_CORES REPRO_BENCH_REPS"
    );
}
