//! Wall-clock timing helpers used by the bench harness and stage metrics.

use std::time::{Duration, Instant};

/// A simple start/elapsed timer.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, duration).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let (v, d) = time(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(d >= Duration::from_millis(4));
    }

    #[test]
    fn restart_resets() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let first = t.restart();
        assert!(first >= Duration::from_millis(1));
        assert!(t.elapsed() < first + Duration::from_millis(50));
    }
}
