//! Report rendering: turn bench suites into the EXPERIMENTS.md blocks
//! and validate the paper's qualitative claims against measurements.

use crate::util::bench::BenchSuite;

/// A qualitative claim from the paper checked against a measured suite.
#[derive(Debug, Clone)]
pub struct ClaimCheck {
    pub claim: String,
    pub holds: bool,
    pub detail: String,
}

/// Claim: every Eclat variant beats RDD-Apriori at every x (Figs 1a–4a).
pub fn check_eclat_beats_apriori(suite: &BenchSuite) -> ClaimCheck {
    let mut holds = true;
    let mut worst = String::new();
    let xs: Vec<f64> = unique_xs(suite);
    for &x in &xs {
        let Some(apriori) = suite.median("RDD-Apriori", x) else {
            continue;
        };
        for v in ["EclatV1", "EclatV2", "EclatV3", "EclatV4", "EclatV5"] {
            if let Some(e) = suite.median(v, x) {
                if e >= apriori {
                    holds = false;
                    worst = format!("{v} {:.1}ms >= apriori {:.1}ms at x={x}", e, apriori);
                }
            }
        }
    }
    ClaimCheck {
        claim: "RDD-Eclat outperforms RDD-Apriori at every min_sup".into(),
        holds,
        detail: if holds {
            let speedup = average_speedup(suite);
            format!("mean speedup vs slowest variant: {speedup:.1}x")
        } else {
            worst
        },
    }
}

/// Claim: the Eclat–Apriori gap widens as min_sup decreases (§5.1).
pub fn check_gap_widens(suite: &BenchSuite) -> ClaimCheck {
    let mut xs = unique_xs(suite);
    xs.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending min_sup
    let ratios: Vec<f64> = xs
        .iter()
        .filter_map(|&x| {
            let a = suite.median("RDD-Apriori", x)?;
            let best = ["EclatV1", "EclatV4", "EclatV5"]
                .iter()
                .filter_map(|v| suite.median(v, x))
                .fold(f64::INFINITY, f64::min);
            Some(a / best)
        })
        .collect();
    let holds = ratios.len() >= 2 && ratios.last().unwrap() > ratios.first().unwrap();
    ClaimCheck {
        claim: "execution-time gap widens with decreasing min_sup".into(),
        holds,
        detail: format!("apriori/eclat ratios along sweep: {ratios:.1?}"),
    }
}

/// Claim: V4/V5 beat V2/V3 (partitioning heuristics help, §5.1).
pub fn check_v45_beat_v23(suite: &BenchSuite) -> ClaimCheck {
    let xs = unique_xs(suite);
    let mut wins = 0usize;
    let mut total = 0usize;
    for &x in &xs {
        let v45: Vec<f64> = ["EclatV4", "EclatV5"]
            .iter()
            .filter_map(|v| suite.median(v, x))
            .collect();
        let v23: Vec<f64> = ["EclatV2", "EclatV3"]
            .iter()
            .filter_map(|v| suite.median(v, x))
            .collect();
        if v45.is_empty() || v23.is_empty() {
            continue;
        }
        total += 1;
        let best45 = v45.iter().copied().fold(f64::INFINITY, f64::min);
        let best23 = v23.iter().copied().fold(f64::INFINITY, f64::min);
        if best45 < best23 {
            wins += 1;
        }
    }
    ClaimCheck {
        claim: "EclatV4/V5 improve on EclatV2/V3".into(),
        holds: total > 0 && wins * 2 > total,
        detail: format!("best(V4,V5) < best(V2,V3) at {wins}/{total} sweep points"),
    }
}

/// Claim: execution time decreases with more cores (Fig 5).
pub fn check_core_scaling(suite: &BenchSuite) -> ClaimCheck {
    let mut xs = unique_xs(suite);
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (lo, hi) = (xs[0], *xs.last().unwrap());
    let mut improved = 0usize;
    let mut total = 0usize;
    for v in ["EclatV1", "EclatV2", "EclatV3", "EclatV4", "EclatV5"] {
        if let (Some(a), Some(b)) = (suite.median(v, lo), suite.median(v, hi)) {
            total += 1;
            if b < a {
                improved += 1;
            }
        }
    }
    ClaimCheck {
        claim: format!("time decreases from {lo} to {hi} cores"),
        holds: total > 0 && improved * 2 > total,
        detail: format!("{improved}/{total} variants faster at {hi} cores"),
    }
}

/// Claim: execution time grows ~linearly with dataset size (Fig 6).
pub fn check_linear_scaling(suite: &BenchSuite) -> ClaimCheck {
    let mut worst_r = 1.0f64;
    for v in ["EclatV1", "EclatV2", "EclatV3", "EclatV4", "EclatV5"] {
        let mut pts: Vec<(f64, f64)> = suite
            .measurements()
            .iter()
            .filter(|m| m.series == v)
            .map(|m| (m.x, m.median_ms()))
            .collect();
        if pts.len() < 3 {
            continue;
        }
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let r = crate::util::stats::pearson(&xs, &ys);
        worst_r = worst_r.min(r);
    }
    ClaimCheck {
        claim: "execution time linear in dataset size".into(),
        holds: worst_r > 0.95,
        detail: format!("worst Pearson r across variants: {worst_r:.4}"),
    }
}

/// Render claim checks as a markdown block.
pub fn render_claims(checks: &[ClaimCheck]) -> String {
    let mut out = String::from("### Claim checks\n");
    for c in checks {
        out.push_str(&format!(
            "- [{}] {} — {}\n",
            if c.holds { "x" } else { " " },
            c.claim,
            c.detail
        ));
    }
    out
}

fn unique_xs(suite: &BenchSuite) -> Vec<f64> {
    let mut xs: Vec<f64> = Vec::new();
    for m in suite.measurements() {
        if !xs.iter().any(|&x| (x - m.x).abs() < 1e-12) {
            xs.push(m.x);
        }
    }
    xs
}

fn average_speedup(suite: &BenchSuite) -> f64 {
    let xs = unique_xs(suite);
    let mut ratios = Vec::new();
    for &x in &xs {
        if let Some(a) = suite.median("RDD-Apriori", x) {
            let worst_eclat = ["EclatV1", "EclatV2", "EclatV3", "EclatV4", "EclatV5"]
                .iter()
                .filter_map(|v| suite.median(v, x))
                .fold(0.0f64, f64::max);
            if worst_eclat > 0.0 {
                ratios.push(a / worst_eclat);
            }
        }
    }
    crate::util::stats::mean(&ratios)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_suite() -> BenchSuite {
        let mut s = BenchSuite::new("fake", "test").with_reps(1, 0);
        for (x, apriori, v1, v4) in [
            (0.02, 100.0, 40.0, 30.0),
            (0.01, 300.0, 60.0, 45.0),
        ] {
            s.record("RDD-Apriori", "min_sup", x, vec![apriori]);
            s.record("EclatV1", "min_sup", x, vec![v1]);
            s.record("EclatV2", "min_sup", x, vec![v1 * 1.3]);
            s.record("EclatV3", "min_sup", x, vec![v1 * 1.25]);
            s.record("EclatV4", "min_sup", x, vec![v4]);
            s.record("EclatV5", "min_sup", x, vec![v4 * 1.02]);
        }
        s
    }

    #[test]
    fn claims_hold_on_paper_shaped_data() {
        let s = fake_suite();
        assert!(check_eclat_beats_apriori(&s).holds);
        assert!(check_gap_widens(&s).holds);
        assert!(check_v45_beat_v23(&s).holds);
    }

    #[test]
    fn claims_fail_on_inverted_data() {
        let mut s = BenchSuite::new("bad", "test").with_reps(1, 0);
        s.record("RDD-Apriori", "min_sup", 0.01, vec![10.0]);
        s.record("EclatV1", "min_sup", 0.01, vec![50.0]);
        assert!(!check_eclat_beats_apriori(&s).holds);
    }

    #[test]
    fn linear_scaling_detects_linearity() {
        let mut s = BenchSuite::new("lin", "test").with_reps(1, 0);
        for v in ["EclatV1", "EclatV2", "EclatV3", "EclatV4", "EclatV5"] {
            for (x, y) in [(1.0, 10.0), (2.0, 21.0), (4.0, 39.0), (8.0, 82.0)] {
                s.record(v, "size", x, vec![y]);
            }
        }
        assert!(check_linear_scaling(&s).holds);
    }

    #[test]
    fn render_claims_markdown() {
        let out = render_claims(&[ClaimCheck {
            claim: "x".into(),
            holds: true,
            detail: "d".into(),
        }]);
        assert!(out.contains("- [x] x — d"));
    }
}
