//! `DStream<T>` — a discretized stream: one RDD per batch tick.
//!
//! A DStream is a *recipe* (`batch index -> Rdd<T>`) plus a memo of the
//! RDDs it has produced. Transformations compose recipes; nothing runs
//! until an output op (or a window / stateful child) asks for a batch.
//! Produced RDDs are `cache()`d and unpersisted once they fall behind
//! the remember horizon, which `window` widens on its parent so sliding
//! windows can union still-materialized batches.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::context::StreamContext;
use crate::sparklet::rdd::{Data, Rdd};

pub struct DStream<T: Data> {
    ssc: StreamContext,
    /// Output cadence in ticks: active at `t` iff `(t + 1) % slide == 0`.
    slide: usize,
    /// How many trailing batches stay memoized (grown by `window`).
    remember: Arc<AtomicUsize>,
    gen: Arc<dyn Fn(usize) -> Rdd<T> + Send + Sync>,
    memo: Arc<Mutex<HashMap<usize, Rdd<T>>>>,
}

impl<T: Data> Clone for DStream<T> {
    fn clone(&self) -> Self {
        Self {
            ssc: self.ssc.clone(),
            slide: self.slide,
            remember: Arc::clone(&self.remember),
            gen: Arc::clone(&self.gen),
            memo: Arc::clone(&self.memo),
        }
    }
}

impl<T: Data> DStream<T> {
    pub(crate) fn from_gen(
        ssc: StreamContext,
        slide: usize,
        gen: impl Fn(usize) -> Rdd<T> + Send + Sync + 'static,
    ) -> Self {
        Self {
            ssc,
            slide: slide.max(1),
            remember: Arc::new(AtomicUsize::new(1)),
            gen: Arc::new(gen),
            memo: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    pub fn stream_context(&self) -> &StreamContext {
        &self.ssc
    }

    /// Output cadence in ticks.
    pub fn slide_interval(&self) -> usize {
        self.slide
    }

    /// Whether this stream produces output at tick `batch`.
    pub fn is_active(&self, batch: usize) -> bool {
        (batch + 1) % self.slide == 0
    }

    /// Keep at least the last `n` batches materialized (used by windows).
    pub fn remember(&self, n: usize) {
        self.remember.fetch_max(n.max(1), Ordering::SeqCst);
    }

    /// The RDD for batch `batch` (memoized; evicted batches are
    /// regenerated deterministically from the recipe).
    pub fn rdd(&self, batch: usize) -> Rdd<T> {
        if let Some(r) = self.memo.lock().unwrap().get(&batch) {
            return r.clone();
        }
        // Generate outside the lock: window/state recipes recurse into
        // parent streams.
        let r = (self.gen)(batch).cache();
        let mut memo = self.memo.lock().unwrap();
        let horizon = self.remember.load(Ordering::SeqCst).max(1);
        let min_keep = batch.saturating_sub(horizon - 1);
        memo.retain(|&b, old| {
            if b < min_keep {
                old.unpersist();
                false
            } else {
                true
            }
        });
        memo.insert(batch, r.clone());
        r
    }

    /// Unpersist and forget every memoized batch. Call when done driving
    /// a stream inside a long-lived process: cached partitions live in
    /// the engine's `CacheManager` and are *not* freed by merely
    /// dropping the handle.
    pub fn clear(&self) {
        let mut memo = self.memo.lock().unwrap();
        for (_, r) in memo.drain() {
            r.unpersist();
        }
    }

    // ------------------------------------------------------ transformations

    pub fn map<U: Data>(&self, f: impl Fn(T) -> U + Send + Sync + 'static) -> DStream<U> {
        let parent = self.clone();
        let f = Arc::new(f);
        DStream::from_gen(self.ssc.clone(), self.slide, move |t| {
            let f = Arc::clone(&f);
            parent.rdd(t).map(move |x| f(x))
        })
    }

    pub fn flat_map<U: Data>(
        &self,
        f: impl Fn(T) -> Vec<U> + Send + Sync + 'static,
    ) -> DStream<U> {
        let parent = self.clone();
        let f = Arc::new(f);
        DStream::from_gen(self.ssc.clone(), self.slide, move |t| {
            let f = Arc::clone(&f);
            parent.rdd(t).flat_map(move |x| f(x))
        })
    }

    pub fn filter(&self, f: impl Fn(&T) -> bool + Send + Sync + 'static) -> DStream<T> {
        let parent = self.clone();
        let f = Arc::new(f);
        DStream::from_gen(self.ssc.clone(), self.slide, move |t| {
            let f = Arc::clone(&f);
            parent.rdd(t).filter(move |x| f(x))
        })
    }

    /// Arbitrary per-batch RDD-to-RDD transformation (Spark's
    /// `transform`), with the batch index for time-aware logic.
    pub fn transform<U: Data>(
        &self,
        f: impl Fn(&Rdd<T>, usize) -> Rdd<U> + Send + Sync + 'static,
    ) -> DStream<U> {
        let parent = self.clone();
        DStream::from_gen(self.ssc.clone(), self.slide, move |t| f(&parent.rdd(t), t))
    }

    /// Map each element to a key-value pair (`mapToPair`).
    pub fn map_to_pair<K: Data, V: Data>(
        &self,
        f: impl Fn(T) -> (K, V) + Send + Sync + 'static,
    ) -> DStream<(K, V)> {
        self.map(f)
    }

    /// Per-batch element counts as a single-element stream.
    pub fn count(&self) -> DStream<usize> {
        self.transform(|rdd, _| {
            let n = rdd.count();
            rdd.context().parallelize(vec![n], 1)
        })
    }

    // ------------------------------------------------------------- windows

    /// Sliding window: at each active tick (every `slide` ticks) the
    /// window RDD is the union of the parent's last `size` batches.
    /// `size` and `slide` are measured in ticks.
    pub fn window(&self, size: usize, slide: usize) -> DStream<T> {
        assert!(size >= 1, "window size must be >= 1");
        assert!(slide >= 1, "window slide must be >= 1");
        self.remember(size);
        let parent = self.clone();
        DStream::from_gen(self.ssc.clone(), slide, move |t| {
            let lo = (t + 1).saturating_sub(size);
            let mut acc: Option<Rdd<T>> = None;
            for b in lo..=t {
                // Union only the parent's *valid* batches: a parent with
                // slide > 1 (a window of windows) produces output at its
                // active ticks only — its inactive-tick RDDs are partial
                // windows that would double-count elements.
                if !parent.is_active(b) {
                    continue;
                }
                let r = parent.rdd(b);
                acc = Some(match acc {
                    None => r,
                    Some(a) => a.union(&r),
                });
            }
            acc.unwrap_or_else(|| parent.ssc.spark().parallelize(Vec::new(), 1))
        })
    }

    /// Tumbling window: non-overlapping, `window(size, size)`.
    pub fn tumbling(&self, size: usize) -> DStream<T> {
        self.window(size, size)
    }

    // -------------------------------------------------------------- outputs

    /// Register an output op: runs at every *active* tick of this stream
    /// with the batch index and that batch's RDD.
    pub fn foreach_rdd(&self, f: impl Fn(usize, &Rdd<T>) + Send + Sync + 'static) {
        let s = self.clone();
        self.ssc.register_output(Arc::new(move |t| {
            if s.is_active(t) {
                f(t, &s.rdd(t));
            }
        }));
    }

    /// Testing helper: collect every active batch (index, elements) into
    /// a shared buffer.
    pub fn collect_batches(&self) -> Arc<Mutex<Vec<(usize, Vec<T>)>>> {
        let out = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&out);
        self.foreach_rdd(move |t, rdd| sink.lock().unwrap().push((t, rdd.collect())));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparklet::SparkletContext;

    fn ssc(cores: usize) -> StreamContext {
        StreamContext::new(SparkletContext::local(cores))
    }

    #[test]
    fn map_filter_flat_map_compose_per_batch() {
        let ssc = ssc(2);
        let s = ssc
            .queue_stream(vec![vec![1u32, 2, 3], vec![4, 5]], 2)
            .map(|x| x * 10)
            .filter(|x| *x != 20)
            .flat_map(|x| vec![x, x + 1]);
        assert_eq!(s.rdd(0).collect(), vec![10, 11, 30, 31]);
        assert_eq!(s.rdd(1).collect(), vec![40, 41, 50, 51]);
    }

    #[test]
    fn sliding_window_unions_last_size_batches() {
        let ssc = ssc(2);
        let src = ssc.generator_stream(1, |t| vec![t as u32]);
        let w = src.window(3, 2);
        assert_eq!(w.slide_interval(), 2);
        // tick 1 (first active): window covers batches 0..=1
        assert!(!w.is_active(0) && w.is_active(1));
        assert_eq!(w.rdd(1).collect(), vec![0, 1]);
        // tick 3: covers batches 1..=3
        assert_eq!(w.rdd(3).collect(), vec![1, 2, 3]);
        // tick 5: covers batches 3..=5
        assert_eq!(w.rdd(5).collect(), vec![3, 4, 5]);
    }

    #[test]
    fn tumbling_windows_partition_the_stream() {
        let ssc = ssc(2);
        let src = ssc.generator_stream(1, |t| vec![t as u32]);
        let w = src.tumbling(2);
        let seen = w.collect_batches();
        ssc.run_batches(6);
        let got = seen.lock().unwrap().clone();
        assert_eq!(
            got,
            vec![(1, vec![0, 1]), (3, vec![2, 3]), (5, vec![4, 5])]
        );
    }

    #[test]
    fn window_regenerates_evicted_batches_deterministically() {
        let ssc = ssc(2);
        let src = ssc.generator_stream(1, |t| vec![t as u32 * 100]);
        let w = src.window(2, 1);
        // Access far apart so early batches get evicted, then ask again.
        assert_eq!(w.rdd(0).collect(), vec![0]);
        assert_eq!(w.rdd(9).collect(), vec![800, 900]);
        assert_eq!(w.rdd(0).collect(), vec![0]);
    }

    #[test]
    fn window_over_windowed_stream_counts_each_batch_once() {
        let ssc = ssc(2);
        let src = ssc.generator_stream(1, |t| vec![t as u32]);
        // A window of two tumbling-window outputs: the parent only emits
        // at its active ticks (1, 3, ...); partial inactive-tick windows
        // must not leak in (they would double-count batches).
        let w = src.tumbling(2).window(4, 4);
        assert!(w.is_active(3));
        let mut got = w.rdd(3).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn count_stream() {
        let ssc = ssc(2);
        let s = ssc
            .queue_stream(vec![vec![1u32, 2, 3], vec![], vec![7]], 2)
            .count();
        assert_eq!(s.rdd(0).collect(), vec![3]);
        assert_eq!(s.rdd(1).collect(), vec![0]);
        assert_eq!(s.rdd(2).collect(), vec![1]);
    }
}
