//! Dataset statistics profiler — regenerates Table 1.

use crate::fim::Transaction;

/// The properties Table 1 reports, plus extras used in DESIGN.md.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetStats {
    pub transactions: usize,
    pub distinct_items: usize,
    pub avg_width: f64,
    pub max_width: usize,
    pub max_item_id: u32,
    /// Density = avg_width / distinct_items.
    pub density: f64,
}

impl DatasetStats {
    pub fn compute(txns: &[Transaction]) -> Self {
        let transactions = txns.len();
        let mut items = std::collections::HashSet::new();
        let mut total = 0usize;
        let mut max_width = 0usize;
        let mut max_item_id = 0u32;
        for t in txns {
            total += t.len();
            max_width = max_width.max(t.len());
            for &i in t {
                items.insert(i);
                max_item_id = max_item_id.max(i);
            }
        }
        let distinct_items = items.len();
        let avg_width = if transactions == 0 {
            0.0
        } else {
            total as f64 / transactions as f64
        };
        let density = if distinct_items == 0 {
            0.0
        } else {
            avg_width / distinct_items as f64
        };
        Self {
            transactions,
            distinct_items,
            avg_width,
            max_width,
            max_item_id,
            density,
        }
    }
}

impl std::fmt::Display for DatasetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} txns, {} items, avg width {:.2}, max width {}, max id {}",
            self.transactions, self.distinct_items, self.avg_width, self.max_width, self.max_item_id
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn computes_basic_stats() {
        let txns = vec![vec![1u32, 2, 3], vec![2, 3], vec![900]];
        let s = DatasetStats::compute(&txns);
        assert_eq!(s.transactions, 3);
        assert_eq!(s.distinct_items, 4);
        assert!((s.avg_width - 2.0).abs() < 1e-12);
        assert_eq!(s.max_width, 3);
        assert_eq!(s.max_item_id, 900);
    }

    #[test]
    fn empty_is_safe() {
        let s = DatasetStats::compute(&[]);
        assert_eq!(s.transactions, 0);
        assert_eq!(s.avg_width, 0.0);
    }
}
