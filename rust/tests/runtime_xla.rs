//! End-to-end tests of the XLA/PJRT bridge: HLO-text artifacts compiled
//! by `python/compile/aot.py`, loaded and executed from rust, checked
//! against the native implementations.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a loud message) otherwise so `cargo test` works in a fresh
//! checkout.

use rdd_eclat::fim::sequential::eclat_sequential;
use rdd_eclat::fim::trimatrix::TriMatrix;
use rdd_eclat::runtime::{artifacts_available, artifacts_dir, ArtifactRegistry, XlaFim};
use rdd_eclat::util::{Bitmap, SplitMix64};

fn need_artifacts() -> bool {
    if artifacts_available() {
        true
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        false
    }
}

#[test]
fn registry_loads_and_reports_platform() {
    if !need_artifacts() {
        return;
    }
    let mut reg = ArtifactRegistry::new().unwrap();
    let art = reg.load(&artifacts_dir(), "intersect_64x256").unwrap();
    assert_eq!(art.shape, (64, 256));
    assert!(!reg.platform().is_empty());
}

#[test]
fn manifest_lists_artifacts() {
    if !need_artifacts() {
        return;
    }
    let names = ArtifactRegistry::manifest(&artifacts_dir()).unwrap();
    assert!(names.iter().any(|n| n.starts_with("intersect_")));
    assert!(names.iter().any(|n| n.starts_with("cooc_pair_")));
    assert!(names.contains(&"model".to_string()));
}

#[test]
fn intersect_batch_matches_native() {
    if !need_artifacts() {
        return;
    }
    let mut fim = XlaFim::load(&artifacts_dir()).unwrap();
    let mut rng = SplitMix64::new(0xA11CE);
    // universe larger than one word-tile to exercise word chunking:
    // 1024 words/tile = 32768 tids; use 40000
    let universe = 40_000usize;
    let n = 300usize; // > 256 rows/tile to exercise row chunking
    let make = |rng: &mut SplitMix64| {
        let mut b = Bitmap::new(universe);
        for i in 0..universe {
            if rng.gen_bool(0.05) {
                b.set(i);
            }
        }
        b
    };
    let xs: Vec<Bitmap> = (0..n).map(|_| make(&mut rng)).collect();
    let ys: Vec<Bitmap> = (0..n).map(|_| make(&mut rng)).collect();
    let xr: Vec<&Bitmap> = xs.iter().collect();
    let yr: Vec<&Bitmap> = ys.iter().collect();
    let (inter, sup) = fim.intersect_batch(&xr, &yr).unwrap();
    assert_eq!(inter.len(), n);
    for i in 0..n {
        let want = xs[i].and(&ys[i]);
        assert_eq!(inter[i], want, "row {i} bitmap mismatch");
        assert_eq!(sup[i] as usize, want.count(), "row {i} support mismatch");
    }
}

#[test]
fn intersect_batch_empty_input() {
    if !need_artifacts() {
        return;
    }
    let mut fim = XlaFim::load(&artifacts_dir()).unwrap();
    let (inter, sup) = fim.intersect_batch(&[], &[]).unwrap();
    assert!(inter.is_empty() && sup.is_empty());
}

#[test]
fn intersect_minsup_fused_matches_native() {
    if !need_artifacts() {
        return;
    }
    let mut fim = XlaFim::load(&artifacts_dir()).unwrap();
    let mut rng = SplitMix64::new(0x315EED);
    let universe = 8_192usize; // 256 words — single fused tile
    let n = 100usize;
    let make = |rng: &mut SplitMix64, d: f64| {
        let mut b = Bitmap::new(universe);
        for i in 0..universe {
            if rng.gen_bool(d) {
                b.set(i);
            }
        }
        b
    };
    let xs: Vec<Bitmap> = (0..n).map(|_| make(&mut rng, 0.1)).collect();
    let ys: Vec<Bitmap> = (0..n).map(|_| make(&mut rng, 0.1)).collect();
    let xr: Vec<&Bitmap> = xs.iter().collect();
    let yr: Vec<&Bitmap> = ys.iter().collect();
    let min_sup = 80u32;
    let (sup, mask) = fim.intersect_minsup_batch(&xr, &yr, min_sup).unwrap();
    for i in 0..n {
        let want = xs[i].and_count(&ys[i]) as u32;
        assert_eq!(sup[i], want, "row {i}");
        assert_eq!(mask[i], want >= min_sup, "row {i} mask");
    }
    // threshold is a runtime operand: re-run with a different min_sup
    let (_, mask0) = fim.intersect_minsup_batch(&xr, &yr, 0).unwrap();
    assert!(mask0.iter().all(|&m| m));
    // oversized universe is rejected, not silently wrong
    let big = Bitmap::new(64 * 1024 * 32);
    assert!(fim.intersect_minsup_batch(&[&big], &[&big], 1).is_err());
}

#[test]
fn cooc_matches_native_trimatrix() {
    if !need_artifacts() {
        return;
    }
    let mut fim = XlaFim::load(&artifacts_dir()).unwrap();
    let mut rng = SplitMix64::new(0xC00C);
    // item count above one 256-row tile to exercise block-pair sweep
    let n_items = 300usize;
    let n_txns = 3_000usize;
    // random transactions of ~8 items
    let txns: Vec<Vec<u32>> = (0..n_txns)
        .map(|_| {
            let mut t: Vec<u32> = (0..n_items as u32)
                .filter(|_| rng.gen_bool(8.0 / n_items as f64))
                .collect();
            if t.is_empty() {
                t.push(rng.gen_range(n_items) as u32);
            }
            t
        })
        .collect();
    // native matrix
    let mut native = TriMatrix::new(n_items);
    for t in &txns {
        native.update_transaction(t);
    }
    // per-item bitmaps -> xla matrix
    let mut bitmaps: Vec<Bitmap> = (0..n_items).map(|_| Bitmap::new(n_txns)).collect();
    for (tid, t) in txns.iter().enumerate() {
        for &i in t {
            bitmaps[i as usize].set(tid);
        }
    }
    let refs: Vec<&Bitmap> = bitmaps.iter().collect();
    let xla_tri = fim.cooc_tri_matrix(&refs).unwrap();
    for i in 0..n_items as u32 {
        for j in (i + 1)..n_items as u32 {
            assert_eq!(
                xla_tri.get_support(i, j),
                native.get_support(i, j),
                "pair ({i},{j})"
            );
        }
    }
}

#[test]
fn cooc_from_vertical_roundtrip() {
    if !need_artifacts() {
        return;
    }
    let mut fim = XlaFim::load(&artifacts_dir()).unwrap();
    let txns = vec![
        vec![0u32, 1, 2],
        vec![0, 1],
        vec![1, 2],
        vec![0, 2],
        vec![0, 1, 2],
    ];
    let n = txns.len();
    let mut vertical: Vec<(u32, Vec<u32>)> = Vec::new();
    for item in 0..3u32 {
        let tids: Vec<u32> = txns
            .iter()
            .enumerate()
            .filter(|(_, t)| t.contains(&item))
            .map(|(i, _)| i as u32)
            .collect();
        vertical.push((item, tids));
    }
    let tri = fim.cooc_from_vertical(&vertical, n).unwrap();
    assert_eq!(tri.get_support(0, 1), 3);
    assert_eq!(tri.get_support(0, 2), 3);
    assert_eq!(tri.get_support(1, 2), 3);
}

#[test]
fn xla_phase2_drives_full_mine() {
    // Use the XLA triangular matrix as the Phase-2 of a real mine and
    // check the itemsets equal the sequential oracle. This is the
    // "three layers compose" smoke test at the algorithm level.
    if !need_artifacts() {
        return;
    }
    let mut fim = XlaFim::load(&artifacts_dir()).unwrap();
    let db = rdd_eclat::data::Dataset::T10I4D100K.generate_scaled(11, 0.01); // 1K txns
    let n = db.len();
    let min_sup = rdd_eclat::fim::types::abs_min_sup(0.01, n);

    // vertical db over frequent items, ranked dense
    use std::collections::HashMap;
    let mut tidsets: HashMap<u32, Vec<u32>> = HashMap::new();
    for (tid, t) in db.iter().enumerate() {
        for &i in t {
            tidsets.entry(i).or_default().push(tid as u32);
        }
    }
    let mut vertical: Vec<(u32, Vec<u32>)> = tidsets
        .into_iter()
        .filter(|(_, tids)| tids.len() as u32 >= min_sup)
        .collect();
    vertical.sort_by_key(|(item, tids)| (tids.len(), *item));

    let tri = fim.cooc_from_vertical(&vertical, n).expect("xla cooc");

    // run class construction with the XLA matrix as pruning oracle
    use rdd_eclat::fim::eqclass::{bottom_up, build_classes};
    use rdd_eclat::fim::tidset::{TidOps, VecTidset};
    use rdd_eclat::fim::types::FrequentItemset;
    let rank: HashMap<u32, u32> = vertical
        .iter()
        .enumerate()
        .map(|(r, (item, _))| (*item, r as u32))
        .collect();
    let vts: Vec<(u32, VecTidset)> = vertical
        .iter()
        .map(|(item, tids)| (*item, VecTidset::from_tids(tids, n)))
        .collect();
    let mut out: Vec<FrequentItemset> = vts
        .iter()
        .map(|(item, ts)| FrequentItemset::new(vec![*item], ts.support() as u32))
        .collect();
    let mut twos = Vec::new();
    let classes = build_classes(&vts, min_sup, Some(&tri), |item| rank[&item], &mut twos);
    out.extend(twos);
    for (_, c) in &classes {
        bottom_up(c, min_sup, &mut out);
    }
    let got = rdd_eclat::fim::MiningResult::new(out);
    let oracle = eclat_sequential(&db, min_sup);
    assert!(
        got.same_as(&oracle),
        "XLA-phase2 mine: {} itemsets vs oracle {}",
        got.len(),
        oracle.len()
    );
}
