//! Stateful streaming: `updateStateByKey`.
//!
//! The state at batch `t` is a pair RDD `(K, S)` produced by cogrouping
//! the previous state with batch `t`'s records (the grouping shuffle
//! places keys with the engine's `HashPartitioner`) and applying the
//! user's update function to every key present in either. The result is
//! *checkpointed*: each batch's state is materialized on the driver and
//! re-parallelized, so state lineage stays one batch deep instead of
//! growing with the stream (Spark solves the same problem with periodic
//! RDD checkpointing).

use std::hash::Hash;
use std::sync::{Arc, Mutex};

use super::dstream::DStream;
use crate::sparklet::pair::PairRdd;
use crate::sparklet::rdd::Data;
use crate::sparklet::serde::SerDe;

/// `updateStateByKey` on pair DStreams. Keys, values, and state cross
/// the cogroup shuffle, so all three must be [`SerDe`].
pub trait StatefulDStream<K: Data + Hash + Eq + SerDe, V: Data + SerDe> {
    /// For every key with new values this batch (or existing state), call
    /// `update(new_values, previous_state)`; `None` drops the key. The
    /// returned stream emits the full state each batch.
    ///
    /// Stateful streams are forward-only: asking for a batch older than
    /// the last one computed (after its memo entry was evicted) panics,
    /// since past states are not retained.
    fn update_state_by_key<S: Data + SerDe>(
        &self,
        num_partitions: usize,
        update: impl Fn(Vec<V>, Option<S>) -> Option<S> + Send + Sync + 'static,
    ) -> DStream<(K, S)>;
}

impl<K: Data + Hash + Eq + SerDe, V: Data + SerDe> StatefulDStream<K, V> for DStream<(K, V)> {
    fn update_state_by_key<S: Data + SerDe>(
        &self,
        num_partitions: usize,
        update: impl Fn(Vec<V>, Option<S>) -> Option<S> + Send + Sync + 'static,
    ) -> DStream<(K, S)> {
        let parent = self.clone();
        let update = Arc::new(update);
        let sc = self.stream_context().spark().clone();
        let p = num_partitions.max(1);
        // (last batch applied, materialized state) — the checkpoint.
        let state: Arc<Mutex<(Option<usize>, Vec<(K, S)>)>> =
            Arc::new(Mutex::new((None, Vec::new())));
        DStream::from_gen(
            self.stream_context().clone(),
            self.slide_interval(),
            move |t| {
                let mut st = state.lock().unwrap();
                let from = match st.0 {
                    None => 0,
                    Some(last) => {
                        if t <= last {
                            assert_eq!(
                                t, last,
                                "stateful stream is forward-only: asked for batch {t}, \
                                 state already at {last}"
                            );
                            return sc.parallelize(st.1.clone(), p);
                        }
                        last + 1
                    }
                };
                for b in from..=t {
                    st.0 = Some(b);
                    // A parent with slide > 1 (e.g. a windowed pair
                    // stream) only delivers a batch at its active ticks;
                    // folding its partial inactive-tick RDDs would
                    // double-count records.
                    if !parent.is_active(b) {
                        continue;
                    }
                    let prev = sc.parallelize(st.1.clone(), p);
                    let upd = Arc::clone(&update);
                    // cogroup's grouping shuffle already places keys with
                    // the engine's HashPartitioner; the driver checkpoint
                    // collect below discards placement anyway, so an
                    // explicit re-partition here would only add a second,
                    // wasted shuffle per batch.
                    let next = prev
                        .cogroup(&parent.rdd(b))
                        .flat_map(move |(k, (states, values))| {
                            upd(values, states.into_iter().next()).map(|s| (k, s))
                        });
                    st.1 = next.collect();
                }
                sc.parallelize(st.1.clone(), p)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparklet::streaming::StreamContext;
    use crate::sparklet::SparkletContext;

    #[test]
    fn running_counts_per_key() {
        let ssc = StreamContext::new(SparkletContext::local(2));
        let batches = vec![
            vec![('a', 1u32), ('b', 1)],
            vec![('a', 1), ('a', 1)],
            vec![('c', 5)],
        ];
        let s = ssc.queue_stream(batches, 2);
        let counts = s.update_state_by_key(4, |vals: Vec<u32>, prev: Option<u32>| {
            Some(prev.unwrap_or(0) + vals.iter().sum::<u32>())
        });
        let collect_sorted = |t: usize| {
            let mut v = counts.rdd(t).collect();
            v.sort();
            v
        };
        assert_eq!(collect_sorted(0), vec![('a', 1), ('b', 1)]);
        assert_eq!(collect_sorted(1), vec![('a', 3), ('b', 1)]);
        assert_eq!(collect_sorted(2), vec![('a', 3), ('b', 1), ('c', 5)]);
    }

    #[test]
    fn returning_none_drops_keys() {
        let ssc = StreamContext::new(SparkletContext::local(2));
        let batches = vec![
            vec![("keep".to_string(), 1u32), ("drop".to_string(), 1)],
            vec![("drop".to_string(), 1)],
            vec![],
        ];
        let s = ssc.queue_stream(batches, 2);
        // Keys accumulate; any key reaching 2 is dropped.
        let st = s.update_state_by_key(2, |vals: Vec<u32>, prev: Option<u32>| {
            let total = prev.unwrap_or(0) + vals.iter().sum::<u32>();
            (total < 2).then_some(total)
        });
        let mut t1 = st.rdd(1).collect();
        t1.sort();
        assert_eq!(t1, vec![("keep".to_string(), 1)]);
        // State persists through empty batches.
        assert_eq!(st.rdd(2).collect(), vec![("keep".to_string(), 1)]);
    }

    #[test]
    fn state_over_windowed_stream_counts_each_record_once() {
        let ssc = StreamContext::new(SparkletContext::local(2));
        let src = ssc.generator_stream(1, |_| vec![('k', 1u32)]);
        // Tumbling-2 parent emits only at ticks 1, 3, ...: the state must
        // fold exactly those batches (4 records by t=3), not the partial
        // inactive-tick windows as well.
        let st = src
            .tumbling(2)
            .update_state_by_key(2, |vals: Vec<u32>, prev: Option<u32>| {
                Some(prev.unwrap_or(0) + vals.iter().sum::<u32>())
            });
        assert_eq!(st.rdd(3).collect(), vec![('k', 4)]);
    }

    #[test]
    fn state_advances_through_skipped_queries() {
        let ssc = StreamContext::new(SparkletContext::local(2));
        let s = ssc.generator_stream(1, |t| vec![('k', t as u32)]);
        let st = s.update_state_by_key(2, |vals: Vec<u32>, prev: Option<u32>| {
            Some(prev.unwrap_or(0) + vals.iter().sum::<u32>())
        });
        // Jump straight to batch 3: batches 0..=3 must all be applied.
        assert_eq!(st.rdd(3).collect(), vec![('k', 0 + 1 + 2 + 3)]);
    }
}
