//! Association-rule generation from mined frequent itemsets
//! (support/confidence/lift) — used by the `retail_rules` example; the
//! paper's motivation section frames FIM as the support step of
//! association-rule mining.

use crate::util::hash::FxHashMap;

use super::types::{Item, MiningResult};

/// An association rule `antecedent => consequent`.
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    pub antecedent: Vec<Item>,
    pub consequent: Vec<Item>,
    /// Absolute support of antecedent ∪ consequent.
    pub support: u32,
    pub confidence: f64,
    pub lift: f64,
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let a: Vec<String> = self.antecedent.iter().map(|i| i.to_string()).collect();
        let c: Vec<String> = self.consequent.iter().map(|i| i.to_string()).collect();
        write!(
            f,
            "{{{}}} => {{{}}} (sup={}, conf={:.3}, lift={:.3})",
            a.join(","),
            c.join(","),
            self.support,
            self.confidence,
            self.lift
        )
    }
}

/// Generate all rules with confidence >= `min_conf` from a mining result.
/// `n_transactions` is |D| (for lift).
pub fn generate_rules(
    result: &MiningResult,
    min_conf: f64,
    n_transactions: usize,
) -> Vec<Rule> {
    let support: FxHashMap<Vec<Item>, u32> = result
        .itemsets
        .iter()
        .map(|f| (f.items.clone(), f.support))
        .collect();
    let n = n_transactions as f64;
    let mut rules = Vec::new();
    for f in &result.itemsets {
        let k = f.items.len();
        if k < 2 {
            continue;
        }
        // Every non-empty proper subset as antecedent.
        for mask in 1u32..((1 << k) - 1) {
            let mut ante = Vec::new();
            let mut cons = Vec::new();
            for (b, &item) in f.items.iter().enumerate() {
                if mask >> b & 1 == 1 {
                    ante.push(item);
                } else {
                    cons.push(item);
                }
            }
            let Some(&ante_sup) = support.get(&ante) else {
                continue; // antecedent below min_sup: skip (anti-monotone)
            };
            let conf = f.support as f64 / ante_sup as f64;
            if conf < min_conf {
                continue;
            }
            let lift = match support.get(&cons) {
                Some(&cons_sup) if cons_sup > 0 => conf / (cons_sup as f64 / n),
                _ => f64::NAN,
            };
            rules.push(Rule {
                antecedent: ante,
                consequent: cons,
                support: f.support,
                confidence: conf,
                lift,
            });
        }
    }
    rules.sort_by(|a, b| {
        b.confidence
            .partial_cmp(&a.confidence)
            .unwrap()
            .then(b.support.cmp(&a.support))
    });
    rules
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::sequential::eclat_sequential;

    fn db() -> Vec<Vec<Item>> {
        vec![
            vec![1, 2],
            vec![1, 2],
            vec![1, 2, 3],
            vec![1, 3],
            vec![2, 3],
        ]
    }

    #[test]
    fn confidence_and_lift_correct() {
        let result = eclat_sequential(&db(), 1);
        let rules = generate_rules(&result, 0.0, 5);
        // rule {1} => {2}: sup({1,2})=3, sup({1})=4 -> conf 0.75
        let r = rules
            .iter()
            .find(|r| r.antecedent == vec![1] && r.consequent == vec![2])
            .unwrap();
        assert_eq!(r.support, 3);
        assert!((r.confidence - 0.75).abs() < 1e-12);
        // lift = conf / (sup({2})/5) = 0.75 / (4/5) = 0.9375
        assert!((r.lift - 0.9375).abs() < 1e-12);
    }

    #[test]
    fn min_conf_filters() {
        let result = eclat_sequential(&db(), 1);
        let all = generate_rules(&result, 0.0, 5);
        let high = generate_rules(&result, 0.9, 5);
        assert!(high.len() < all.len());
        assert!(high.iter().all(|r| r.confidence >= 0.9));
    }

    #[test]
    fn rules_sorted_by_confidence() {
        let result = eclat_sequential(&db(), 1);
        let rules = generate_rules(&result, 0.0, 5);
        for w in rules.windows(2) {
            assert!(w[0].confidence >= w[1].confidence);
        }
    }

    #[test]
    fn no_rules_from_single_items() {
        let result = eclat_sequential(&[vec![1], vec![2]], 1);
        assert!(generate_rules(&result, 0.0, 2).is_empty());
    }

    #[test]
    fn three_way_rules_enumerated() {
        let result = eclat_sequential(&db(), 1);
        let rules = generate_rules(&result, 0.0, 5);
        // {1,2,3} frequent (sup 1): 6 rules from the 3-itemset
        let from_triple = rules
            .iter()
            .filter(|r| r.antecedent.len() + r.consequent.len() == 3)
            .count();
        assert_eq!(from_triple, 6);
    }
}
