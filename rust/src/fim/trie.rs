//! Item trie — Borgelt's transaction-filtering structure [13] plus the
//! candidate trie used by the Apriori baseline for support counting.
//!
//! For 1-itemset filtering a set would suffice, but the trie also backs
//! (a) EclatV2/V3's broadcast `trieL1` exactly as the paper describes and
//! (b) YAFIM-style candidate subset matching, where prefix sharing is the
//! point: counting all candidate k-itemsets contained in a transaction
//! walks the trie once instead of probing each candidate.

use crate::util::hash::FxHashMap;

use super::types::Item;

/// A prefix trie over sorted itemsets.
#[derive(Debug, Clone, Default)]
pub struct ItemTrie {
    root: Node,
    len: usize,
}

#[derive(Debug, Clone, Default)]
struct Node {
    children: FxHashMap<Item, Node>,
    terminal: bool,
    /// Support counter for candidate counting (Apriori phase-2).
    count: u32,
}

impl ItemTrie {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the 1-item trie (`trieL1`) from the frequent items.
    pub fn from_items(items: impl IntoIterator<Item = Item>) -> Self {
        let mut t = Self::new();
        for i in items {
            t.insert(&[i]);
        }
        t
    }

    /// Insert a sorted itemset.
    pub fn insert(&mut self, itemset: &[Item]) {
        debug_assert!(itemset.windows(2).all(|w| w[0] < w[1]));
        let mut node = &mut self.root;
        for &i in itemset {
            node = node.children.entry(i).or_default();
        }
        if !node.terminal {
            node.terminal = true;
            self.len += 1;
        }
    }

    /// Number of stored itemsets.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exact membership of a sorted itemset.
    pub fn contains(&self, itemset: &[Item]) -> bool {
        let mut node = &self.root;
        for &i in itemset {
            match node.children.get(&i) {
                Some(n) => node = n,
                None => return false,
            }
        }
        node.terminal
    }

    /// Does the trie contain the single item? (transaction filtering).
    pub fn contains_item(&self, item: Item) -> bool {
        self.root
            .children
            .get(&item)
            .is_some_and(|n| n.terminal)
    }

    /// Borgelt transaction filtering: keep only items present (as
    /// 1-itemsets) in this trie. Preserves input order.
    pub fn filter_transaction(&self, txn: &[Item]) -> Vec<Item> {
        txn.iter()
            .copied()
            .filter(|&i| self.contains_item(i))
            .collect()
    }

    /// Increment the count of every stored itemset that is a subset of
    /// the (sorted) transaction. Recursive prefix descent: at each node
    /// try each remaining transaction item that has a child edge.
    pub fn count_subsets(&mut self, txn: &[Item]) {
        fn walk(node: &mut Node, txn: &[Item]) {
            if node.terminal {
                node.count += 1;
            }
            if node.children.is_empty() {
                return;
            }
            for (pos, &i) in txn.iter().enumerate() {
                if let Some(child) = node.children.get_mut(&i) {
                    walk(child, &txn[pos + 1..]);
                }
            }
        }
        walk(&mut self.root, txn);
    }

    /// Drain `(itemset, count)` for all stored itemsets.
    pub fn counts(&self) -> Vec<(Vec<Item>, u32)> {
        let mut out = Vec::with_capacity(self.len);
        let mut prefix = Vec::new();
        fn walk(node: &Node, prefix: &mut Vec<Item>, out: &mut Vec<(Vec<Item>, u32)>) {
            if node.terminal {
                out.push((prefix.clone(), node.count));
            }
            let mut keys: Vec<Item> = node.children.keys().copied().collect();
            keys.sort_unstable();
            for k in keys {
                prefix.push(k);
                walk(&node.children[&k], prefix, out);
                prefix.pop();
            }
        }
        walk(&self.root, &mut prefix, &mut out);
        out
    }

    /// Merge another trie's counts into this one (accumulator semantics:
    /// same candidate sets, add counts).
    pub fn merge_counts(&mut self, other: &ItemTrie) {
        fn walk(a: &mut Node, b: &Node) {
            a.count += b.count;
            for (k, bc) in &b.children {
                let ac = a.children.entry(*k).or_default();
                if bc.terminal && !ac.terminal {
                    ac.terminal = true;
                }
                walk(ac, bc);
            }
        }
        walk(&mut self.root, &other.root);
        // recompute len (cheap enough; merging is once per stage)
        self.len = self.counts().len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains() {
        let mut t = ItemTrie::new();
        t.insert(&[1, 3, 5]);
        t.insert(&[1, 3]);
        assert!(t.contains(&[1, 3, 5]));
        assert!(t.contains(&[1, 3]));
        assert!(!t.contains(&[1]));
        assert!(!t.contains(&[3, 5]));
        assert_eq!(t.len(), 2);
        t.insert(&[1, 3]); // duplicate
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn filter_transaction_keeps_frequent_order() {
        let t = ItemTrie::from_items([2, 5, 9]);
        assert_eq!(t.filter_transaction(&[1, 2, 3, 5, 8, 9]), vec![2, 5, 9]);
        assert_eq!(t.filter_transaction(&[7, 8]), Vec::<Item>::new());
        assert!(t.contains_item(5));
        assert!(!t.contains_item(1));
    }

    #[test]
    fn count_subsets_matches_bruteforce() {
        let candidates: Vec<Vec<Item>> = vec![vec![1, 2], vec![1, 3], vec![2, 3], vec![1, 2, 3]];
        let txns: Vec<Vec<Item>> = vec![vec![1, 2, 3], vec![1, 2], vec![2, 3], vec![1, 3, 4]];
        let mut t = ItemTrie::new();
        for c in &candidates {
            t.insert(c);
        }
        for txn in &txns {
            t.count_subsets(txn);
        }
        let counts: std::collections::HashMap<Vec<Item>, u32> =
            t.counts().into_iter().collect();
        for c in &candidates {
            let want = txns
                .iter()
                .filter(|txn| c.iter().all(|i| txn.contains(i)))
                .count() as u32;
            assert_eq!(counts[c], want, "candidate {c:?}");
        }
    }

    #[test]
    fn merge_counts_adds() {
        let mut a = ItemTrie::new();
        a.insert(&[1, 2]);
        let mut b = ItemTrie::new();
        b.insert(&[1, 2]);
        a.count_subsets(&[1, 2]);
        b.count_subsets(&[1, 2]);
        b.count_subsets(&[1, 2, 3]);
        a.merge_counts(&b);
        let counts = a.counts();
        assert_eq!(counts, vec![(vec![1, 2], 3)]);
    }

    #[test]
    fn counts_sorted_lexicographically() {
        let mut t = ItemTrie::new();
        t.insert(&[2]);
        t.insert(&[1]);
        t.insert(&[1, 2]);
        let sets: Vec<Vec<Item>> = t.counts().into_iter().map(|(s, _)| s).collect();
        assert_eq!(sets, vec![vec![1], vec![1, 2], vec![2]]);
    }
}
