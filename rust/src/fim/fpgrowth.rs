//! FP-Growth (Han et al. [20]) — the third baseline family of the
//! paper's related work (PFP [25], DFPS [11]).
//!
//! * [`fpgrowth_sequential`] — arena-based FP-tree with header links and
//!   the standard conditional-pattern-base recursion.
//! * [`mine_fpgrowth_rdd`] — the PFP/DFPS shape on Sparklet: frequent
//!   items by word-count, items hashed into `g` groups, mappers emit
//!   group-dependent transaction prefixes, each reducer builds a local
//!   FP-tree for its group's shard and mines only its own items, results
//!   union without duplication.

use crate::sparklet::{PairRdd, Rdd, SparkletContext};
use crate::util::hash::FxHashMap;

use super::types::{FrequentItemset, Item, MiningResult, Transaction};

// ------------------------------------------------------------- FP-tree

#[derive(Debug, Clone)]
struct Node {
    item: Item,
    count: u32,
    parent: usize,
    children: FxHashMap<Item, usize>,
}

/// Arena-allocated FP-tree with a header table of per-item node lists.
#[derive(Debug, Clone)]
pub struct FpTree {
    nodes: Vec<Node>,
    header: FxHashMap<Item, Vec<usize>>,
}

impl FpTree {
    pub fn new() -> Self {
        Self {
            nodes: vec![Node {
                item: u32::MAX,
                count: 0,
                parent: usize::MAX,
                children: FxHashMap::default(),
            }],
            header: FxHashMap::default(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.len() == 1
    }

    /// Insert a path (already filtered + sorted in tree order) with a
    /// multiplicity.
    pub fn insert(&mut self, path: &[Item], count: u32) {
        let mut cur = 0usize;
        for &item in path {
            cur = match self.nodes[cur].children.get(&item) {
                Some(&child) => {
                    self.nodes[child].count += count;
                    child
                }
                None => {
                    let idx = self.nodes.len();
                    self.nodes.push(Node {
                        item,
                        count,
                        parent: cur,
                        children: FxHashMap::default(),
                    });
                    self.nodes[cur].children.insert(item, idx);
                    self.header.entry(item).or_default().push(idx);
                    idx
                }
            };
        }
    }

    /// Total support of an item in this tree.
    fn item_support(&self, item: Item) -> u32 {
        self.header
            .get(&item)
            .map(|nodes| nodes.iter().map(|&n| self.nodes[n].count).sum())
            .unwrap_or(0)
    }

    /// Conditional pattern base of `item`: (prefix path root→parent,
    /// count) per occurrence.
    fn pattern_base(&self, item: Item) -> Vec<(Vec<Item>, u32)> {
        let mut out = Vec::new();
        if let Some(nodes) = self.header.get(&item) {
            for &n in nodes {
                let count = self.nodes[n].count;
                let mut path = Vec::new();
                let mut cur = self.nodes[n].parent;
                while cur != 0 && cur != usize::MAX {
                    path.push(self.nodes[cur].item);
                    cur = self.nodes[cur].parent;
                }
                path.reverse();
                if !path.is_empty() {
                    out.push((path, count));
                }
            }
        }
        out
    }

    /// Items present in this tree.
    fn items(&self) -> Vec<Item> {
        self.header.keys().copied().collect()
    }
}

impl Default for FpTree {
    fn default() -> Self {
        Self::new()
    }
}

/// Build a conditional FP-tree from a pattern base, keeping only items
/// with support >= min_sup, paths ordered by (support desc, item asc).
fn conditional_tree(base: &[(Vec<Item>, u32)], min_sup: u32) -> FpTree {
    let mut counts: FxHashMap<Item, u32> = FxHashMap::default();
    for (path, c) in base {
        for &i in path {
            *counts.entry(i).or_insert(0) += c;
        }
    }
    let mut tree = FpTree::new();
    for (path, c) in base {
        let mut filtered: Vec<Item> = path
            .iter()
            .copied()
            .filter(|i| counts[i] >= min_sup)
            .collect();
        filtered.sort_by_key(|i| (std::cmp::Reverse(counts[i]), *i));
        if !filtered.is_empty() {
            tree.insert(&filtered, *c);
        }
    }
    tree
}

/// The FP-Growth recursion: mine all itemsets of `tree` extended with
/// `suffix`. When `only_items` is set (PFP group mining), top-level
/// extensions are restricted to those items to avoid duplicate emission
/// across groups.
fn fp_mine(
    tree: &FpTree,
    suffix: &[Item],
    min_sup: u32,
    only_items: Option<&dyn Fn(Item) -> bool>,
    out: &mut Vec<FrequentItemset>,
) {
    let mut items = tree.items();
    items.sort_unstable();
    for item in items {
        if let Some(pred) = only_items {
            if !pred(item) {
                continue;
            }
        }
        let support = tree.item_support(item);
        if support < min_sup {
            continue;
        }
        let mut itemset = suffix.to_vec();
        itemset.push(item);
        out.push(FrequentItemset::new(itemset.clone(), support));
        let base = tree.pattern_base(item);
        if !base.is_empty() {
            let cond = conditional_tree(&base, min_sup);
            if !cond.is_empty() {
                // deeper levels are unrestricted: suffix already contains
                // a group item, so ownership is established
                fp_mine(&cond, &itemset, min_sup, None, out);
            }
        }
    }
}

/// Sequential FP-Growth.
pub fn fpgrowth_sequential(txns: &[Transaction], min_sup: u32) -> MiningResult {
    // global item counts
    let mut counts: FxHashMap<Item, u32> = FxHashMap::default();
    for t in txns {
        let mut seen = t.clone();
        seen.sort_unstable();
        seen.dedup();
        for i in seen {
            *counts.entry(i).or_insert(0) += 1;
        }
    }
    counts.retain(|_, c| *c >= min_sup);
    let mut tree = FpTree::new();
    for t in txns {
        let mut filtered: Vec<Item> = t.iter().copied().filter(|i| counts.contains_key(i)).collect();
        filtered.sort_unstable();
        filtered.dedup();
        filtered.sort_by_key(|i| (std::cmp::Reverse(counts[i]), *i));
        if !filtered.is_empty() {
            tree.insert(&filtered, 1);
        }
    }
    let mut out = Vec::new();
    fp_mine(&tree, &[], min_sup, None, &mut out);
    MiningResult::new(out)
}

// ----------------------------------------------------------- PFP on RDDs

/// Parallel FP-Growth (PFP [25] / DFPS [11] shape) on Sparklet.
/// `n_groups` is PFP's G parameter (item-group shards).
pub fn mine_fpgrowth_rdd(
    sc: &SparkletContext,
    txns: &Rdd<Transaction>,
    min_sup: u32,
    n_groups: usize,
) -> MiningResult {
    let txns = txns.cache();
    // Step 1: frequent items (word count).
    let counts: Vec<(Item, u32)> = txns
        .flat_map(|t| t)
        .map_to_pair(|i| (i, 1u32))
        .reduce_by_key(|a, b| a + b)
        .filter(move |(_, c)| *c >= min_sup)
        .collect();
    if counts.is_empty() {
        return MiningResult::default();
    }
    let count_map: FxHashMap<Item, u32> = counts.iter().copied().collect();
    let b_counts = sc.broadcast(count_map);
    let g = n_groups.max(1);

    // Step 2: group-dependent shards. For the frequency-ordered
    // transaction t, for each position j (from the tail), emit the prefix
    // t[0..=j] to group(t[j]) — at most once per group per transaction.
    let b2 = b_counts.clone();
    let shards = txns.flat_map_to_pair(move |t| {
        let counts = b2.value();
        let mut filtered: Vec<Item> = t
            .iter()
            .copied()
            .filter(|i| counts.contains_key(i))
            .collect();
        filtered.sort_unstable();
        filtered.dedup();
        filtered.sort_by_key(|i| (std::cmp::Reverse(counts[i]), *i));
        let mut out: Vec<(usize, Vec<Item>)> = Vec::new();
        let mut emitted = std::collections::HashSet::new();
        for j in (0..filtered.len()).rev() {
            let grp = (filtered[j] as usize) % g;
            if emitted.insert(grp) {
                out.push((grp, filtered[..=j].to_vec()));
            }
        }
        out
    });

    // Step 3: per-group FP-trees, mining only the group's own items at
    // the top level.
    let b3 = b_counts.clone();
    let grouped = shards.group_by_key_with_partitions(g);
    let mined = grouped.flat_map(move |(grp, paths)| {
        let counts = b3.value();
        let mut tree = FpTree::new();
        for path in &paths {
            tree.insert(path, 1);
        }
        let mut out = Vec::new();
        let owns = |item: Item| (item as usize) % g == grp && counts.contains_key(&item);
        fp_mine(&tree, &[], min_sup, Some(&owns), &mut out);
        out
    });
    MiningResult::new(mined.collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::engine::MiningSession;
    use crate::fim::sequential::eclat_sequential;
    use crate::util::prop::{forall, gen};

    /// Mine an in-memory database through the unified session API.
    fn mine_vec(sc: &SparkletContext, txns: Vec<Transaction>, min_sup: u32) -> MiningResult {
        MiningSession::new("fpgrowth")
            .min_sup(min_sup)
            .n_groups(sc.default_parallelism() * 2)
            .run_vec(sc, &txns)
            .unwrap()
            .result
    }

    fn demo_db() -> Vec<Transaction> {
        vec![
            vec![1, 2, 5],
            vec![2, 4],
            vec![2, 3],
            vec![1, 2, 4],
            vec![1, 3],
            vec![2, 3],
            vec![1, 3],
            vec![1, 2, 3, 5],
            vec![1, 2, 3],
        ]
    }

    #[test]
    fn sequential_matches_eclat_on_demo() {
        for min_sup in 1..=4u32 {
            let fp = fpgrowth_sequential(&demo_db(), min_sup);
            let ec = eclat_sequential(&demo_db(), min_sup);
            assert!(
                fp.same_as(&ec),
                "min_sup={min_sup}: fp={} eclat={}",
                fp.len(),
                ec.len()
            );
        }
    }

    #[test]
    fn tree_structure_shares_prefixes() {
        let mut tree = FpTree::new();
        tree.insert(&[1, 2, 3], 1);
        tree.insert(&[1, 2, 4], 1);
        tree.insert(&[1, 2, 3], 1);
        // nodes: root + 1,2,3,4 = 5 (prefix shared)
        assert_eq!(tree.nodes.len(), 5);
        assert_eq!(tree.item_support(1), 3);
        assert_eq!(tree.item_support(3), 2);
    }

    #[test]
    fn pattern_base_walks_to_root() {
        let mut tree = FpTree::new();
        tree.insert(&[1, 2, 3], 2);
        tree.insert(&[1, 3], 1);
        let base = tree.pattern_base(3);
        let mut got: Vec<(Vec<Item>, u32)> = base;
        got.sort();
        assert_eq!(got, vec![(vec![1], 1), (vec![1, 2], 2)]);
    }

    #[test]
    fn rdd_pfp_matches_sequential_on_demo() {
        let sc = SparkletContext::local(3);
        for min_sup in [1u32, 2, 3] {
            let got = mine_vec(&sc, demo_db(), min_sup);
            let want = fpgrowth_sequential(&demo_db(), min_sup);
            assert!(got.same_as(&want), "min_sup={min_sup}");
        }
    }

    #[test]
    fn property_fp_equals_eclat_random() {
        forall(30, gen::database(25, 8, 0.35), |db| {
            for min_sup in [1u32, 2, 3] {
                if !fpgrowth_sequential(db, min_sup).same_as(&eclat_sequential(db, min_sup)) {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn property_pfp_group_count_invariant() {
        // result must not depend on the number of groups
        let sc = SparkletContext::local(2);
        forall(12, gen::database(20, 7, 0.4), |db| {
            let want = fpgrowth_sequential(db, 2);
            for g in [1usize, 3, 8] {
                let rdd = sc.parallelize(db.clone(), 3).map(|mut t: Transaction| {
                    t.sort_unstable();
                    t.dedup();
                    t
                });
                let got = mine_fpgrowth_rdd(&sc, &rdd, 2, g);
                if !got.same_as(&want) {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn empty_db_and_high_minsup() {
        assert!(fpgrowth_sequential(&[], 1).is_empty());
        assert!(fpgrowth_sequential(&demo_db(), 100).is_empty());
        let sc = SparkletContext::local(2);
        assert!(mine_vec(&sc, demo_db(), 100).is_empty());
    }
}
