//! The upper-triangular candidate-2-itemset count matrix (Zaki [12],
//! recommended for Phase-2 of every RDD-Eclat variant).
//!
//! Counting 2-itemsets with tidset intersections is the most expensive
//! level of the lattice; one pass over the horizontal transactions into a
//! triangular matrix is far cheaper. The matrix is shared across tasks as
//! a Sparklet accumulator (elementwise-add merge), exactly the paper's
//! `accMatrix`.
//!
//! Size scales with the square of the *item-id space*, which is why the
//! paper disables it for BMS1/BMS2 (large ids) — our experiments honour
//! the same `tri_matrix_mode` flag.

use crate::sparklet::accumulator::AccumValue;

use super::types::Item;

/// Upper-triangular u32 count matrix over items `0..n` (dense ranks).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TriMatrix {
    n: usize,
    counts: Vec<u32>,
}

impl TriMatrix {
    pub fn new(n_items: usize) -> Self {
        let len = n_items * n_items.saturating_sub(1) / 2;
        Self {
            n: n_items,
            counts: vec![0; len],
        }
    }

    pub fn n_items(&self) -> usize {
        self.n
    }

    /// Memory footprint in bytes (the paper's out-of-memory guard).
    pub fn bytes(&self) -> usize {
        self.counts.len() * std::mem::size_of::<u32>()
    }

    /// Linear index of pair (i, j) with i < j < n: row-major upper
    /// triangle. Row i starts at i*n - i*(i+1)/2 - i - ... standard:
    /// idx = i*(2n - i - 1)/2 + (j - i - 1).
    #[inline]
    fn index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < j && j < self.n, "bad pair ({i},{j}) n={}", self.n);
        i * (2 * self.n - i - 1) / 2 + (j - i - 1)
    }

    /// Increment the count of the unordered pair {a, b}.
    #[inline]
    pub fn update(&mut self, a: Item, b: Item) {
        let (i, j) = if a < b {
            (a as usize, b as usize)
        } else {
            (b as usize, a as usize)
        };
        let idx = self.index(i, j);
        self.counts[idx] += 1;
    }

    /// Count every 2-combination of a (sorted, deduped) transaction.
    pub fn update_transaction(&mut self, txn: &[Item]) {
        for (x, &a) in txn.iter().enumerate() {
            for &b in &txn[x + 1..] {
                self.update(a, b);
            }
        }
    }

    /// Support of the unordered pair {a, b}.
    #[inline]
    pub fn get_support(&self, a: Item, b: Item) -> u32 {
        if a == b {
            return 0;
        }
        let (i, j) = if a < b {
            (a as usize, b as usize)
        } else {
            (b as usize, a as usize)
        };
        self.counts[self.index(i, j)]
    }

    /// Add counts from an XLA co-occurrence tile: `tile[r, c]` is the
    /// count of items `(row_base + r, col_base + c)`. Only strictly-upper
    /// pairs inside the matrix are merged.
    pub fn add_cooc_tile(
        &mut self,
        tile: &[f32],
        tile_dim: usize,
        row_base: usize,
        col_base: usize,
    ) {
        for r in 0..tile_dim {
            let gi = row_base + r;
            if gi >= self.n {
                break;
            }
            for c in 0..tile_dim {
                let gj = col_base + c;
                if gj >= self.n || gi >= gj {
                    continue;
                }
                let v = tile[r * tile_dim + c] as u32;
                if v > 0 {
                    let idx = self.index(gi, gj);
                    self.counts[idx] += v;
                }
            }
        }
    }
}

impl AccumValue for TriMatrix {
    fn merge(&mut self, other: Self) {
        assert_eq!(self.n, other.n, "triangular matrix size mismatch");
        for (a, b) in self.counts.iter_mut().zip(other.counts) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_covers_all_pairs_uniquely() {
        let n = 17;
        let m = TriMatrix::new(n);
        let mut seen = std::collections::HashSet::new();
        for i in 0..n {
            for j in (i + 1)..n {
                let idx = m.index(i, j);
                assert!(idx < m.counts.len());
                assert!(seen.insert(idx), "collision at ({i},{j})");
            }
        }
        assert_eq!(seen.len(), n * (n - 1) / 2);
    }

    #[test]
    fn update_and_get_symmetric() {
        let mut m = TriMatrix::new(5);
        m.update(3, 1);
        m.update(1, 3);
        assert_eq!(m.get_support(1, 3), 2);
        assert_eq!(m.get_support(3, 1), 2);
        assert_eq!(m.get_support(0, 4), 0);
        assert_eq!(m.get_support(2, 2), 0);
    }

    #[test]
    fn transaction_counts_match_bruteforce() {
        let txns: Vec<Vec<Item>> = vec![
            vec![0, 1, 2],
            vec![1, 2, 3],
            vec![0, 2, 3],
            vec![0, 1, 2, 3],
        ];
        let mut m = TriMatrix::new(4);
        for t in &txns {
            m.update_transaction(t);
        }
        // brute force
        for i in 0..4u32 {
            for j in (i + 1)..4u32 {
                let want = txns
                    .iter()
                    .filter(|t| t.contains(&i) && t.contains(&j))
                    .count() as u32;
                assert_eq!(m.get_support(i, j), want, "pair ({i},{j})");
            }
        }
    }

    #[test]
    fn merge_adds_elementwise() {
        let mut a = TriMatrix::new(4);
        let mut b = TriMatrix::new(4);
        a.update(0, 1);
        b.update(0, 1);
        b.update(2, 3);
        a.merge(b);
        assert_eq!(a.get_support(0, 1), 2);
        assert_eq!(a.get_support(2, 3), 1);
    }

    #[test]
    fn cooc_tile_merge() {
        // 2x2 tile at (row_base=0, col_base=0) for n=3
        let mut m = TriMatrix::new(3);
        // tile[r,c]: pair counts; diagonal ignored; lower triangle ignored
        let tile = vec![5.0f32, 2.0, 7.0, 4.0]; // (0,0)=5 (0,1)=2 (1,0)=7 (1,1)=4
        m.add_cooc_tile(&tile, 2, 0, 0);
        assert_eq!(m.get_support(0, 1), 2);
        // off-diagonal tile
        let tile2 = vec![3.0f32, 0.0, 1.0, 9.0]; // rows {0,1} x cols {2,3(, oob)}
        m.add_cooc_tile(&tile2, 2, 0, 2);
        assert_eq!(m.get_support(0, 2), 3);
        assert_eq!(m.get_support(1, 2), 1);
    }

    #[test]
    fn bytes_reflects_quadratic_growth() {
        assert!(TriMatrix::new(1000).bytes() > TriMatrix::new(100).bytes() * 50);
        assert_eq!(TriMatrix::new(0).bytes(), 0);
        assert_eq!(TriMatrix::new(1).bytes(), 0);
    }
}
