//! Clickstream analysis: the BMS_WebView scenario — sparse short
//! sessions, large item-id space (triangular matrix disabled, exactly as
//! the paper configures BMS1/BMS2), comparing all five Eclat variants
//! through the unified session API.
//!
//! Run: `cargo run --release --example clickstream`

use rdd_eclat::coordinator::experiments::eclat_roster;
use rdd_eclat::data::{BmsSpec, DatasetStats};
use rdd_eclat::fim::engine::MiningSession;
use rdd_eclat::fim::types::abs_min_sup;
use rdd_eclat::sparklet::SparkletContext;

fn main() {
    let sessions = BmsSpec::bms2().scaled(0.25).generate(7);
    let stats = DatasetStats::compute(&sessions);
    println!("clickstream: {stats}");
    println!(
        "(id space {} >> catalogue {} -> triMatrixMode=false, as in the paper)\n",
        stats.max_item_id, stats.distinct_items
    );

    let min_sup = abs_min_sup(0.001, sessions.len());
    let mut reference = None;
    for engine in eclat_roster() {
        let sc = SparkletContext::local(4);
        let report = MiningSession::new(engine)
            .min_sup(min_sup)
            .tri_matrix(false) // id space too large, per the paper
            .p(10)
            .run_vec(&sc, &sessions)
            .expect("roster engines are registered");
        println!(
            "  {:<8} {:>6} itemsets  {:>8.1} ms  (stages: {}, retries: {})",
            report.label,
            report.result.len(),
            report.wall_ms,
            report.n_stages(),
            sc.metrics().total_retries()
        );
        // all variants must agree
        match &reference {
            None => reference = Some(report.result),
            Some(r) => assert!(report.result.same_as(r), "{engine} disagrees"),
        }
    }
    println!("\nall variants produced identical itemsets ✓");
}
