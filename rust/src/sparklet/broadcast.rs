//! Broadcast variables.
//!
//! In a distributed Spark, broadcast ships one read-only copy of a value
//! to every executor instead of per-task closure capture. In-process the
//! data plane is an `Arc`, but the API (and the registry, which tracks
//! how many broadcasts a job created and their approximate size) is kept
//! so algorithm code reads like the paper's pseudo-code — e.g. EclatV2
//! broadcasts the frequent-item trie before transaction filtering.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// A read-only value shared with all tasks.
#[derive(Debug)]
pub struct Broadcast<T> {
    id: usize,
    value: Arc<T>,
}

impl<T> Clone for Broadcast<T> {
    fn clone(&self) -> Self {
        Self {
            id: self.id,
            value: Arc::clone(&self.value),
        }
    }
}

impl<T> Broadcast<T> {
    pub(crate) fn new(id: usize, value: T) -> Self {
        Self {
            id,
            value: Arc::new(value),
        }
    }

    /// Access the broadcast value (Spark's `bcast.value()`).
    pub fn value(&self) -> &T {
        &self.value
    }

    pub fn id(&self) -> usize {
        self.id
    }
}

/// Context-level registry: issues ids, tracks the count (metrics only).
#[derive(Default)]
pub struct BroadcastRegistry {
    next_id: AtomicUsize,
}

impl BroadcastRegistry {
    pub fn create<T>(&self, value: T) -> Broadcast<T> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Broadcast::new(id, value)
    }

    pub fn count(&self) -> usize {
        self.next_id.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shared_and_ids_distinct() {
        let reg = BroadcastRegistry::default();
        let a = reg.create(vec![1, 2, 3]);
        let b = reg.create("hello".to_string());
        assert_ne!(a.id(), b.id());
        assert_eq!(a.value(), &vec![1, 2, 3]);
        assert_eq!(b.value(), "hello");
        assert_eq!(reg.count(), 2);
    }

    #[test]
    fn clone_is_cheap_alias() {
        let reg = BroadcastRegistry::default();
        let a = reg.create(vec![0u8; 1024]);
        let b = a.clone();
        assert!(std::ptr::eq(a.value(), b.value()));
    }
}
