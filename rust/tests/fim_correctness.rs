//! Cross-algorithm correctness: every distributed algorithm must return
//! exactly the itemsets of the sequential oracles, across datasets,
//! supports, partitionings, and engine configurations — all driven
//! through the unified `MiningSession` API.

use rdd_eclat::data::Dataset;
use rdd_eclat::fim::engine::MiningSession;
use rdd_eclat::fim::sequential::{apriori_sequential, eclat_sequential};
use rdd_eclat::fim::types::abs_min_sup;
use rdd_eclat::sparklet::{SparkletConf, SparkletContext};

const ECLAT_ENGINES: [&str; 5] = ["eclat-v1", "eclat-v2", "eclat-v3", "eclat-v4", "eclat-v5"];

#[test]
fn variants_match_oracle_on_t10_sample() {
    let txns = Dataset::T10I4D100K.generate_scaled(42, 0.02); // 2K txns
    let min_sup = abs_min_sup(0.01, txns.len());
    let oracle = eclat_sequential(&txns, min_sup);
    assert!(!oracle.is_empty());
    let sc = SparkletContext::local(3);
    for engine in ECLAT_ENGINES {
        let got = MiningSession::new(engine)
            .min_sup(min_sup)
            .tri_matrix(true)
            .run_vec(&sc, &txns)
            .unwrap();
        assert!(got.result.same_as(&oracle), "{engine}");
    }
    let apriori = MiningSession::new("apriori")
        .min_sup(min_sup)
        .run_vec(&sc, &txns)
        .unwrap();
    assert!(apriori.result.same_as(&oracle), "rdd-apriori");
}

#[test]
fn variants_match_oracle_on_bms_sample_no_trimatrix() {
    let txns = Dataset::Bms1.generate_scaled(42, 0.05); // ~3K sessions
    let min_sup = abs_min_sup(0.002, txns.len());
    let oracle = eclat_sequential(&txns, min_sup);
    let sc = SparkletContext::local(2);
    for engine in ECLAT_ENGINES {
        let got = MiningSession::new(engine)
            .min_sup(min_sup)
            .tri_matrix(false)
            .run_vec(&sc, &txns)
            .unwrap();
        assert!(got.result.same_as(&oracle), "{engine}");
    }
}

#[test]
fn deep_itemsets_on_t40_sample() {
    // T40 has wide transactions -> deeper lattice levels; exercises the
    // recursion properly.
    let txns = Dataset::T40I10D100K.generate_scaled(1, 0.005); // 500 txns
    let min_sup = abs_min_sup(0.05, txns.len());
    let oracle = eclat_sequential(&txns, min_sup);
    assert!(
        oracle.max_length() >= 3,
        "want depth >= 3, got {}",
        oracle.max_length()
    );
    let sc = SparkletContext::local(2);
    for engine in ["eclat-v1", "eclat-v4"] {
        let got = MiningSession::new(engine)
            .min_sup(min_sup)
            .run_vec(&sc, &txns)
            .unwrap();
        assert!(got.result.same_as(&oracle), "{engine}");
    }
    let apriori = apriori_sequential(&txns, min_sup);
    assert!(apriori.same_as(&oracle));
}

#[test]
fn result_invariant_to_cores_and_partitions() {
    let txns = Dataset::T10I4D100K.generate_scaled(9, 0.01);
    let min_sup = abs_min_sup(0.01, txns.len());
    let base = eclat_sequential(&txns, min_sup);
    for cores in [1usize, 2, 7] {
        let sc = SparkletContext::local(cores);
        for p in [1usize, 3, 16] {
            let got = MiningSession::new("eclat-v5")
                .min_sup(min_sup)
                .p(p)
                .run_vec(&sc, &txns)
                .unwrap();
            assert!(got.result.same_as(&base), "cores={cores} p={p}");
        }
    }
}

#[test]
fn mining_survives_failure_injection() {
    // Lineage recovery must not corrupt results. NOTE: accumulators can
    // double-count under retries (documented Spark caveat), so inject
    // failures only with triMatrixMode=false (no accumulator on the
    // Phase-2 path) and V2 (groupByKey vertical rather than hashmap
    // accumulator).
    let txns = Dataset::T10I4D100K.generate_scaled(3, 0.01);
    let min_sup = abs_min_sup(0.02, txns.len());
    let oracle = eclat_sequential(&txns, min_sup);
    let conf = SparkletConf::new("faulty-mine")
        .with_cores(4)
        .unwrap()
        .with_failure_injection(0.3, 777)
        .with_max_task_failures(8);
    let sc = SparkletContext::new(conf);
    let got = MiningSession::new("eclat-v2")
        .min_sup(min_sup)
        .tri_matrix(false)
        .run_vec(&sc, &txns)
        .unwrap();
    assert!(got.result.same_as(&oracle));
    assert!(
        sc.metrics().total_retries() > 0,
        "injection should have fired"
    );
}

#[test]
fn apriori_survives_failure_injection() {
    let txns = Dataset::T10I4D100K.generate_scaled(5, 0.005);
    let min_sup = abs_min_sup(0.02, txns.len());
    let oracle = apriori_sequential(&txns, min_sup);
    let conf = SparkletConf::new("faulty-apriori")
        .with_cores(3)
        .unwrap()
        .with_failure_injection(0.3, 999)
        .with_max_task_failures(8);
    let sc = SparkletContext::new(conf);
    let got = MiningSession::new("apriori")
        .min_sup(min_sup)
        .run_vec(&sc, &txns)
        .unwrap();
    assert!(got.result.same_as(&oracle));
}

#[test]
fn file_roundtrip_mine() {
    // write -> textFile -> MiningSession::run on the lines RDD == oracle
    use rdd_eclat::data::write_transactions;
    use rdd_eclat::fim::eclat::transactions_from_lines;
    let txns = Dataset::Bms2.generate_scaled(8, 0.01);
    let min_sup = abs_min_sup(0.005, txns.len());
    let dir = std::env::temp_dir().join("rdd_eclat_file_mine");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("db.txt");
    write_transactions(path.to_str().unwrap(), &txns).unwrap();
    let sc = SparkletContext::local(2);
    let lines = sc.text_file(path.to_str().unwrap(), 2).unwrap();
    let rdd = transactions_from_lines(&lines);
    let got = MiningSession::new("eclat-v3")
        .min_sup(min_sup)
        .tri_matrix(false)
        .run(&sc, &rdd)
        .unwrap();
    assert!(got.result.same_as(&eclat_sequential(&txns, min_sup)));
}

#[test]
fn supports_are_exact_counts() {
    // spot-check supports against brute-force membership counting
    let txns = Dataset::T10I4D100K.generate_scaled(2, 0.005);
    let min_sup = abs_min_sup(0.02, txns.len());
    let sc = SparkletContext::local(2);
    let got = MiningSession::new("eclat-v4")
        .min_sup(min_sup)
        .run_vec(&sc, &txns)
        .unwrap();
    for f in got.result.itemsets.iter().take(50) {
        let brute = txns
            .iter()
            .filter(|t| f.items.iter().all(|i| t.binary_search(i).is_ok()))
            .count() as u32;
        assert_eq!(f.support, brute, "itemset {:?}", f.items);
    }
}
