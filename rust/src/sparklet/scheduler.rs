//! DAG scheduler: stage splitting at shuffle boundaries, task-set
//! submission to the pluggable executor backend, retries from lineage,
//! failure injection.
//!
//! A job is: (target RDD, per-partition result function). Execution:
//!  1. Walk the dependency DAG; for every incomplete shuffle dependency
//!     (post-order, so grandparents first) run its *map stage* — one task
//!     per parent partition — then mark the shuffle complete.
//!  2. Run the *result stage*: one task per target partition applying the
//!     result function.
//! Each stage becomes a [`TaskSet`] submitted to the context's
//! [`ExecutorBackend`](super::executor::ExecutorBackend); the returned
//! `JobHandle` is awaited and its steal/queue-wait counters land in the
//! stage's [`StageMetrics`]. Task failures (panics or injected faults)
//! are retried up to `max_task_failures` times; because `compute` is
//! pure over lineage, a retry recomputes exactly what was lost —
//! Spark's recovery model.

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use super::context::SparkletContext;
use super::events::SparkletEvent;
use super::executor::{panic_message, TaskSet};
use super::faults::{FaultSite, RetryError, RetryPolicy};
use super::metrics::{StageKind, StageMetrics};
use super::pair::ShuffleDepObj;
use super::rdd::{materialize, Data, Dep, DepNode, Rdd, TaskContext};
use super::shuffle::LocalBlockFetcher;
use super::transport::{TaskDescriptor, TaskEnv, TaskRegistry};

/// Deterministic fault-injection coin: should task (stage_tag, part,
/// attempt) fail? Only first attempts fail so jobs always converge.
fn injected_failure(ctx: &SparkletContext, stage_tag: u64, part: usize, attempt: usize) -> bool {
    let rate = ctx.conf().task_failure_rate;
    if rate <= 0.0 || attempt > 0 {
        return false;
    }
    let mut rng = crate::util::SplitMix64::new(
        ctx.conf()
            .failure_seed
            .wrapping_add(stage_tag)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(part as u64),
    );
    rng.gen_bool(rate)
}

/// Run a set of per-partition tasks with retry-from-lineage. `run` must be
/// safe to re-execute for the same partition.
fn run_stage<U: Send + 'static>(
    ctx: &SparkletContext,
    job_id: u64,
    kind: StageKind,
    rdd_id: usize,
    stage_tag: u64,
    num_tasks: usize,
    run: Arc<dyn Fn(usize, usize) -> U + Send + Sync>,
) -> Vec<U> {
    let wall = Instant::now();
    ctx.events().emit(SparkletEvent::StageSubmitted {
        job_id,
        stage_tag,
        kind,
        name: format!("{kind:?}/rdd{rdd_id}"),
        num_tasks,
    });
    // Snapshot shuffle-volume counters so the stage records its delta
    // (the driver runs stages sequentially, so deltas don't interleave).
    let records_before = ctx.shuffle_manager().records_written();
    let bytes_before = ctx.shuffle_manager().bytes_written();
    let spilled_before = ctx.shuffle_manager().spilled_blocks();
    let mut results: Vec<Option<U>> = (0..num_tasks).map(|_| None).collect();
    let mut task_millis = vec![0.0f64; num_tasks];
    let mut pending: Vec<usize> = (0..num_tasks).collect();
    let mut retries = 0usize;
    let mut steals = 0usize;
    let mut queue_wait_ms = 0.0f64;
    let max_attempts = ctx.conf().max_task_failures;
    let policy = RetryPolicy::new(
        max_attempts as u32,
        ctx.conf().retry_backoff_ms,
        ctx.conf().job_deadline_ms,
    );
    let started = Instant::now();
    let mut deadline_hit: Option<RetryError> = None;
    let mut last_error = String::new();

    for attempt in 0..max_attempts {
        if pending.is_empty() {
            break;
        }
        if attempt > 0 {
            if let Err(e) = policy.check_deadline(started) {
                deadline_hit = Some(e);
                break;
            }
            std::thread::sleep(policy.backoff(attempt as u32));
        }
        // Build the stage's task set. Each task catches its own panic
        // and reports `(partition, outcome)` through the channel; the
        // executor only has to run the closures.
        let mut taskset = TaskSet::new(stage_tag, format!("{kind:?}/rdd{rdd_id}/attempt{attempt}"));
        let (tx, rx) = channel::<(usize, Result<(U, f64), String>)>();
        for &part in &pending {
            let run = Arc::clone(&run);
            let ctx2 = ctx.clone();
            let tx = tx.clone();
            taskset.push(move || {
                // Task spans are emitted from inside the closure, i.e.
                // on whichever executor backend thread runs it — every
                // backend traces the same way for free.
                ctx2.events().emit(SparkletEvent::TaskStart {
                    job_id,
                    stage_tag,
                    task: part,
                    attempt,
                    worker: None,
                });
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if injected_failure(&ctx2, stage_tag, part, attempt) {
                        panic!("injected task failure (stage {stage_tag}, part {part})");
                    }
                    if ctx2.faults().should_fail(FaultSite::TaskPanic) {
                        panic!("injected task_panic fault (stage {stage_tag}, part {part})");
                    }
                    let t = Instant::now();
                    let out = run(part, attempt);
                    (out, t.elapsed().as_secs_f64() * 1e3)
                }))
                .map_err(|e| panic_message(e.as_ref()));
                ctx2.events().emit(SparkletEvent::TaskEnd {
                    job_id,
                    stage_tag,
                    task: part,
                    attempt,
                    ok: outcome.is_ok(),
                    run_ms: outcome.as_ref().map(|(_, ms)| *ms).unwrap_or(0.0),
                    worker: None,
                });
                let _ = tx.send((part, outcome));
            });
        }
        drop(tx);
        let handle = ctx.executor().submit(taskset);
        let stats = handle.wait();
        steals += stats.steals;
        queue_wait_ms += stats.queue_wait_ms;

        let mut outcomes: HashMap<usize, Result<(U, f64), String>> = rx.try_iter().collect();
        let mut still_pending = Vec::new();
        for &part in &pending {
            match outcomes
                .remove(&part)
                .unwrap_or_else(|| Err("executor dropped the task's result".into()))
            {
                Ok((out, ms)) => {
                    results[part] = Some(out);
                    task_millis[part] = ms;
                }
                Err(msg) => {
                    log::warn!("task {part} failed (attempt {attempt}): {msg}");
                    retries += 1;
                    last_error = msg;
                    still_pending.push(part);
                }
            }
        }
        pending = still_pending;
    }

    if !pending.is_empty() {
        // run_stage serves closure-typed public APIs (`collect` et al.)
        // whose signatures can't carry a Result; the typed error rides
        // the panic payload and is re-typed at the engine boundary
        // (`MiningSession::run_*` catches it into `FimError`).
        let err = deadline_hit.unwrap_or_else(|| {
            policy.exhausted(format!("partitions {pending:?}: {last_error}"))
        });
        panic!("stage {stage_tag:x} failed: {err}");
    }

    // StageCompleted always goes out; whether it lands in the metrics
    // registry depends on whether `collect_metrics` subscribed the
    // MetricsListener at context build. The flush makes the registry
    // update visible before run_stage returns (synchronous readers like
    // the partition-cost model depend on that).
    ctx.events().emit(SparkletEvent::StageCompleted {
        job_id,
        stage_tag,
        metrics: StageMetrics {
            kind,
            rdd_id,
            num_tasks,
            wall: wall.elapsed(),
            task_millis,
            retries,
            shuffle_records: ctx.shuffle_manager().records_written() - records_before,
            shuffle_bytes: ctx.shuffle_manager().bytes_written() - bytes_before,
            spilled_blocks: ctx.shuffle_manager().spilled_blocks() - spilled_before,
            backend: ctx.executor().name(),
            steals,
            queue_wait_ms,
        },
    });
    ctx.events().flush();

    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Recursively ensure every shuffle dependency reachable from `node` has
/// completed its map stage (running grandparent shuffles first).
fn ensure_shuffles(
    ctx: &SparkletContext,
    job_id: u64,
    node: &Arc<dyn DepNode>,
    visited: &mut HashSet<usize>,
) {
    if !visited.insert(node.node_id()) {
        return;
    }
    for dep in node.node_deps() {
        match dep {
            Dep::Narrow(parent) => ensure_shuffles(ctx, job_id, &parent, visited),
            Dep::Shuffle(sd) => {
                let mgr = ctx.shuffle_manager();
                if mgr.is_completed(sd.shuffle_id()) {
                    continue;
                }
                // Parents of the map stage first.
                let parent = sd.parent_node();
                ensure_shuffles(ctx, job_id, &parent, visited);
                run_map_stage(ctx, job_id, &sd);
            }
        }
    }
}

fn run_map_stage(ctx: &SparkletContext, job_id: u64, sd: &Arc<dyn ShuffleDepObj>) {
    let mgr = ctx.shuffle_manager();
    // Clear any partial output from a previous failed run of this stage.
    mgr.clear_shuffle(sd.shuffle_id());
    let n = sd.num_map_partitions();
    let sd2 = Arc::clone(sd);
    let ctx2 = ctx.clone();
    let stage_tag = 0x5A5A_0000u64 ^ sd.shuffle_id() as u64;
    run_stage::<()>(
        ctx,
        job_id,
        StageKind::ShuffleMap,
        usize::MAX,
        stage_tag,
        n,
        Arc::new(move |part, attempt| {
            let tc = TaskContext::new(part, attempt, ctx2.clone());
            sd2.run_map_task(part, &tc);
        }),
    );
    mgr.mark_completed(sd.shuffle_id());
}

/// Find the shuffle dependency directly feeding `node`. The described
/// runner's target is always the output of `partition_by`, so one hop
/// is enough — no recursive walk.
fn direct_shuffle_dep(node: &Arc<dyn DepNode>) -> Option<Arc<dyn ShuffleDepObj>> {
    node.node_deps().into_iter().find_map(|dep| match dep {
        Dep::Shuffle(sd) => Some(sd),
        Dep::Narrow(_) => None,
    })
}

/// Run a job whose result stage is a *described* task set: instead of
/// in-memory `Fn` captures, each task is a [`TaskDescriptor`] — stage
/// identity + a [`TaskRegistry`] key + a serialized partition spec —
/// that a worker in another process can execute against shuffle blocks
/// fetched over the transport.
///
/// `rdd` must sit directly on a shuffle boundary (a `partition_by`
/// output): its map stages run on the driver as usual, then one
/// descriptor per reduce partition is built with
/// `payload(shuffle_id, part)` and submitted. On a backend without
/// remote dispatch (`supports_described() == false`) each descriptor is
/// degraded to a driver-local closure running the same registry entry
/// against the driver's own block store — identical semantics, one
/// process.
///
/// Failure handling follows `run_stage`: a lost worker fails its
/// in-flight descriptors, which land back in `pending` and are
/// re-dispatched (to surviving workers) on the next attempt. Map output
/// lives in the driver's store, so a worker death never loses map
/// stages — lineage re-execution is only needed when the *driver*
/// retries a map task, which the existing path already covers.
///
/// Retry exhaustion and per-job deadline overrun surface as typed
/// [`RetryError`]s (the stage/job spans still close, so event streams
/// stay balanced for replay).
pub fn run_described_job<T: Data>(
    ctx: &SparkletContext,
    rdd: &Rdd<T>,
    key: &str,
    payload: impl Fn(usize, usize) -> Vec<u8>,
) -> Result<Vec<Vec<u8>>, RetryError> {
    let job_id = ctx.events().next_job_id();
    ctx.events().emit(SparkletEvent::JobStart { job_id });

    let node = rdd.as_node();
    let mut visited = HashSet::new();
    ensure_shuffles(ctx, job_id, &node, &mut visited);
    let sd = direct_shuffle_dep(&node)
        .expect("run_described_job target must sit directly on a shuffle boundary");
    let shuffle_id = sd.shuffle_id();

    let kind = StageKind::Result;
    let stage_tag = 0xA11C_0000u64 ^ rdd.id() as u64;
    let num_tasks = rdd.num_partitions();
    let wall = Instant::now();
    ctx.events().emit(SparkletEvent::StageSubmitted {
        job_id,
        stage_tag,
        kind,
        name: format!("Described/{key}/rdd{}", rdd.id()),
        num_tasks,
    });
    let records_before = ctx.shuffle_manager().records_written();
    let bytes_before = ctx.shuffle_manager().bytes_written();
    let spilled_before = ctx.shuffle_manager().spilled_blocks();
    let mut results: Vec<Option<Vec<u8>>> = (0..num_tasks).map(|_| None).collect();
    let mut task_millis = vec![0.0f64; num_tasks];
    let mut pending: Vec<usize> = (0..num_tasks).collect();
    let mut retries = 0usize;
    let mut steals = 0usize;
    let mut queue_wait_ms = 0.0f64;
    let max_attempts = ctx.conf().max_task_failures;
    let remote = ctx.executor().supports_described();
    let policy = RetryPolicy::new(
        max_attempts as u32,
        ctx.conf().retry_backoff_ms,
        ctx.conf().job_deadline_ms,
    );
    let started = Instant::now();
    let mut deadline_hit: Option<RetryError> = None;
    let mut last_error = String::new();

    for attempt in 0..max_attempts {
        if pending.is_empty() {
            break;
        }
        if attempt > 0 {
            if let Err(e) = policy.check_deadline(started) {
                deadline_hit = Some(e);
                break;
            }
            std::thread::sleep(policy.backoff(attempt as u32));
        }
        let mut taskset = TaskSet::new(stage_tag, format!("Described/{key}/attempt{attempt}"));
        let (tx, rx) = channel::<(usize, Result<(Vec<u8>, f64), String>)>();
        for &part in &pending {
            let desc = TaskDescriptor {
                job_id,
                stage_tag,
                part,
                attempt,
                key: key.to_string(),
                payload: payload(shuffle_id, part),
            };
            let tx = tx.clone();
            if remote {
                // The backend owns dispatch and emits the task spans
                // (with worker ids) from its driver-side event loop.
                taskset.push_described(
                    desc,
                    Box::new(move |res, ms| {
                        let _ = tx.send((part, res.map(|bytes| (bytes, ms))));
                    }),
                );
            } else {
                // Degrade to a driver-local closure over the same
                // registry entry — the in-process oracle for the
                // multi-process path.
                let ctx2 = ctx.clone();
                taskset.push(move || {
                    ctx2.events().emit(SparkletEvent::TaskStart {
                        job_id,
                        stage_tag,
                        task: part,
                        attempt,
                        worker: None,
                    });
                    let outcome = catch_unwind(AssertUnwindSafe(|| {
                        if injected_failure(&ctx2, stage_tag, part, attempt) {
                            panic!("injected task failure (stage {stage_tag}, part {part})");
                        }
                        if ctx2.faults().should_fail(FaultSite::TaskPanic) {
                            panic!("injected task_panic fault (stage {stage_tag}, part {part})");
                        }
                        let t = Instant::now();
                        let fetcher = LocalBlockFetcher::new(ctx2.shuffle_arc());
                        let env = TaskEnv::new(&fetcher);
                        TaskRegistry::run(&desc, &env)
                            .map(|bytes| (bytes, t.elapsed().as_secs_f64() * 1e3))
                    }))
                    .map_err(|e| panic_message(e.as_ref()))
                    .and_then(|r| r);
                    ctx2.events().emit(SparkletEvent::TaskEnd {
                        job_id,
                        stage_tag,
                        task: part,
                        attempt,
                        ok: outcome.is_ok(),
                        run_ms: outcome.as_ref().map(|(_, ms)| *ms).unwrap_or(0.0),
                        worker: None,
                    });
                    let _ = tx.send((part, outcome));
                });
            }
        }
        drop(tx);
        let handle = ctx.executor().submit(taskset);
        let stats = handle.wait();
        steals += stats.steals;
        queue_wait_ms += stats.queue_wait_ms;

        let mut outcomes: HashMap<usize, Result<(Vec<u8>, f64), String>> = rx.try_iter().collect();
        let mut still_pending = Vec::new();
        for &part in &pending {
            match outcomes
                .remove(&part)
                .unwrap_or_else(|| Err("executor dropped the task's result".into()))
            {
                Ok((out, ms)) => {
                    results[part] = Some(out);
                    task_millis[part] = ms;
                }
                Err(msg) => {
                    log::warn!("described task {part} failed (attempt {attempt}): {msg}");
                    retries += 1;
                    last_error = msg;
                    still_pending.push(part);
                }
            }
        }
        pending = still_pending;
    }

    let failure = if pending.is_empty() {
        None
    } else {
        Some(deadline_hit.unwrap_or_else(|| {
            policy.exhausted(format!("partitions {pending:?}: {last_error}"))
        }))
    };

    ctx.events().emit(SparkletEvent::StageCompleted {
        job_id,
        stage_tag,
        metrics: StageMetrics {
            kind,
            rdd_id: rdd.id(),
            num_tasks,
            wall: wall.elapsed(),
            task_millis,
            retries,
            shuffle_records: ctx.shuffle_manager().records_written() - records_before,
            shuffle_bytes: ctx.shuffle_manager().bytes_written() - bytes_before,
            spilled_blocks: ctx.shuffle_manager().spilled_blocks() - spilled_before,
            backend: ctx.executor().name(),
            steals,
            queue_wait_ms,
        },
    });
    ctx.events().emit(SparkletEvent::JobEnd { job_id });
    ctx.events().flush();

    match failure {
        Some(err) => Err(err),
        None => Ok(results.into_iter().map(|r| r.unwrap()).collect()),
    }
}

/// Entry point used by all actions.
pub fn run_job<T: Data, U: Send + 'static>(
    ctx: &SparkletContext,
    rdd: &Rdd<T>,
    func: impl Fn(usize, Vec<T>) -> U + Send + Sync + 'static,
) -> Vec<U> {
    // One job span per action; map stages nest inside it.
    let job_id = ctx.events().next_job_id();
    ctx.events().emit(SparkletEvent::JobStart { job_id });

    // Stage 0..k-1: shuffle map stages in dependency order.
    let node = rdd.as_node();
    let mut visited = HashSet::new();
    ensure_shuffles(ctx, job_id, &node, &mut visited);

    // Result stage.
    let base = Arc::clone(&rdd.base);
    let ctx2 = ctx.clone();
    let func = Arc::new(func);
    let stage_tag = 0xA11C_0000u64 ^ rdd.id() as u64;
    let out = run_stage(
        ctx,
        job_id,
        StageKind::Result,
        rdd.id(),
        stage_tag,
        rdd.num_partitions(),
        Arc::new(move |part, attempt| {
            let tc = TaskContext::new(part, attempt, ctx2.clone());
            let data = materialize(&base, part, &tc);
            func(part, data)
        }),
    );
    ctx.events().emit(SparkletEvent::JobEnd { job_id });
    ctx.events().flush();
    out
}
