//! Offline shim for the `anyhow` crate (the build has no registry
//! access). Provides exactly the subset this repository uses: `Error`,
//! `Result`, `Context` (on both `Result` and `Option`), and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a flat,
//! context-prefixed message rather than a source chain — enough for the
//! CLI's error reporting. Replace with the real crates.io `anyhow` by
//! editing `rust/Cargo.toml`; no source changes are needed.

use std::fmt;

/// A flat error message with accumulated context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Prefix the message with additional context.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Self {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug mirrors Display so `fn main() -> anyhow::Result<()>` prints the
// readable message, as the real anyhow does.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`,
// which is what makes this blanket conversion coherent (same trick as
// the real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn conversion_and_context() {
        let e = io_fail().context("reading config").unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        let e2: Result<u32> = None.with_context(|| format!("missing {}", "x"));
        assert_eq!(e2.unwrap_err().to_string(), "missing x");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(anyhow!("plain").to_string(), "plain");
    }
}
