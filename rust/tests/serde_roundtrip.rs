//! Property tests for the shuffle SerDe codec: random values roundtrip
//! bit-exactly (including empty/huge vectors and non-ASCII strings),
//! block framing sizes are exact, and corrupt bytes decode to typed
//! errors, never panics or silent garbage.

use rdd_eclat::sparklet::serde::{decode_records, encode_records, SerDe};
use rdd_eclat::util::prop::{forall, gen};
use rdd_eclat::util::SplitMix64;

fn roundtrip<T: SerDe + PartialEq + std::fmt::Debug>(v: &T) -> bool {
    match T::from_bytes(&v.to_bytes()) {
        Ok(back) => back == *v,
        Err(_) => false,
    }
}

#[test]
fn prop_random_scalars_roundtrip() {
    forall(
        200,
        |r: &mut SplitMix64| (r.next_u64(), r.next_u64() as u32, r.next_u64() as u8),
        |t| {
            let (a, b, c) = *t;
            roundtrip(&a)
                && roundtrip(&b)
                && roundtrip(&c)
                && roundtrip(&(a as i64))
                && roundtrip(&(f64::from_bits(a & !(0x7FFu64 << 52)))) // finite
                && roundtrip(&(a % 2 == 0))
        },
    );
}

#[test]
fn prop_random_vecs_roundtrip_including_empty() {
    forall(
        60,
        gen::vec_of(0, 300, |r| (r.next_u64() as u32, r.next_u64())),
        |v: &Vec<(u32, u64)>| roundtrip(v),
    );
    // degenerate + huge
    assert!(roundtrip(&Vec::<u32>::new()));
    assert!(roundtrip(&vec![Vec::<u64>::new(); 17]));
    let huge: Vec<u32> = (0..200_000).collect();
    assert!(roundtrip(&huge));
}

#[test]
fn prop_random_strings_roundtrip_including_non_ascii() {
    // Random scalar values mapped into chars cover multi-byte UTF-8.
    forall(
        80,
        gen::vec_of(0, 64, |r| {
            char::from_u32((r.next_u64() % 0x2_FFFF) as u32).unwrap_or('\u{FFFD}')
        }),
        |chars: &Vec<char>| {
            let s: String = chars.iter().collect();
            roundtrip(&s) && roundtrip(&Some(s.clone())) && roundtrip(&vec![s])
        },
    );
    assert!(roundtrip(&"汉字 🚀 κόσμος ñ".to_string()));
    assert!(roundtrip(&String::new()));
}

#[test]
fn prop_record_blocks_roundtrip_with_exact_framing() {
    forall(
        40,
        gen::vec_of(0, 200, |r| {
            let n = r.gen_range(8);
            let tids: Vec<u32> = (0..n as u32).map(|i| i * 7).collect();
            (r.next_u64() as u32, tids)
        }),
        |recs: &Vec<(u32, Vec<u32>)>| {
            let block = encode_records(recs);
            // exact framing: count header + per-record frame + payload
            let expected =
                8 + recs.iter().map(|x| 4 + x.to_bytes().len()).sum::<usize>();
            block.len() == expected
                && decode_records::<(u32, Vec<u32>)>(&block).as_ref() == Ok(recs)
        },
    );
}

#[test]
fn prop_corrupted_blocks_fail_typed_never_panic() {
    forall(
        60,
        |r: &mut SplitMix64| {
            let recs: Vec<(u32, u64)> = (0..1 + r.gen_range(20))
                .map(|_| (r.next_u64() as u32, r.next_u64()))
                .collect();
            let mut block = encode_records(&recs);
            // flip one random byte (or truncate) somewhere in the block
            if r.gen_bool(0.3) {
                let cut = r.gen_range(block.len());
                block.truncate(cut);
            } else {
                let at = r.gen_range(block.len());
                block[at] ^= 0x41;
            }
            block
        },
        |block: &Vec<u8>| {
            // Decoding corrupt bytes must return (anything) without
            // panicking; when it "succeeds" the frame checks made sure
            // the bytes were still structurally coherent.
            let _ = decode_records::<(u32, u64)>(block);
            true
        },
    );
}

#[test]
fn fim_record_types_roundtrip() {
    use rdd_eclat::fim::types::FrequentItemset;
    let f = FrequentItemset::new(vec![3, 1, 2], 5);
    let back = FrequentItemset::from_bytes(&f.to_bytes()).unwrap();
    assert_eq!(back, f);
    // transactions are plain Vec<u32>
    let t: Vec<u32> = vec![1, 5, 9];
    assert!(roundtrip(&t));
}
