//! Serialized block shuffle — the wide-dependency data plane.
//!
//! Map tasks partition their output into `num_reduce` buckets, serialize
//! each bucket through the [`super::serde`] codec, and register the
//! resulting byte block here; reduce tasks fetch and deserialize the
//! blocks for their partition. Payloads crossing a stage boundary are
//! **owned bytes** — no `Arc<dyn Any>` sharing — which makes
//! `bytes_written` exact (serialized sizes, not `size_of` estimates),
//! lets the [`BlockStore`] spill cold blocks to disk under a memory
//! budget, and is the stepping stone to a multi-process executor
//! backend (a block is already transport-ready).
//!
//! Fetching a shuffle whose map stage has not been marked completed is a
//! typed [`ShuffleError::MapStageIncomplete`] — a scheduler ordering bug
//! fails loudly instead of reading as "zero records".

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::block::{BlockId, BlockIoError, BlockStore, ShuffleBlock};
use super::faults::FaultPlane;

/// Typed shuffle failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShuffleError {
    /// A reduce task asked for a shuffle whose map stage has not been
    /// marked completed — the scheduler must run (and complete) the map
    /// stage first, so this is always an ordering bug, never "no data".
    MapStageIncomplete {
        shuffle_id: usize,
        reduce_part: usize,
    },
    /// The block index knows the id but the store lost the payload
    /// (e.g. a spill file vanished between index and store lookups).
    MissingBlock { id: BlockId },
    /// Disk IO on a spilled block failed (real or injected). The block
    /// entry survives, so this is retryable: the task fails typed, the
    /// stage re-runs it, and a transient fault recovers.
    SpillIo(BlockIoError),
}

impl fmt::Display for ShuffleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::MapStageIncomplete {
                shuffle_id,
                reduce_part,
            } => write!(
                f,
                "shuffle {shuffle_id} fetched for reduce partition {reduce_part} before its \
                 map stage completed (scheduler ordering bug)"
            ),
            Self::MissingBlock { id } => write!(f, "shuffle block {id} missing from the store"),
            Self::SpillIo(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for ShuffleError {}

impl From<BlockIoError> for ShuffleError {
    fn from(e: BlockIoError) -> Self {
        Self::SpillIo(e)
    }
}

/// Shuffle data + completion registry for one context.
pub struct ShuffleManager {
    store: BlockStore,
    /// (shuffle_id, reduce_partition) -> ids of the blocks written for it.
    index: Mutex<HashMap<(usize, usize), Vec<BlockId>>>,
    /// Shuffle ids whose map stage has fully completed.
    completed: Mutex<HashSet<usize>>,
    next_shuffle_id: AtomicUsize,
    /// Total records moved through the shuffle (metrics).
    records_written: AtomicU64,
    /// Exact bytes moved through the shuffle: the serialized length of
    /// every block written (retried map tasks count again — this is a
    /// "bytes moved" meter, mirroring Spark's shuffle write metric).
    bytes_written: AtomicU64,
    /// Shared-nothing assertion mode (`SparkletConf::shared_nothing`):
    /// `fetch` verifies the store's byte buffers are exclusively owned
    /// at hand-out — no map-side `Arc` alias survived serialization.
    shared_nothing: bool,
}

impl Default for ShuffleManager {
    fn default() -> Self {
        Self::with_conf(None, cfg!(debug_assertions))
    }
}

impl ShuffleManager {
    /// Unlimited memory budget, shared-nothing checks in debug builds.
    pub fn new() -> Self {
        Self::default()
    }

    /// `memory_budget`: in-memory block budget in bytes (`None` =
    /// unlimited). `shared_nothing`: enable the exclusive-ownership
    /// assertion on fetch.
    pub fn with_conf(memory_budget: Option<usize>, shared_nothing: bool) -> Self {
        Self {
            store: BlockStore::new(memory_budget),
            index: Mutex::new(HashMap::new()),
            completed: Mutex::new(HashSet::new()),
            next_shuffle_id: AtomicUsize::new(0),
            records_written: AtomicU64::new(0),
            bytes_written: AtomicU64::new(0),
            shared_nothing,
        }
    }

    pub fn new_shuffle_id(&self) -> usize {
        self.next_shuffle_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Register one map task's serialized bucket for `reduce_part`.
    /// `records` is the bucket's record count; the byte cost is exactly
    /// `bytes.len()`. Writing the same (shuffle, reduce, map) triple
    /// again (a retried map task) overwrites — retries are idempotent.
    pub fn write_block(
        &self,
        shuffle_id: usize,
        reduce_part: usize,
        map_part: usize,
        bytes: Vec<u8>,
        records: usize,
    ) {
        self.records_written
            .fetch_add(records as u64, Ordering::Relaxed);
        self.bytes_written
            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
        let id = BlockId {
            shuffle_id,
            reduce_part,
            map_part,
        };
        {
            let mut index = self.index.lock().unwrap();
            let ids = index.entry((shuffle_id, reduce_part)).or_default();
            if !ids.contains(&id) {
                ids.push(id);
            }
        }
        self.store.put(id, bytes, records);
    }

    /// Fetch all blocks for a reduce partition (possibly spilled ones,
    /// reloaded transparently). An empty `Vec` is a legitimate "no
    /// records hashed here"; asking before the map stage completed is a
    /// typed error.
    pub fn fetch(
        &self,
        shuffle_id: usize,
        reduce_part: usize,
    ) -> Result<Vec<ShuffleBlock>, ShuffleError> {
        if !self.is_completed(shuffle_id) {
            return Err(ShuffleError::MapStageIncomplete {
                shuffle_id,
                reduce_part,
            });
        }
        let ids = self
            .index
            .lock()
            .unwrap()
            .get(&(shuffle_id, reduce_part))
            .cloned()
            .unwrap_or_default();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            let block = self
                .store
                .get(&id)?
                .ok_or(ShuffleError::MissingBlock { id })?;
            if self.shared_nothing {
                // The store holds one Arc, we hold one: anything above 2
                // means a payload is aliased across the stage boundary
                // (a just-spilled block legitimately reads 1).
                let owners = Arc::strong_count(&block.bytes);
                assert!(
                    owners <= 2,
                    "shared-nothing violation: block {id} bytes have {owners} owners at fetch"
                );
            }
            out.push(block);
        }
        Ok(out)
    }

    /// Clear any partial blocks for a shuffle (before re-running its map
    /// stage after a failure, so retries start clean) — spilled blocks
    /// included, their files deleted.
    pub fn clear_shuffle(&self, shuffle_id: usize) {
        self.index
            .lock()
            .unwrap()
            .retain(|(sid, _), _| *sid != shuffle_id);
        self.store.remove_where(|id| id.shuffle_id == shuffle_id);
        self.completed.lock().unwrap().remove(&shuffle_id);
    }

    pub fn mark_completed(&self, shuffle_id: usize) {
        self.completed.lock().unwrap().insert(shuffle_id);
    }

    pub fn is_completed(&self, shuffle_id: usize) -> bool {
        self.completed.lock().unwrap().contains(&shuffle_id)
    }

    pub fn records_written(&self) -> u64 {
        self.records_written.load(Ordering::Relaxed)
    }

    /// Exact serialized bytes written through the shuffle.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written.load(Ordering::Relaxed)
    }

    /// Blocks spilled to disk under the memory budget.
    pub fn spilled_blocks(&self) -> u64 {
        self.store.spilled_blocks()
    }

    /// Install the block spill/reload observer on the underlying store
    /// (the context routes it onto the event bus).
    pub fn set_spill_hook(&self, hook: super::block::BlockIoHook) {
        self.store.set_spill_hook(hook);
    }

    /// Arm the store's spill read/write fault sites with the context's
    /// plane.
    pub fn set_fault_plane(&self, plane: std::sync::Arc<FaultPlane>) {
        self.store.set_fault_plane(plane);
    }

    /// Spilled blocks reloaded on fetch.
    pub fn spill_reloads(&self) -> u64 {
        self.store.reloaded_blocks()
    }

    /// Total bytes written to spill files.
    pub fn spilled_bytes(&self) -> u64 {
        self.store.spilled_bytes()
    }

    /// Charge external (non-block) bytes — the serve-mode result cache —
    /// against the store's memory budget.
    pub fn charge_external(&self, bytes: usize) {
        self.store.charge_external(bytes);
    }

    /// Release previously charged external bytes.
    pub fn release_external(&self, bytes: usize) {
        self.store.release_external(bytes);
    }

    /// Combined budget consumption: resident block bytes plus external
    /// charges (what serve-mode admission compares to the budget).
    pub fn used_bytes(&self) -> usize {
        self.store.used_bytes()
    }

    /// The store's configured budget in bytes (`usize::MAX` = unlimited).
    pub fn memory_budget(&self) -> usize {
        self.store.budget()
    }

    /// Files currently in the spill directory (leak detection).
    pub fn spill_file_count(&self) -> usize {
        self.store.spill_file_count()
    }

    /// Human-readable spill line for CLI output.
    pub fn spill_summary(&self) -> String {
        let budget = self.store.budget();
        let budget = if budget == usize::MAX {
            "unlimited".to_string()
        } else {
            format!("{} B", budget)
        };
        format!(
            "memory budget {budget}: {} blocks spilled ({} B), {} reloads, {} B resident",
            self.spilled_blocks(),
            self.spilled_bytes(),
            self.spill_reloads(),
            self.store.mem_bytes(),
        )
    }

    /// Drop all shuffle data (job teardown / memory reclamation).
    pub fn clear_all(&self) {
        self.index.lock().unwrap().clear();
        self.store.clear();
        self.completed.lock().unwrap().clear();
    }

    /// Fetch a reduce partition as transport-ready `(id, bytes, records)`
    /// triples — the shape `BlockData` frames carry to remote workers.
    /// Bytes are copied out of the store's `Arc` buffers: what goes on
    /// the wire (or into a local described task) is exclusively owned.
    pub fn fetch_serialized(
        &self,
        shuffle_id: usize,
        reduce_part: usize,
    ) -> Result<Vec<super::transport::WireBlock>, ShuffleError> {
        let ids = if self.is_completed(shuffle_id) {
            self.index
                .lock()
                .unwrap()
                .get(&(shuffle_id, reduce_part))
                .cloned()
                .unwrap_or_default()
        } else {
            return Err(ShuffleError::MapStageIncomplete {
                shuffle_id,
                reduce_part,
            });
        };
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            let block = self
                .store
                .get(&id)?
                .ok_or(ShuffleError::MissingBlock { id })?;
            out.push((id, block.bytes.to_vec(), block.records));
        }
        Ok(out)
    }
}

/// [`super::transport::BlockFetcher`] over the driver's own
/// [`ShuffleManager`] — what a described task uses when it runs on the
/// driver (local fallback) instead of a remote worker.
pub struct LocalBlockFetcher {
    shuffle: Arc<ShuffleManager>,
}

impl LocalBlockFetcher {
    pub fn new(shuffle: Arc<ShuffleManager>) -> Self {
        Self { shuffle }
    }
}

impl super::transport::BlockFetcher for LocalBlockFetcher {
    fn fetch_blocks(
        &self,
        shuffle_id: usize,
        reduce_part: usize,
    ) -> Result<Vec<super::transport::WireBlock>, String> {
        self.shuffle
            .fetch_serialized(shuffle_id, reduce_part)
            .map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::super::serde::{decode_records, encode_records};
    use super::*;

    fn block_of(recs: &[(u32, String)]) -> (Vec<u8>, usize) {
        (encode_records(recs), recs.len())
    }

    #[test]
    fn write_fetch_roundtrip_with_exact_bytes() {
        let m = ShuffleManager::new();
        let sid = m.new_shuffle_id();
        let a = vec![(1u32, "a".to_string())];
        let b = vec![(2u32, "b".to_string())];
        let c = vec![(3u32, "c".to_string())];
        let (ba, na) = block_of(&a);
        let (bb, nb) = block_of(&b);
        let (bc, nc) = block_of(&c);
        let exact = (ba.len() + bb.len() + bc.len()) as u64;
        m.write_block(sid, 0, 0, ba, na);
        m.write_block(sid, 0, 1, bb, nb);
        m.write_block(sid, 1, 2, bc, nc);
        m.mark_completed(sid);
        let got = m.fetch(sid, 0).unwrap();
        assert_eq!(got.len(), 2);
        let first: Vec<(u32, String)> = decode_records(&got[0].bytes).unwrap();
        assert_eq!(first, a);
        assert_eq!(got[0].records, 1);
        assert_eq!(m.fetch(sid, 1).unwrap().len(), 1);
        assert_eq!(m.fetch(sid, 2).unwrap().len(), 0, "empty partition is Ok");
        assert_eq!(m.records_written(), 3);
        assert_eq!(m.bytes_written(), exact, "byte accounting is exact");
    }

    #[test]
    fn fetch_before_completion_is_a_typed_error() {
        let m = ShuffleManager::new();
        let sid = m.new_shuffle_id();
        let (bytes, n) = block_of(&[(1u32, "x".to_string())]);
        m.write_block(sid, 0, 0, bytes, n);
        let err = m.fetch(sid, 0).unwrap_err();
        assert_eq!(
            err,
            ShuffleError::MapStageIncomplete {
                shuffle_id: sid,
                reduce_part: 0
            }
        );
        assert!(err.to_string().contains("before its map stage"), "{err}");
        // completing flips it to Ok; clearing flips it back to Err
        m.mark_completed(sid);
        assert_eq!(m.fetch(sid, 0).unwrap().len(), 1);
        m.clear_shuffle(sid);
        assert!(matches!(
            m.fetch(sid, 0),
            Err(ShuffleError::MapStageIncomplete { .. })
        ));
    }

    #[test]
    fn retried_map_task_overwrites_not_duplicates() {
        let m = ShuffleManager::new();
        let sid = m.new_shuffle_id();
        let (b1, n1) = block_of(&[(1u32, "first".to_string())]);
        m.write_block(sid, 0, 0, b1, n1);
        let retry = vec![(1u32, "retry".to_string()), (2, "extra".to_string())];
        let (b2, n2) = block_of(&retry);
        m.write_block(sid, 0, 0, b2, n2);
        m.mark_completed(sid);
        let got = m.fetch(sid, 0).unwrap();
        assert_eq!(got.len(), 1, "same (shuffle,reduce,map) triple overwrote");
        let recs: Vec<(u32, String)> = decode_records(&got[0].bytes).unwrap();
        assert_eq!(recs, retry);
    }

    #[test]
    fn clear_shuffle_scopes_to_id_even_when_spilled() {
        // 1-byte budget: every block lives on disk immediately.
        let m = ShuffleManager::with_conf(Some(1), true);
        let a = m.new_shuffle_id();
        let b = m.new_shuffle_id();
        let (ba, na) = block_of(&[(1u32, "a".to_string())]);
        let (bb, nb) = block_of(&[(2u32, "b".to_string())]);
        m.write_block(a, 0, 0, ba, na);
        m.write_block(b, 0, 0, bb, nb);
        assert!(m.spilled_blocks() >= 2, "budget of 1 byte spills all");
        m.mark_completed(a);
        m.mark_completed(b);
        m.clear_shuffle(a);
        assert!(matches!(
            m.fetch(a, 0),
            Err(ShuffleError::MapStageIncomplete { .. })
        ));
        // b survives a's clear and reloads from its spill file
        let got = m.fetch(b, 0).unwrap();
        let recs: Vec<(u32, String)> = decode_records(&got[0].bytes).unwrap();
        assert_eq!(recs, vec![(2u32, "b".to_string())]);
        assert!(m.spill_reloads() >= 1);
        assert!(m.spill_summary().contains("spilled"), "{}", m.spill_summary());
    }

    #[test]
    fn completion_registry() {
        let m = ShuffleManager::new();
        let sid = m.new_shuffle_id();
        assert!(!m.is_completed(sid));
        m.mark_completed(sid);
        assert!(m.is_completed(sid));
        m.clear_shuffle(sid);
        assert!(!m.is_completed(sid));
    }

    #[test]
    fn fetch_serialized_matches_fetch_and_is_owned() {
        use super::super::transport::BlockFetcher;
        let m = Arc::new(ShuffleManager::new());
        let sid = m.new_shuffle_id();
        let recs = vec![(1u32, "a".to_string()), (2, "b".to_string())];
        let (bytes, n) = block_of(&recs);
        m.write_block(sid, 0, 0, bytes, n);
        // Before completion: same typed error as fetch.
        assert!(matches!(
            m.fetch_serialized(sid, 0),
            Err(ShuffleError::MapStageIncomplete { .. })
        ));
        m.mark_completed(sid);
        let wire = m.fetch_serialized(sid, 0).unwrap();
        assert_eq!(wire.len(), 1);
        let (id, payload, records) = &wire[0];
        assert_eq!((id.shuffle_id, id.reduce_part, id.map_part), (sid, 0, 0));
        assert_eq!(*records, 2);
        let decoded: Vec<(u32, String)> = decode_records(payload).unwrap();
        assert_eq!(decoded, recs);
        // The adapter exposes the same data through the trait.
        let fetcher = LocalBlockFetcher::new(Arc::clone(&m));
        let via_trait = fetcher.fetch_blocks(sid, 0).unwrap();
        assert_eq!(via_trait, wire);
        assert!(fetcher
            .fetch_blocks(sid + 100, 0)
            .unwrap_err()
            .contains("before its map stage"));
    }

    #[test]
    fn injected_spill_fault_propagates_as_typed_shuffle_error() {
        use super::super::faults::{FaultPlan, FaultPlane};
        let m = ShuffleManager::with_conf(Some(1), true);
        m.set_fault_plane(Arc::new(FaultPlane::new(
            FaultPlan::parse("spill_read:nth=1").unwrap(),
        )));
        let sid = m.new_shuffle_id();
        let (bytes, n) = block_of(&[(1u32, "x".to_string())]);
        m.write_block(sid, 0, 0, bytes, n);
        m.mark_completed(sid);
        let err = m.fetch(sid, 0).unwrap_err();
        assert!(matches!(err, ShuffleError::SpillIo(_)), "{err}");
        assert!(err.to_string().contains("injected"), "{err}");
        // The entry survived, so the retry fetch recovers.
        assert_eq!(m.fetch(sid, 0).unwrap().len(), 1);
        // fetch_serialized hits the same typed path.
        m.set_fault_plane(Arc::new(FaultPlane::new(
            FaultPlan::parse("spill_read:nth=1").unwrap(),
        )));
        assert!(matches!(
            m.fetch_serialized(sid, 0),
            Err(ShuffleError::SpillIo(_))
        ));
    }

    #[test]
    fn distinct_ids() {
        let m = ShuffleManager::new();
        assert_ne!(m.new_shuffle_id(), m.new_shuffle_id());
    }
}
