//! Partition cache — `rdd.cache()` / MEMORY_ONLY storage.
//!
//! Stores computed partitions keyed by (rdd id, partition index) as
//! type-erased vectors. Eviction is exposed so the lineage-recovery
//! tests can simulate executor loss: evict a cached partition and the
//! next job recomputes it from lineage transparently.

use std::any::Any;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

type Stored = Arc<dyn Any + Send + Sync>;

#[derive(Default)]
pub struct CacheManager {
    /// Rdd ids with caching enabled.
    enabled: Mutex<HashSet<usize>>,
    entries: Mutex<HashMap<(usize, usize), Stored>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheManager {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn enable(&self, rdd_id: usize) {
        self.enabled.lock().unwrap().insert(rdd_id);
    }

    pub fn is_enabled(&self, rdd_id: usize) -> bool {
        self.enabled.lock().unwrap().contains(&rdd_id)
    }

    /// Fetch a cached partition, if present.
    pub fn get<T: Clone + Send + Sync + 'static>(
        &self,
        rdd_id: usize,
        part: usize,
    ) -> Option<Vec<T>> {
        let entries = self.entries.lock().unwrap();
        match entries.get(&(rdd_id, part)) {
            Some(stored) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                stored.downcast_ref::<Vec<T>>().cloned()
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn put<T: Clone + Send + Sync + 'static>(
        &self,
        rdd_id: usize,
        part: usize,
        data: Vec<T>,
    ) {
        self.entries
            .lock()
            .unwrap()
            .insert((rdd_id, part), Arc::new(data));
    }

    /// Evict one partition (simulated executor loss).
    pub fn evict(&self, rdd_id: usize, part: usize) -> bool {
        self.entries.lock().unwrap().remove(&(rdd_id, part)).is_some()
    }

    /// Evict all partitions of an rdd (`unpersist`).
    pub fn evict_rdd(&self, rdd_id: usize) {
        self.entries
            .lock()
            .unwrap()
            .retain(|(id, _), _| *id != rdd_id);
        self.enabled.lock().unwrap().remove(&rdd_id);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn cached_partitions(&self) -> usize {
        self.entries.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_evict() {
        let c = CacheManager::new();
        c.enable(1);
        assert!(c.is_enabled(1));
        assert!(!c.is_enabled(2));
        assert_eq!(c.get::<u32>(1, 0), None);
        c.put(1, 0, vec![1u32, 2, 3]);
        assert_eq!(c.get::<u32>(1, 0), Some(vec![1, 2, 3]));
        assert!(c.evict(1, 0));
        assert!(!c.evict(1, 0));
        assert_eq!(c.get::<u32>(1, 0), None);
    }

    #[test]
    fn unpersist_clears_all_partitions() {
        let c = CacheManager::new();
        c.enable(7);
        c.put(7, 0, vec![1u8]);
        c.put(7, 1, vec![2u8]);
        c.put(8, 0, vec![3u8]);
        c.evict_rdd(7);
        assert!(!c.is_enabled(7));
        assert_eq!(c.get::<u8>(7, 0), None);
        assert_eq!(c.get::<u8>(8, 0), Some(vec![3u8]));
    }

    #[test]
    fn hit_miss_counters() {
        let c = CacheManager::new();
        c.put(1, 0, vec![0u8]);
        let _ = c.get::<u8>(1, 0);
        let _ = c.get::<u8>(1, 1);
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
    }
}
