//! Retail association-rule mining: generate an IBM-Quest-style retail
//! basket dataset, mine frequent itemsets with RDD-Eclat and derive
//! association rules in one `MiningSession`, and print the strongest
//! ones — the workload the paper's introduction motivates.
//!
//! Run: `cargo run --release --example retail_rules`

use rdd_eclat::data::QuestSpec;
use rdd_eclat::fim::engine::MiningSession;
use rdd_eclat::sparklet::SparkletContext;

fn main() {
    // 10K baskets over an 870-product catalogue (T10-shaped).
    let spec = QuestSpec::t10i4d100k().scaled(0.1);
    let baskets = spec.generate(2026);
    println!(
        "generated {} baskets, avg width {:.1}",
        baskets.len(),
        baskets.iter().map(|b| b.len()).sum::<usize>() as f64 / baskets.len() as f64
    );

    let sc = SparkletContext::local(4);
    // One session: mine at 0.5% support with EclatV5, then derive rules
    // at confidence >= 0.5 — the post-pipeline rides on the same run.
    let report = MiningSession::new("eclat-v5")
        .min_sup_frac(0.005)
        .p(10)
        .rules(0.5)
        .run_vec(&sc, &baskets)
        .expect("eclat-v5 is a builtin engine");
    println!(
        "mined {} frequent itemsets (max length {}) in {:.0} ms (min_sup abs {})",
        report.result.len(),
        report.result.max_length(),
        report.wall_ms,
        report.min_sup
    );

    let rules = report.rules.as_deref().unwrap_or(&[]);
    println!("\ntop association rules (confidence >= 0.5):");
    for r in rules.iter().take(15) {
        println!("  {r}");
    }
    println!("({} rules total)", rules.len());

    // sanity: every rule's confidence is consistent with its supports
    for r in rules {
        assert!(r.confidence > 0.0 && r.confidence <= 1.0);
    }
}
