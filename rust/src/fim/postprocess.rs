//! Post-processing of mining results: closed and maximal frequent
//! itemsets — the standard condensed representations (Zaki's CHARM /
//! Bayardo's MaxMiner outputs), useful when the full result set (e.g.
//! 13K itemsets on T10 at 0.1%) is too verbose for downstream use.
//!
//! * **closed**: no proper superset has the *same* support.
//! * **maximal**: no proper superset is frequent at all.
//! Every maximal itemset is closed; both sets reconstruct the full
//! result's membership (maximal) or membership+supports (closed).

use crate::util::hash::FxHashMap;

use super::types::{FrequentItemset, Item, MiningResult};

fn is_subset(a: &[Item], b: &[Item]) -> bool {
    // both sorted
    let mut j = 0usize;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j >= b.len() || b[j] != x {
            return false;
        }
        j += 1;
    }
    true
}

/// Group itemsets by length for superset scans (longer first).
fn by_length_desc(result: &MiningResult) -> Vec<&FrequentItemset> {
    let mut v: Vec<&FrequentItemset> = result.itemsets.iter().collect();
    v.sort_by_key(|f| std::cmp::Reverse(f.items.len()));
    v
}

/// Maximal frequent itemsets: those with no frequent proper superset.
pub fn maximal_itemsets(result: &MiningResult) -> MiningResult {
    let sorted = by_length_desc(result);
    let mut maximal: Vec<FrequentItemset> = Vec::new();
    for f in sorted {
        let covered = maximal
            .iter()
            .any(|m| m.items.len() > f.items.len() && is_subset(&f.items, &m.items));
        if !covered {
            maximal.push(f.clone());
        }
    }
    MiningResult::new(maximal)
}

/// Closed frequent itemsets: those with no proper superset of equal
/// support. Uses the support-partition trick: an itemset can only be
/// closed-violated by a superset with identical support.
pub fn closed_itemsets(result: &MiningResult) -> MiningResult {
    let mut by_support: FxHashMap<u32, Vec<&FrequentItemset>> = FxHashMap::default();
    for f in &result.itemsets {
        by_support.entry(f.support).or_default().push(f);
    }
    let mut closed = Vec::new();
    for f in &result.itemsets {
        let peers = &by_support[&f.support];
        let has_equal_superset = peers.iter().any(|g| {
            g.items.len() > f.items.len() && is_subset(&f.items, &g.items)
        });
        if !has_equal_superset {
            closed.push(f.clone());
        }
    }
    MiningResult::new(closed)
}

/// The `k` highest-support itemsets (ties broken toward shorter, then
/// lexicographically smaller itemsets, so the cut is deterministic).
/// A `MiningSession` post-stage for dashboards that only want headliners.
pub fn top_k(result: &MiningResult, k: usize) -> MiningResult {
    let mut itemsets = result.itemsets.clone();
    itemsets.sort_by(|a, b| {
        b.support
            .cmp(&a.support)
            .then(a.items.len().cmp(&b.items.len()))
            .then(a.items.cmp(&b.items))
    });
    itemsets.truncate(k);
    MiningResult::new(itemsets)
}

/// Compression ratio of a condensed representation (|condensed| / |full|).
pub fn compression_ratio(full: &MiningResult, condensed: &MiningResult) -> f64 {
    if full.is_empty() {
        return 1.0;
    }
    condensed.len() as f64 / full.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::sequential::eclat_sequential;
    use crate::util::prop::{forall, gen};

    fn demo_db() -> Vec<Vec<Item>> {
        vec![
            vec![1, 2, 5],
            vec![2, 4],
            vec![2, 3],
            vec![1, 2, 4],
            vec![1, 3],
            vec![2, 3],
            vec![1, 3],
            vec![1, 2, 3, 5],
            vec![1, 2, 3],
        ]
    }

    fn brute_maximal(full: &MiningResult) -> std::collections::BTreeSet<Vec<Item>> {
        full.itemsets
            .iter()
            .filter(|f| {
                !full.itemsets.iter().any(|g| {
                    g.items.len() > f.items.len() && is_subset(&f.items, &g.items)
                })
            })
            .map(|f| f.items.clone())
            .collect()
    }

    fn brute_closed(full: &MiningResult) -> std::collections::BTreeSet<Vec<Item>> {
        full.itemsets
            .iter()
            .filter(|f| {
                !full.itemsets.iter().any(|g| {
                    g.support == f.support
                        && g.items.len() > f.items.len()
                        && is_subset(&f.items, &g.items)
                })
            })
            .map(|f| f.items.clone())
            .collect()
    }

    #[test]
    fn subset_check() {
        assert!(is_subset(&[1, 3], &[1, 2, 3]));
        assert!(is_subset(&[], &[1]));
        assert!(!is_subset(&[1, 4], &[1, 2, 3]));
        assert!(!is_subset(&[1, 2, 3], &[1, 2]));
    }

    #[test]
    fn maximal_and_closed_match_bruteforce_demo() {
        let full = eclat_sequential(&demo_db(), 2);
        let maximal = maximal_itemsets(&full);
        let closed = closed_itemsets(&full);
        let max_sets: std::collections::BTreeSet<Vec<Item>> =
            maximal.itemsets.iter().map(|f| f.items.clone()).collect();
        let closed_sets: std::collections::BTreeSet<Vec<Item>> =
            closed.itemsets.iter().map(|f| f.items.clone()).collect();
        assert_eq!(max_sets, brute_maximal(&full));
        assert_eq!(closed_sets, brute_closed(&full));
        // maximal ⊆ closed ⊆ full
        assert!(max_sets.is_subset(&closed_sets));
        assert!(closed.len() <= full.len());
        assert!(maximal.len() <= closed.len());
    }

    #[test]
    fn property_condensed_representations() {
        forall(25, gen::database(25, 8, 0.4), |db| {
            let full = eclat_sequential(db, 2);
            let maximal = maximal_itemsets(&full);
            let closed = closed_itemsets(&full);
            let max_sets: std::collections::BTreeSet<Vec<Item>> =
                maximal.itemsets.iter().map(|f| f.items.clone()).collect();
            let closed_sets: std::collections::BTreeSet<Vec<Item>> =
                closed.itemsets.iter().map(|f| f.items.clone()).collect();
            max_sets == brute_maximal(&full)
                && closed_sets == brute_closed(&full)
                && max_sets.is_subset(&closed_sets)
        });
    }

    #[test]
    fn every_frequent_itemset_has_maximal_superset() {
        let full = eclat_sequential(&demo_db(), 2);
        let maximal = maximal_itemsets(&full);
        for f in &full.itemsets {
            assert!(
                maximal
                    .itemsets
                    .iter()
                    .any(|m| is_subset(&f.items, &m.items)),
                "{:?} not covered",
                f.items
            );
        }
    }

    #[test]
    fn top_k_selects_highest_supports_deterministically() {
        let full = eclat_sequential(&demo_db(), 2);
        let top = top_k(&full, 5);
        assert_eq!(top.len(), 5);
        let cutoff = top.itemsets.iter().map(|f| f.support).min().unwrap();
        // nothing outside the top-k strictly beats anything inside it
        let excluded_max = full
            .itemsets
            .iter()
            .filter(|f| !top.itemsets.contains(f))
            .map(|f| f.support)
            .max()
            .unwrap();
        assert!(excluded_max <= cutoff);
        // k >= |full| is the identity (as a set)
        assert!(top_k(&full, 10_000).same_as(&full));
        assert!(top_k(&full, 0).is_empty());
    }

    #[test]
    fn compression_ratio_sane() {
        let full = eclat_sequential(&demo_db(), 1);
        let maximal = maximal_itemsets(&full);
        let r = compression_ratio(&full, &maximal);
        assert!(r > 0.0 && r < 1.0, "ratio {r}");
        assert_eq!(compression_ratio(&MiningResult::default(), &maximal), 1.0);
    }
}
