//! Wire protocol for the multi-process executor backend.
//!
//! Everything that crosses the driver↔worker boundary is one
//! length-prefixed frame: a `u32` little-endian payload length followed
//! by a [`Message`] encoded with the same zero-dependency [`SerDe`]
//! codec the shuffle data plane uses. Tasks are not closures on the
//! wire — they are [`TaskDescriptor`]s (stage identity + a
//! [`TaskRegistry`] key + an opaque serialized partition spec), so a
//! worker process that never saw the driver's heap can still execute
//! them. Shuffle input is pulled on demand: a reduce task running on a
//! worker sends `FetchBlock` and the driver answers with the serialized
//! blocks from its `BlockStore` (`BlockData`).
//!
//! Decoding never panics: truncated frames, unknown message tags,
//! oversized lengths, and codec failures all surface as typed
//! [`TransportError`]s — a malformed peer costs a connection, not the
//! driver process.

use std::collections::HashMap;
use std::fmt;
use std::io::{Read, Write};
use std::sync::{Arc, Mutex, OnceLock};

use super::block::BlockId;
use super::faults::{FaultPlane, FaultSite};
use super::serde::{Reader, SerDe, SerDeError};

/// Upper bound on one frame's payload. Shuffle blocks are the largest
/// thing shipped; anything past this is a corrupt length prefix, not a
/// real message, so it is rejected before allocating.
pub const MAX_FRAME_BYTES: usize = 256 * 1024 * 1024;

/// Typed transport failures. `Closed` is the *orderly* end of a
/// connection (EOF between frames) — the driver maps it to worker loss.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportError {
    /// The peer hung up cleanly between frames.
    Closed,
    /// Socket-level read/write failure (includes mid-frame truncation).
    Io(String),
    /// The payload did not decode as the declared message.
    Codec(SerDeError),
    /// A frame carried a message tag this build does not know.
    UnknownTag(u8),
    /// The length prefix exceeds [`MAX_FRAME_BYTES`].
    Oversize { len: usize, max: usize },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Closed => write!(f, "connection closed"),
            Self::Io(e) => write!(f, "transport io error: {e}"),
            Self::Codec(e) => write!(f, "transport codec error: {e}"),
            Self::UnknownTag(t) => write!(f, "unknown message tag {t}"),
            Self::Oversize { len, max } => {
                write!(f, "frame of {len} bytes exceeds the {max}-byte limit")
            }
        }
    }
}

impl std::error::Error for TransportError {}

impl From<SerDeError> for TransportError {
    fn from(e: SerDeError) -> Self {
        Self::Codec(e)
    }
}

/// A serialized task: enough identity for events/retry bookkeeping
/// (`job_id`/`stage_tag`/`part`/`attempt`), the [`TaskRegistry`] key
/// naming the code to run, and an opaque payload the registered
/// function decodes itself (e.g. `{shuffle_id, reduce_part, min_sup}`
/// for the FIM Bottom-Up tasks).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskDescriptor {
    pub job_id: u64,
    pub stage_tag: u64,
    pub part: usize,
    pub attempt: usize,
    pub key: String,
    pub payload: Vec<u8>,
}

impl SerDe for TaskDescriptor {
    fn encode(&self, out: &mut Vec<u8>) {
        self.job_id.encode(out);
        self.stage_tag.encode(out);
        self.part.encode(out);
        self.attempt.encode(out);
        self.key.encode(out);
        self.payload.encode(out);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        Ok(Self {
            job_id: u64::decode(r)?,
            stage_tag: u64::decode(r)?,
            part: usize::decode(r)?,
            attempt: usize::decode(r)?,
            key: String::decode(r)?,
            payload: Vec::decode(r)?,
        })
    }
}

/// One serialized shuffle block on the wire: identity, payload bytes
/// (`encode_records` framing, verbatim from the driver's store), and
/// the record count for integrity checks on the worker side.
pub type WireBlock = (BlockId, Vec<u8>, usize);

/// The protocol. Tag bytes are part of the wire format — append new
/// variants, never renumber.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → driver, first frame after connecting.
    RegisterWorker { worker: String, pid: u32 },
    /// Driver → worker: run one described task.
    LaunchTask { task: TaskDescriptor },
    /// Worker → driver: the outcome of a launched task.
    TaskResult {
        job_id: u64,
        stage_tag: u64,
        part: usize,
        attempt: usize,
        result: Result<Vec<u8>, String>,
        run_ms: f64,
    },
    /// Worker → driver: request every map-output block of one reduce
    /// partition.
    FetchBlock { shuffle_id: usize, reduce_part: usize },
    /// Driver → worker: answer to `FetchBlock`. An `Err` is a
    /// fetch failure (incomplete map stage, unknown shuffle) the task
    /// surfaces as its own failure.
    BlockData {
        shuffle_id: usize,
        reduce_part: usize,
        result: Result<Vec<WireBlock>, String>,
    },
    /// Worker → driver liveness beacon.
    Heartbeat { worker: String, seq: u64 },
    /// Driver-side notification that a worker died (also synthesized
    /// internally on EOF/timeout; on the wire it tells surviving
    /// workers nothing today but keeps the protocol symmetric).
    WorkerLost { worker: String, reason: String },
    /// Driver → worker: exit the worker loop cleanly.
    Shutdown,
    /// Client → server (serve mode): one mining request. The body is an
    /// opaque serve-layer payload (`serve::protocol::ServeRequest`
    /// bytes) — the transport stays ignorant of mining vocabulary, the
    /// same way `TaskDescriptor` payloads are opaque to it.
    Request { body: Vec<u8> },
    /// Server → client (serve mode): the answer to one `Request`
    /// (`serve::protocol::ServeResponse` bytes).
    Response { body: Vec<u8> },
}

const TAG_REGISTER: u8 = 1;
const TAG_LAUNCH: u8 = 2;
const TAG_RESULT: u8 = 3;
const TAG_FETCH: u8 = 4;
const TAG_BLOCKDATA: u8 = 5;
const TAG_HEARTBEAT: u8 = 6;
const TAG_WORKERLOST: u8 = 7;
const TAG_SHUTDOWN: u8 = 8;
const TAG_REQUEST: u8 = 9;
const TAG_RESPONSE: u8 = 10;

impl Message {
    /// Encode into a frame payload (tag byte + body).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Self::RegisterWorker { worker, pid } => {
                out.push(TAG_REGISTER);
                worker.encode(&mut out);
                pid.encode(&mut out);
            }
            Self::LaunchTask { task } => {
                out.push(TAG_LAUNCH);
                task.encode(&mut out);
            }
            Self::TaskResult {
                job_id,
                stage_tag,
                part,
                attempt,
                result,
                run_ms,
            } => {
                out.push(TAG_RESULT);
                job_id.encode(&mut out);
                stage_tag.encode(&mut out);
                part.encode(&mut out);
                attempt.encode(&mut out);
                result.encode(&mut out);
                run_ms.encode(&mut out);
            }
            Self::FetchBlock {
                shuffle_id,
                reduce_part,
            } => {
                out.push(TAG_FETCH);
                shuffle_id.encode(&mut out);
                reduce_part.encode(&mut out);
            }
            Self::BlockData {
                shuffle_id,
                reduce_part,
                result,
            } => {
                out.push(TAG_BLOCKDATA);
                shuffle_id.encode(&mut out);
                reduce_part.encode(&mut out);
                result.encode(&mut out);
            }
            Self::Heartbeat { worker, seq } => {
                out.push(TAG_HEARTBEAT);
                worker.encode(&mut out);
                seq.encode(&mut out);
            }
            Self::WorkerLost { worker, reason } => {
                out.push(TAG_WORKERLOST);
                worker.encode(&mut out);
                reason.encode(&mut out);
            }
            Self::Shutdown => out.push(TAG_SHUTDOWN),
            Self::Request { body } => {
                out.push(TAG_REQUEST);
                body.encode(&mut out);
            }
            Self::Response { body } => {
                out.push(TAG_RESPONSE);
                body.encode(&mut out);
            }
        }
        out
    }

    /// Decode a frame payload, rejecting trailing bytes and unknown
    /// tags with typed errors.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, TransportError> {
        let mut r = Reader::new(bytes);
        let tag = u8::decode(&mut r)?;
        let msg = match tag {
            TAG_REGISTER => Self::RegisterWorker {
                worker: String::decode(&mut r)?,
                pid: u32::decode(&mut r)?,
            },
            TAG_LAUNCH => Self::LaunchTask {
                task: TaskDescriptor::decode(&mut r)?,
            },
            TAG_RESULT => Self::TaskResult {
                job_id: u64::decode(&mut r)?,
                stage_tag: u64::decode(&mut r)?,
                part: usize::decode(&mut r)?,
                attempt: usize::decode(&mut r)?,
                result: Result::decode(&mut r)?,
                run_ms: f64::decode(&mut r)?,
            },
            TAG_FETCH => Self::FetchBlock {
                shuffle_id: usize::decode(&mut r)?,
                reduce_part: usize::decode(&mut r)?,
            },
            TAG_BLOCKDATA => Self::BlockData {
                shuffle_id: usize::decode(&mut r)?,
                reduce_part: usize::decode(&mut r)?,
                result: Result::decode(&mut r)?,
            },
            TAG_HEARTBEAT => Self::Heartbeat {
                worker: String::decode(&mut r)?,
                seq: u64::decode(&mut r)?,
            },
            TAG_WORKERLOST => Self::WorkerLost {
                worker: String::decode(&mut r)?,
                reason: String::decode(&mut r)?,
            },
            TAG_SHUTDOWN => Self::Shutdown,
            TAG_REQUEST => Self::Request {
                body: Vec::decode(&mut r)?,
            },
            TAG_RESPONSE => Self::Response {
                body: Vec::decode(&mut r)?,
            },
            other => return Err(TransportError::UnknownTag(other)),
        };
        if r.remaining() != 0 {
            return Err(TransportError::Codec(SerDeError::Trailing {
                remaining: r.remaining(),
            }));
        }
        Ok(msg)
    }
}

/// Write one `u32`-length-prefixed frame and flush it.
pub fn write_frame(w: &mut impl Write, msg: &Message) -> Result<(), TransportError> {
    write_frame_with(w, msg, None)
}

/// [`write_frame`] with an optional fault plane threaded through.
///
/// Two sites live here: `frame_write` fires *before* any bytes touch
/// the stream (so the connection stays frame-aligned and a retry can
/// genuinely succeed), and `frame_corrupt` flips exactly one seeded
/// payload byte after encoding — the length prefix is never corrupted,
/// so the peer reads a well-framed payload that fails to *decode*
/// (typed `Codec`/`UnknownTag`), which is the interesting failure.
pub fn write_frame_with(
    w: &mut impl Write,
    msg: &Message,
    faults: Option<&FaultPlane>,
) -> Result<(), TransportError> {
    if let Some(plane) = faults {
        if plane.should_fail(FaultSite::FrameWrite) {
            return Err(TransportError::Io("injected frame_write fault".into()));
        }
    }
    let mut payload = msg.to_bytes();
    if let Some(plane) = faults {
        if plane.should_fail(FaultSite::FrameCorrupt) {
            plane.corrupt_byte(&mut payload);
        }
    }
    if payload.len() > MAX_FRAME_BYTES {
        return Err(TransportError::Oversize {
            len: payload.len(),
            max: MAX_FRAME_BYTES,
        });
    }
    let len = payload.len() as u32;
    let io = |e: std::io::Error| TransportError::Io(e.to_string());
    w.write_all(&len.to_le_bytes()).map_err(io)?;
    w.write_all(&payload).map_err(io)?;
    w.flush().map_err(io)?;
    Ok(())
}

/// Read one frame. EOF *before* the length prefix is an orderly
/// [`TransportError::Closed`]; EOF mid-frame is truncation ([`Io`]).
///
/// [`Io`]: TransportError::Io
pub fn read_frame(r: &mut impl Read) -> Result<Message, TransportError> {
    read_frame_with(r, None)
}

/// [`read_frame`] with an optional fault plane. The `frame_read` site
/// fires before the length prefix is consumed — it models a connection
/// reset between frames, so the stream is *not* desynchronized and the
/// caller can treat it exactly like a socket error.
pub fn read_frame_with(
    r: &mut impl Read,
    faults: Option<&FaultPlane>,
) -> Result<Message, TransportError> {
    if let Some(plane) = faults {
        if plane.should_fail(FaultSite::FrameRead) {
            return Err(TransportError::Io("injected frame_read fault".into()));
        }
    }
    let mut len_buf = [0u8; 4];
    let mut filled = 0usize;
    while filled < 4 {
        match r.read(&mut len_buf[filled..]) {
            Ok(0) if filled == 0 => return Err(TransportError::Closed),
            Ok(0) => {
                return Err(TransportError::Io(format!(
                    "eof inside frame length prefix ({filled}/4 bytes)"
                )))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(TransportError::Io(e.to_string())),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(TransportError::Oversize {
            len,
            max: MAX_FRAME_BYTES,
        });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| TransportError::Io(format!("eof inside {len}-byte frame payload: {e}")))?;
    Message::from_bytes(&payload)
}

// ----------------------------------------------------------- task registry

/// Where a described task gets its shuffle input from. On the driver
/// this is the local `ShuffleManager`; on a worker it is the socket
/// (`FetchBlock`/`BlockData` round trip).
pub trait BlockFetcher {
    fn fetch_blocks(
        &self,
        shuffle_id: usize,
        reduce_part: usize,
    ) -> Result<Vec<WireBlock>, String>;
}

/// Execution environment handed to a registered task function.
pub struct TaskEnv<'a> {
    fetcher: &'a dyn BlockFetcher,
}

impl<'a> TaskEnv<'a> {
    pub fn new(fetcher: &'a dyn BlockFetcher) -> Self {
        Self { fetcher }
    }

    /// All map-output blocks of one reduce partition.
    pub fn fetch_blocks(
        &self,
        shuffle_id: usize,
        reduce_part: usize,
    ) -> Result<Vec<WireBlock>, String> {
        self.fetcher.fetch_blocks(shuffle_id, reduce_part)
    }
}

/// A registered task implementation: decode the payload, do the work,
/// encode the result. Errors are strings — they cross the process
/// boundary and feed the scheduler's retry accounting.
pub type RegisteredTaskFn =
    Arc<dyn Fn(&TaskEnv<'_>, &[u8]) -> Result<Vec<u8>, String> + Send + Sync>;

static TASKS: OnceLock<Mutex<HashMap<String, RegisteredTaskFn>>> = OnceLock::new();

fn tasks() -> &'static Mutex<HashMap<String, RegisteredTaskFn>> {
    TASKS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Process-global registry mapping descriptor keys to code. Both the
/// driver (local fallback, tests) and every worker process must
/// register the same keys at startup — the key string is the only
/// thing that crosses the wire.
pub struct TaskRegistry;

impl TaskRegistry {
    /// Register (or overwrite — registration is idempotent) a task
    /// implementation under `key`.
    pub fn register(
        key: &str,
        f: impl Fn(&TaskEnv<'_>, &[u8]) -> Result<Vec<u8>, String> + Send + Sync + 'static,
    ) {
        tasks().lock().unwrap().insert(key.to_string(), Arc::new(f));
    }

    pub fn get(key: &str) -> Option<RegisteredTaskFn> {
        tasks().lock().unwrap().get(key).cloned()
    }

    pub fn keys() -> Vec<String> {
        let mut keys: Vec<String> = tasks().lock().unwrap().keys().cloned().collect();
        keys.sort();
        keys
    }

    /// Execute a descriptor against `env`. An unregistered key is a
    /// task failure (typed string), not a panic — the scheduler decides
    /// whether to retry.
    pub fn run(desc: &TaskDescriptor, env: &TaskEnv<'_>) -> Result<Vec<u8>, String> {
        match Self::get(&desc.key) {
            Some(f) => f(env, &desc.payload),
            None => Err(format!(
                "no task registered under key '{}' (registered: {})",
                desc.key,
                Self::keys().join(", ")
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_descriptor() -> TaskDescriptor {
        TaskDescriptor {
            job_id: 7,
            stage_tag: 0xA11C_0042,
            part: 3,
            attempt: 1,
            key: "fim.bottomup.vec".to_string(),
            payload: vec![1, 2, 3, 4],
        }
    }

    fn all_messages() -> Vec<Message> {
        vec![
            Message::RegisterWorker {
                worker: "w0".into(),
                pid: 4242,
            },
            Message::LaunchTask {
                task: sample_descriptor(),
            },
            Message::TaskResult {
                job_id: 7,
                stage_tag: 0xA11C_0042,
                part: 3,
                attempt: 1,
                result: Ok(vec![9, 9]),
                run_ms: 1.25,
            },
            Message::TaskResult {
                job_id: 7,
                stage_tag: 1,
                part: 0,
                attempt: 2,
                result: Err("worker exploded".into()),
                run_ms: 0.0,
            },
            Message::FetchBlock {
                shuffle_id: 5,
                reduce_part: 2,
            },
            Message::BlockData {
                shuffle_id: 5,
                reduce_part: 2,
                result: Ok(vec![(
                    BlockId {
                        shuffle_id: 5,
                        reduce_part: 2,
                        map_part: 0,
                    },
                    vec![0xAB; 16],
                    3,
                )]),
            },
            Message::BlockData {
                shuffle_id: 5,
                reduce_part: 9,
                result: Err("map stage incomplete".into()),
            },
            Message::Heartbeat {
                worker: "w1".into(),
                seq: 99,
            },
            Message::WorkerLost {
                worker: "w1".into(),
                reason: "heartbeat timeout".into(),
            },
            Message::Shutdown,
            Message::Request {
                body: vec![0xDE, 0xAD, 0xBE, 0xEF],
            },
            Message::Response { body: Vec::new() },
        ]
    }

    #[test]
    fn every_message_roundtrips_through_a_frame() {
        for msg in all_messages() {
            let mut wire = Vec::new();
            write_frame(&mut wire, &msg).unwrap();
            let back = read_frame(&mut wire.as_slice()).unwrap();
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn frames_concatenate_on_one_stream() {
        let msgs = all_messages();
        let mut wire = Vec::new();
        for m in &msgs {
            write_frame(&mut wire, m).unwrap();
        }
        let mut cursor = wire.as_slice();
        for want in &msgs {
            assert_eq!(&read_frame(&mut cursor).unwrap(), want);
        }
        assert_eq!(read_frame(&mut cursor), Err(TransportError::Closed));
    }

    #[test]
    fn truncated_frames_are_typed_errors_never_panics() {
        let mut wire = Vec::new();
        write_frame(
            &mut wire,
            &Message::LaunchTask {
                task: sample_descriptor(),
            },
        )
        .unwrap();
        // every possible truncation point
        for cut in 0..wire.len() {
            let err = read_frame(&mut &wire[..cut]).unwrap_err();
            match cut {
                0 => assert_eq!(err, TransportError::Closed, "cut {cut}"),
                _ => assert!(
                    matches!(err, TransportError::Io(_)),
                    "cut {cut}: {err:?}"
                ),
            }
        }
    }

    #[test]
    fn unknown_tag_and_oversize_are_typed() {
        // unknown tag inside a well-formed frame
        let mut wire = Vec::new();
        wire.extend_from_slice(&1u32.to_le_bytes());
        wire.push(200);
        assert_eq!(
            read_frame(&mut wire.as_slice()),
            Err(TransportError::UnknownTag(200))
        );
        // empty payload: no tag byte at all
        let empty = 0u32.to_le_bytes();
        assert!(matches!(
            read_frame(&mut empty.as_slice()),
            Err(TransportError::Codec(SerDeError::Eof { .. }))
        ));
        // corrupt length prefix past the cap
        let huge = (u32::MAX).to_le_bytes();
        assert!(matches!(
            read_frame(&mut huge.as_slice()),
            Err(TransportError::Oversize { .. })
        ));
        // trailing garbage after a valid message
        let mut payload = Message::Shutdown.to_bytes();
        payload.push(0xFF);
        let mut framed = Vec::new();
        framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        framed.extend_from_slice(&payload);
        assert!(matches!(
            read_frame(&mut framed.as_slice()),
            Err(TransportError::Codec(SerDeError::Trailing { remaining: 1 }))
        ));
        // corrupt body (bad result tag inside TaskResult)
        let mut body = Message::TaskResult {
            job_id: 1,
            stage_tag: 2,
            part: 0,
            attempt: 0,
            result: Ok(vec![]),
            run_ms: 0.0,
        }
        .to_bytes();
        body[1 + 8 + 8 + 8 + 8] = 7; // result tag byte
        let mut framed = Vec::new();
        framed.extend_from_slice(&(body.len() as u32).to_le_bytes());
        framed.extend_from_slice(&body);
        assert!(matches!(
            read_frame(&mut framed.as_slice()),
            Err(TransportError::Codec(SerDeError::Invalid { .. }))
        ));
    }

    struct MapFetcher(HashMap<(usize, usize), Vec<WireBlock>>);
    impl BlockFetcher for MapFetcher {
        fn fetch_blocks(
            &self,
            shuffle_id: usize,
            reduce_part: usize,
        ) -> Result<Vec<WireBlock>, String> {
            self.0
                .get(&(shuffle_id, reduce_part))
                .cloned()
                .ok_or_else(|| format!("no blocks for shuffle {shuffle_id}.{reduce_part}"))
        }
    }

    #[test]
    fn registry_runs_registered_keys_and_rejects_unknown() {
        TaskRegistry::register("test.echo", |_env, payload| Ok(payload.to_vec()));
        TaskRegistry::register("test.fetch-count", |env, payload| {
            let (shuffle_id, reduce_part) =
                <(usize, usize)>::from_bytes(payload).map_err(|e| e.to_string())?;
            let blocks = env.fetch_blocks(shuffle_id, reduce_part)?;
            Ok((blocks.len() as u64).to_bytes())
        });
        assert!(TaskRegistry::keys().contains(&"test.echo".to_string()));

        let mut blocks = HashMap::new();
        blocks.insert(
            (4usize, 0usize),
            vec![
                (
                    BlockId {
                        shuffle_id: 4,
                        reduce_part: 0,
                        map_part: 0,
                    },
                    vec![1],
                    1,
                ),
                (
                    BlockId {
                        shuffle_id: 4,
                        reduce_part: 0,
                        map_part: 1,
                    },
                    vec![2],
                    1,
                ),
            ],
        );
        let fetcher = MapFetcher(blocks);
        let env = TaskEnv::new(&fetcher);

        let mut desc = sample_descriptor();
        desc.key = "test.echo".into();
        assert_eq!(TaskRegistry::run(&desc, &env), Ok(vec![1, 2, 3, 4]));

        desc.key = "test.fetch-count".into();
        desc.payload = (4usize, 0usize).to_bytes();
        let out = TaskRegistry::run(&desc, &env).unwrap();
        assert_eq!(u64::from_bytes(&out), Ok(2));

        // fetch failure propagates as a task error
        desc.payload = (9usize, 9usize).to_bytes();
        assert!(TaskRegistry::run(&desc, &env).unwrap_err().contains("no blocks"));

        // unknown key: typed error listing what IS registered
        desc.key = "test.nope".into();
        let err = TaskRegistry::run(&desc, &env).unwrap_err();
        assert!(err.contains("test.nope") && err.contains("test.echo"), "{err}");
    }

    use super::super::faults::FaultPlan;

    fn plane(spec: &str) -> FaultPlane {
        FaultPlane::new(FaultPlan::parse(spec).unwrap())
    }

    #[test]
    fn injected_frame_write_fails_before_any_bytes_hit_the_stream() {
        let plane = plane("seed=3; frame_write:nth=1");
        let mut wire = Vec::new();
        let err = write_frame_with(&mut wire, &Message::Shutdown, Some(&plane)).unwrap_err();
        assert!(matches!(&err, TransportError::Io(e) if e.contains("injected")), "{err:?}");
        assert!(wire.is_empty(), "a failed write must not leave partial bytes");
        // nth=1 fired once; the retry goes through and frames normally.
        write_frame_with(&mut wire, &Message::Shutdown, Some(&plane)).unwrap();
        assert_eq!(read_frame(&mut wire.as_slice()).unwrap(), Message::Shutdown);
    }

    #[test]
    fn injected_frame_read_is_a_typed_io_error_and_stream_stays_aligned() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Message::Shutdown).unwrap();
        write_frame(
            &mut wire,
            &Message::Heartbeat {
                worker: "w0".into(),
                seq: 1,
            },
        )
        .unwrap();
        let plane = plane("seed=3; frame_read:nth=1");
        let mut cursor = wire.as_slice();
        let err = read_frame_with(&mut cursor, Some(&plane)).unwrap_err();
        assert!(matches!(&err, TransportError::Io(e) if e.contains("injected")), "{err:?}");
        // The fault fired before consuming the prefix: both frames are
        // still intact on the stream.
        assert_eq!(read_frame_with(&mut cursor, Some(&plane)).unwrap(), Message::Shutdown);
        assert!(matches!(
            read_frame_with(&mut cursor, Some(&plane)).unwrap(),
            Message::Heartbeat { .. }
        ));
    }

    #[test]
    fn corrupted_frame_decodes_as_typed_error_and_next_frame_survives() {
        let plane = plane("seed=7; frame_corrupt:nth=1");
        let mut wire = Vec::new();
        // Shutdown's payload is a single tag byte, so the one flipped
        // byte *must* hit the tag: the corruption is guaranteed to
        // surface at decode, whatever index the seed picks.
        write_frame_with(&mut wire, &Message::Shutdown, Some(&plane)).unwrap();
        // Second frame written after nth=1 fired: clean.
        write_frame_with(
            &mut wire,
            &Message::Heartbeat {
                worker: "w0".into(),
                seq: 5,
            },
            Some(&plane),
        )
        .unwrap();
        let mut cursor = wire.as_slice();
        // The corrupted payload is well-framed (length prefix intact) so
        // it decodes as a typed error, never a panic or a
        // desynchronized stream...
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(err, TransportError::UnknownTag(TAG_SHUTDOWN ^ 0xA5));
        // ...and the following frame reads back exactly.
        assert_eq!(
            read_frame(&mut cursor).unwrap(),
            Message::Heartbeat {
                worker: "w0".into(),
                seq: 5,
            }
        );
        assert_eq!(plane.injected(FaultSite::FrameCorrupt), 1);
    }

    #[test]
    fn corruption_replays_identically_for_a_seed() {
        let bytes_for = |seed: u64| {
            let plane = plane(&format!("seed={seed}; frame_corrupt:always"));
            let mut wire = Vec::new();
            write_frame_with(
                &mut wire,
                &Message::Request {
                    body: vec![0x11; 64],
                },
                Some(&plane),
            )
            .unwrap();
            wire
        };
        let clean = {
            let mut wire = Vec::new();
            write_frame(
                &mut wire,
                &Message::Request {
                    body: vec![0x11; 64],
                },
            )
            .unwrap();
            wire
        };
        assert_eq!(bytes_for(42), bytes_for(42), "same seed, same corruption");
        assert_ne!(bytes_for(42), clean, "exactly one byte differs from clean");
        assert_eq!(
            bytes_for(42)
                .iter()
                .zip(clean.iter())
                .filter(|(a, b)| a != b)
                .count(),
            1
        );
    }
}
