//! `repro` — the RDD-Eclat leader binary.
//!
//! Commands:
//!   table1                         regenerate Table 1 (dataset properties)
//!   fig --id N [--panel a|b]       regenerate Fig N (1..6)
//!   claims --id N                  run Fig N and check the paper's claims
//!   mine --dataset D --min-sup F --engine NAME --tidset vec|bitmap|auto
//!                                  one mining session (any registered engine)
//!   bench --dataset D --min-sup F  sweep engines x executor backends, emit BENCH_fim.json
//!   rules --dataset D --min-conf F mine + derive association rules
//!   generate --dataset D --out P   write a generated dataset (FIMI format)
//!   stream --dataset D --min-sup F --window N --slide N
//!                                  micro-batch sliding-window mining
//!   timeline --log PATH            replay an --event-log JSONL into a text Gantt
//!   serve --socket PATH            long-lived mining server over a unix socket
//!   query --socket PATH ...        send one mining request to a running server
//!   xla-smoke                      load + execute the AOT artifacts
//!   all                            table1 + every figure (long)
//!   help                           (or `<command> --help` for per-command flags)
//!
//! Every command validates its flags against a spec allowlist — unknown
//! or misspelled flags fail with a suggestion instead of silently
//! running with defaults. Engine names come from the `EngineRegistry`
//! and executor backend names from the `ExecutorRegistry`, so newly
//! registered engines/backends are immediately addressable.
//!
//! Shared env overrides: REPRO_SCALE, REPRO_SEED, REPRO_CORES,
//! REPRO_BENCH_REPS, REPRO_BENCH_WARMUP, REPRO_ARTIFACTS, plus the
//! engine-level SPARKLET_CORES / SPARKLET_BACKEND /
//! SPARKLET_SHUFFLE_PARTITIONS (explicit flags win over env).

use anyhow::{bail, Result};

use rdd_eclat::cli::{find_command, Args, CommandSpec, FlagSpec};
use rdd_eclat::coordinator::{experiments, report, ExperimentConfig};
use rdd_eclat::data::Dataset;
use rdd_eclat::fim::engine::{
    EngineRegistry, FimError, MiningSession, PartitionStrategy, PostStage, TidsetRepr,
};
use rdd_eclat::fim::streaming::BackpressureStats;
use rdd_eclat::fim::tidset::KernelStats;
use rdd_eclat::fim::types::{abs_min_sup, MiningResult};
use rdd_eclat::sparklet::metrics::StageKind;
use rdd_eclat::sparklet::{ExecutorRegistry, SparkletConf, SparkletContext};

fn main() -> Result<()> {
    // Register the distributed tier before the spec table is built, so
    // `--executor multi-process` validates and shows up in help.
    rdd_eclat::sparklet::remote::register_backend();
    rdd_eclat::fim::distributed::register_tasks();
    // Hidden worker entry point: `repro worker --socket PATH --id wN
    // [--heartbeat-ms MS] [--fault SPEC]`, exec'd by the multi-process
    // backend when it spawns its worker fleet. Intercepted before the
    // CLI spec layer — it is not a user-facing command and never
    // returns (the process lives until the driver shuts it down).
    let raw: Vec<String> = std::env::args().collect();
    if raw.get(1).map(String::as_str) == Some("worker") {
        return run_worker(&raw[2..]);
    }
    let specs = command_specs();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            print_help(&specs);
            std::process::exit(2);
        }
    };
    if args.command == "help" {
        print_help(&specs);
        return Ok(());
    }
    let spec = match find_command(&specs, &args.command) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            print_help(&specs);
            std::process::exit(2);
        }
    };
    if args.wants_help() {
        println!("{}", spec.render_help());
        return Ok(());
    }
    if let Err(e) = args.validate(spec) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    // The EventLogWriter appends (bench opens many short-lived contexts
    // against one log), so the CLI truncates the file exactly once per
    // invocation — each run's log starts clean.
    if let Some(path) = args.get("event-log") {
        std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("cannot create event log {path:?}: {e}"))?;
    }

    let mut cfg = ExperimentConfig::default();
    if let Some(scale) = parsed::<f64>(&args, "scale")? {
        cfg.scale = scale;
    }
    if let Some(cores) = parsed::<usize>(&args, "cores")? {
        cfg.cores = cores;
    }
    if let Some(p) = parsed::<usize>(&args, "p")? {
        cfg.p = p;
    }

    match args.command.as_str() {
        "table1" => println!("{}", experiments::table1(&cfg)),
        "fig" => run_fig(&args, &cfg)?,
        "claims" => run_claims(&args, &cfg)?,
        "mine" => run_mine(&args, &cfg)?,
        "bench" => run_bench(&args, &cfg)?,
        "generate" => run_generate(&args, &cfg)?,
        "rules" => run_rules(&args, &cfg)?,
        "stream" => run_stream(&args, &cfg)?,
        "timeline" => run_timeline(&args)?,
        "serve" => run_serve(&args, &cfg)?,
        "query" => run_query(&args)?,
        "xla-smoke" => xla_smoke()?,
        "all" => {
            println!("{}", experiments::table1(&cfg));
            for id in 1..=6 {
                run_fig_id(id, None, &cfg)?;
            }
        }
        other => bail!("unhandled command {other} (spec/dispatch mismatch)"),
    }
    Ok(())
}

fn parsed<T: std::str::FromStr>(args: &Args, name: &str) -> Result<Option<T>> {
    args.get_parse(name).map_err(anyhow::Error::msg)
}

/// The multi-process backend's worker process: register the same task
/// keys the driver uses (the key string is all that crosses the wire),
/// connect back over the Unix socket, and serve tasks until shutdown.
fn run_worker(args: &[String]) -> Result<()> {
    let mut socket: Option<String> = None;
    let mut id: Option<String> = None;
    let mut fault: Option<String> = None;
    let mut heartbeat_ms = 500u64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--socket" => socket = it.next().cloned(),
            "--id" => id = it.next().cloned(),
            "--fault" => fault = it.next().cloned(),
            "--heartbeat-ms" => {
                heartbeat_ms = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow::anyhow!("worker: --heartbeat-ms needs a number"))?;
            }
            other => bail!("worker: unknown flag {other}"),
        }
    }
    let socket = socket.ok_or_else(|| anyhow::anyhow!("worker: --socket PATH required"))?;
    let id = id.ok_or_else(|| anyhow::anyhow!("worker: --id NAME required"))?;
    rdd_eclat::sparklet::remote::worker_main(
        std::path::Path::new(&socket),
        &id,
        fault.as_deref(),
        heartbeat_ms,
    )
}

// ------------------------------------------------------------ specs/help

fn shared_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec::new("scale", "F", "dataset scale factor (default REPRO_SCALE or 0.25)"),
        FlagSpec::new("cores", "N", "executor cores (default REPRO_CORES or machine)"),
        FlagSpec::new("p", "N", "class partitions for hash/reverse-hash/weighted (default 10)"),
    ]
}

/// Per-command flag allowlists. Engine- and axis-valued flags derive
/// their accepted values from the `EngineRegistry` and the axis parsers,
/// so registering an engine extends the CLI without touching this table.
fn command_specs() -> Vec<CommandSpec> {
    let engines = EngineRegistry::names().join("|");
    let engine_flag = || FlagSpec::new("engine", "NAME", format!("engine ({engines})"));
    let executors = ExecutorRegistry::names().join("|");
    let executor_flag = || FlagSpec::new("executor", "B", format!("executor backend ({executors})"));
    let dataset_flag = || FlagSpec::new("dataset", "D", "dataset (bms1|bms2|t10|t40)");
    let minsup_flag = || FlagSpec::new("min-sup", "F", "relative minimum support (fraction of |D|)");
    // The axis flags `session_from_args` consumes — every command that
    // builds a session through it must allowlist all of these, or the
    // validator would reject flags the handler supports.
    let session_axis_flags = || {
        vec![
            engine_flag(),
            FlagSpec::new("variant", "NAME", "legacy spelling of --engine (v1..v5 etc.)"),
            FlagSpec::new(
                "tidset",
                "R",
                "tidset representation (vec|bitmap|diffset|hybrid|auto)",
            ),
            FlagSpec::new(
                "partitioner",
                "S",
                "class placement (engine|ranked|hash|reverse-hash|weighted)",
            ),
            FlagSpec::new("prefix-len", "K", "equivalence-class prefix length (1|2)"),
            FlagSpec::new("groups", "G", "PFP group shards (fpgrowth engine)"),
            FlagSpec::new("post", "S", "post-stage (closed|maximal|top=K)"),
        ]
    };
    let membudget_flag = || {
        FlagSpec::new(
            "memory-budget",
            "MB",
            "in-memory shuffle block budget in MiB; colder blocks spill to disk \
             (default: unlimited, or SPARKLET_MEMORY_MB)",
        )
    };
    let eventlog_flag = || {
        FlagSpec::new(
            "event-log",
            "PATH",
            "persist scheduler/task/shuffle events as JSONL (replay with `timeline`)",
        )
    };
    let eventlog_max_flag = || {
        FlagSpec::new(
            "event-log-max-mb",
            "MB",
            "rotate the event log to PATH.1 past this size (default: unbounded)",
        )
    };
    let socket_flag = || {
        FlagSpec::new(
            "socket",
            "PATH",
            "unix socket path (or SPARKLET_SERVE_SOCKET)",
        )
    };
    let faultplan_flag = || {
        FlagSpec::new(
            "fault-plan",
            "SPEC",
            "seeded fault-injection plan, e.g. \"seed=7; spill_read:nth=1; worker_kill=w0:2\" \
             (or SPARKLET_FAULT_PLAN; see README Fault tolerance)",
        )
    };
    let jobdeadline_flag = || {
        FlagSpec::new(
            "job-deadline-ms",
            "MS",
            "per-job wall-clock deadline; retries stop and the run fails typed past it \
             (or SPARKLET_JOB_DEADLINE_MS)",
        )
    };
    let mut mine_flags = vec![
        dataset_flag(),
        minsup_flag(),
        FlagSpec::new("tri-matrix", "on|off", "triangular-matrix Phase-2 (default: per dataset)"),
        executor_flag(),
        membudget_flag(),
        eventlog_flag(),
        eventlog_max_flag(),
        faultplan_flag(),
        jobdeadline_flag(),
    ];
    mine_flags.extend(session_axis_flags());
    mine_flags.extend(shared_flags());
    let mut bench_flags = vec![
        dataset_flag(),
        minsup_flag(),
        FlagSpec::new("engines", "CSV", "engines to sweep (default: all registered)"),
        executor_flag(),
        membudget_flag(),
        FlagSpec::new(
            "tidset",
            "R",
            "restrict the tidset sweep to one representation \
             (default: vec|bitmap|diffset|hybrid on the first backend)",
        ),
        FlagSpec::new("out", "PATH", "machine-readable output (default BENCH_fim.json)"),
        eventlog_flag(),
        eventlog_max_flag(),
        faultplan_flag(),
        jobdeadline_flag(),
    ];
    bench_flags.extend(shared_flags());
    let mut rules_flags = vec![
        dataset_flag(),
        FlagSpec::new("input", "PATH", "mine a FIMI file instead of a generated dataset"),
        minsup_flag(),
        FlagSpec::new("min-conf", "F", "minimum rule confidence (default 0.5)"),
        FlagSpec::new("top", "N", "rules to print (default 20)"),
    ];
    rules_flags.extend(session_axis_flags());
    rules_flags.extend(shared_flags());
    let mut stream_flags = vec![
        dataset_flag(),
        minsup_flag(),
        FlagSpec::new("window", "N", "window length in batches (default 4)"),
        FlagSpec::new("slide", "N", "slide length in batches (default 2)"),
        FlagSpec::new("batches", "N", "batches to run (default 10)"),
        FlagSpec::new("batch-size", "N", "transactions per batch (default 2000)"),
        executor_flag(),
        membudget_flag(),
        eventlog_flag(),
        eventlog_max_flag(),
        faultplan_flag(),
        jobdeadline_flag(),
    ];
    stream_flags.extend(session_axis_flags());
    stream_flags.extend(shared_flags());
    let mut fig_flags = vec![
        FlagSpec::new("id", "N", "figure number (1..6)"),
        FlagSpec::new("panel", "a|b", "panel for figs 1-4 (default: both)"),
    ];
    fig_flags.extend(shared_flags());
    let mut claims_flags = vec![FlagSpec::new("id", "N", "figure number (1..6, default 3)")];
    claims_flags.extend(shared_flags());
    let mut generate_flags = vec![
        dataset_flag(),
        FlagSpec::new("out", "PATH", "output path (default dataset.txt)"),
        FlagSpec::new("seed", "N", "generator seed (default REPRO_SEED)"),
    ];
    generate_flags.extend(shared_flags());
    let timeline_flags = vec![
        FlagSpec::new("log", "PATH", "event log to replay (written by --event-log)"),
        FlagSpec::new(
            "width",
            "N",
            "Gantt bar width in characters (default 40, clamped to 10..200)",
        ),
    ];
    let mut serve_flags = vec![
        socket_flag(),
        FlagSpec::new(
            "queue-depth",
            "N",
            "admission queue depth before Overloaded rejections (default 16)",
        ),
        FlagSpec::new(
            "tenant-rate",
            "F",
            "per-tenant requests/second before Throttled (default 0 = off)",
        ),
        FlagSpec::new(
            "cache-budget",
            "MB",
            "result-cache byte budget, LRU-evicted (default: unlimited)",
        ),
        FlagSpec::new(
            "deadline-ms",
            "MS",
            "per-request deadline; requests past it reject typed with exit 3 at the client \
             (or SPARKLET_SERVE_DEADLINE_MS)",
        ),
        executor_flag(),
        membudget_flag(),
        eventlog_flag(),
        eventlog_max_flag(),
        faultplan_flag(),
        jobdeadline_flag(),
    ];
    serve_flags.extend(shared_flags());
    let query_flags = vec![
        socket_flag(),
        dataset_flag(),
        minsup_flag(),
        engine_flag(),
        FlagSpec::new(
            "tidset",
            "R",
            "tidset representation (vec|bitmap|diffset|hybrid|auto)",
        ),
        FlagSpec::new("post", "S", "post-stage (closed|maximal|top=K); repeatable"),
        FlagSpec::new("min-conf", "F", "also derive rules at this confidence (default: off)"),
        FlagSpec::new("tenant", "ID", "tenant id for load shedding (default \"cli\")"),
        FlagSpec::new("shutdown", "", "ask the server to shut down gracefully"),
    ];

    vec![
        CommandSpec::new("table1", "dataset properties (Table 1)", shared_flags()),
        CommandSpec::new("fig", "regenerate figure N in 1..6", fig_flags),
        CommandSpec::new("claims", "figure N + paper-claim checks", claims_flags),
        CommandSpec::new("mine", "one mining session through the unified API", mine_flags),
        CommandSpec::new("bench", "sweep engines x executor backends; emit BENCH_fim.json", bench_flags),
        CommandSpec::new("rules", "mine + derive association rules", rules_flags),
        CommandSpec::new("generate", "write a generated dataset (FIMI format)", generate_flags),
        CommandSpec::new("stream", "micro-batch sliding-window mining", stream_flags),
        CommandSpec::new("timeline", "replay an --event-log JSONL into a text Gantt", timeline_flags),
        CommandSpec::new("serve", "long-lived mining server over a unix socket", serve_flags),
        CommandSpec::new("query", "send one mining request to a running server", query_flags),
        CommandSpec::new("xla-smoke", "verify the XLA/PJRT artifact path", Vec::new()),
        CommandSpec::new("all", "table1 + every figure (long)", shared_flags()),
        CommandSpec::new("help", "this overview", Vec::new()),
    ]
}

fn print_help(specs: &[CommandSpec]) {
    println!("repro — RDD-Eclat reproduction (see README.md)\n");
    println!("USAGE: repro <command> [flags]   (repro <command> --help for flags)\n");
    println!("COMMANDS:");
    for s in specs {
        println!("  {:<12} {}", s.name, s.about);
    }
    println!("\nENGINES (mine/bench/rules/stream --engine):");
    print!("{}", EngineRegistry::describe_all());
    println!("\nEXECUTORS (mine/bench/stream --executor):");
    print!("{}", ExecutorRegistry::describe_all());
    println!(
        "\nENV: REPRO_SCALE REPRO_SEED REPRO_CORES REPRO_BENCH_REPS \
         SPARKLET_CORES SPARKLET_BACKEND SPARKLET_SHUFFLE_PARTITIONS \
         SPARKLET_SERVE_SOCKET SPARKLET_FAULT_PLAN SPARKLET_RETRY_BACKOFF_MS \
         SPARKLET_JOB_DEADLINE_MS SPARKLET_SERVE_DEADLINE_MS"
    );
}

// -------------------------------------------------------------- commands

fn parse_dataset(name: &str) -> Result<Dataset> {
    Ok(match name.to_lowercase().as_str() {
        "bms1" | "bms_webview_1" => Dataset::Bms1,
        "bms2" | "bms_webview_2" => Dataset::Bms2,
        "t10" | "t10i4d100k" => Dataset::T10I4D100K,
        "t40" | "t40i10d100k" => Dataset::T40I10D100K,
        other => bail!("unknown dataset {other} (bms1|bms2|t10|t40)"),
    })
}

fn fig_dataset(id: usize) -> Result<Dataset> {
    Ok(match id {
        1 => Dataset::Bms1,
        2 => Dataset::Bms2,
        3 => Dataset::T10I4D100K,
        4 => Dataset::T40I10D100K,
        _ => bail!("figures 1-4 are min_sup sweeps; got {id}"),
    })
}

fn run_fig(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let id: usize = parsed(args, "id")?.ok_or_else(|| anyhow::anyhow!("--id 1..6 required"))?;
    let panel = args.get("panel").map(|s| s.to_string());
    run_fig_id(id, panel, cfg)
}

fn run_fig_id(id: usize, panel: Option<String>, cfg: &ExperimentConfig) -> Result<()> {
    match id {
        1..=4 => {
            let d = fig_dataset(id)?;
            let panels: Vec<bool> = match panel.as_deref() {
                Some("a") => vec![true],
                Some("b") => vec![false],
                _ => vec![true, false],
            };
            for with_apriori in panels {
                experiments::fig_minsup(id, d, with_apriori, cfg).finish();
            }
        }
        5 => {
            experiments::fig_cores(Dataset::Bms2, 0.001, cfg).finish();
            experiments::fig_cores(Dataset::T40I10D100K, 0.01, cfg).finish();
        }
        6 => experiments::fig_scaling(cfg).finish(),
        _ => bail!("--id must be 1..6"),
    }
    Ok(())
}

fn run_claims(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let id: usize = parsed(args, "id")?.unwrap_or(3);
    match id {
        1..=4 => {
            let d = fig_dataset(id)?;
            let suite = experiments::fig_minsup(id, d, true, cfg);
            suite.finish();
            let checks = vec![
                report::check_eclat_beats_apriori(&suite),
                report::check_gap_widens(&suite),
                report::check_v45_beat_v23(&suite),
            ];
            println!("{}", report::render_claims(&checks));
        }
        5 => {
            let suite = experiments::fig_cores(Dataset::Bms2, 0.001, cfg);
            suite.finish();
            println!(
                "{}",
                report::render_claims(&[report::check_core_scaling(&suite)])
            );
        }
        6 => {
            let suite = experiments::fig_scaling(cfg);
            suite.finish();
            println!(
                "{}",
                report::render_claims(&[report::check_linear_scaling(&suite)])
            );
        }
        _ => bail!("--id must be 1..6"),
    }
    Ok(())
}

/// Engine configuration shared by the mine-like commands. Precedence,
/// weakest first: `REPRO_CORES`/machine default, `SPARKLET_*` env
/// overrides, explicit `--cores`/`--executor` flags. Every value is
/// validated (typed `ConfError`s, not asserts).
fn conf_from_args(args: &Args, cfg: &ExperimentConfig) -> Result<SparkletConf> {
    let mut conf = SparkletConf::new("repro").with_cores(cfg.cores.max(1))?;
    conf = conf.with_env_overrides()?;
    if let Some(cores) = parsed::<usize>(args, "cores")? {
        // The flag beats SPARKLET_CORES, but with_cores also resets
        // shuffle_partitions — preserve an explicit
        // SPARKLET_SHUFFLE_PARTITIONS override across it.
        let env_partitions = std::env::var("SPARKLET_SHUFFLE_PARTITIONS")
            .ok()
            .filter(|v| !v.is_empty())
            .map(|_| conf.shuffle_partitions);
        conf = conf.with_cores(cores)?;
        if let Some(partitions) = env_partitions {
            conf = conf.with_shuffle_partitions(partitions)?;
        }
    }
    if let Some(backend) = args.get("executor") {
        conf = conf.with_executor_backend(backend)?;
    }
    if let Some(mb) = parsed::<usize>(args, "memory-budget")? {
        conf = conf.with_memory_budget_mb(mb)?;
    }
    if let Some(path) = args.get("event-log") {
        conf = conf.with_event_log(path);
    }
    if let Some(mb) = parsed::<usize>(args, "event-log-max-mb")? {
        conf = conf.with_event_log_max_mb(mb)?;
    }
    if let Some(spec) = args.get("fault-plan") {
        conf = conf.with_fault_plan(spec)?;
    }
    if let Some(ms) = parsed::<u64>(args, "job-deadline-ms")? {
        conf = conf.with_job_deadline_ms(ms)?;
    }
    Ok(conf)
}

fn context_from_args(args: &Args, cfg: &ExperimentConfig) -> Result<SparkletContext> {
    Ok(SparkletContext::try_new(conf_from_args(args, cfg)?)?)
}

/// Resolve `--engine` (with `--variant` as the legacy spelling) against
/// the registry, failing with the registry's own suggestion-bearing
/// error on unknown names.
fn engine_from_args(args: &Args, default: &str) -> Result<String> {
    let name = args
        .get("engine")
        .or_else(|| args.get("variant"))
        .unwrap_or(default);
    match EngineRegistry::get(name) {
        Some(e) => Ok(e.name().to_string()),
        None => bail!(FimError::UnknownEngine {
            name: name.to_string(),
            suggestion: EngineRegistry::suggest(name).map(str::to_string),
        }),
    }
}

fn parse_post(s: &str) -> Result<PostStage> {
    // One grammar for the CLI and the serve wire protocol.
    PostStage::parse(s).map_err(|e| anyhow::anyhow!("--post: {e}"))
}

/// Build a `MiningSession` from the axis flags shared by mine-like
/// commands.
fn session_from_args(args: &Args, cfg: &ExperimentConfig, default_engine: &str) -> Result<MiningSession> {
    let engine = engine_from_args(args, default_engine)?;
    let mut session = MiningSession::new(engine).p(cfg.p);
    if let Some(repr) = args.get("tidset") {
        session = session.tidset(TidsetRepr::parse(repr).map_err(anyhow::Error::msg)?);
    }
    if let Some(s) = args.get("partitioner") {
        session = session.partitioning(PartitionStrategy::parse(s).map_err(anyhow::Error::msg)?);
    }
    if let Some(k) = parsed::<usize>(args, "prefix-len")? {
        if !(1..=2).contains(&k) {
            bail!("--prefix-len must be 1 or 2");
        }
        session = session.prefix_len(k);
    }
    if let Some(g) = parsed::<usize>(args, "groups")? {
        session = session.n_groups(g);
    }
    for post in args.get_all("post") {
        session = session.post(parse_post(post)?);
    }
    Ok(session)
}

fn run_mine(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let dataset = parse_dataset(args.get_or("dataset", "t10"))?;
    let min_sup_frac: f64 = parsed(args, "min-sup")?.unwrap_or(0.01);
    let tri_matrix = match args.get("tri-matrix") {
        Some("on") | Some("true") => true,
        Some("off") | Some("false") => false,
        Some(other) => bail!("--tri-matrix must be on|off, got {other:?}"),
        // bare `--tri-matrix` means on; only full absence falls back to
        // the dataset's paper default
        None if args.flag("tri-matrix") => true,
        None => dataset.tri_matrix_mode(),
    };
    let session = session_from_args(args, cfg, "eclat-v4")?
        .min_sup_frac(min_sup_frac)
        .tri_matrix(tri_matrix);
    let txns = dataset.generate_scaled(cfg.seed, cfg.scale);
    let sc = context_from_args(args, cfg)?;
    println!(
        "mining {} ({} txns, scale {}) at min_sup {} with engine {} on {} cores ({} executor)",
        dataset.name(),
        txns.len(),
        cfg.scale,
        min_sup_frac,
        session.engine_name(),
        sc.executor().cores(),
        sc.executor().name()
    );
    let report = session.run_vec(&sc, &txns)?;
    println!("{}", report.summary());
    let hist = report.result.histogram();
    for (k, count) in hist.iter().enumerate() {
        println!("  L{}: {count}", k + 1);
    }
    if !report.stages.is_empty() {
        println!("per-phase stages:");
        for (i, s) in report.stages.iter().enumerate() {
            println!(
                "  stage {i:>2} {:<11} {:>3} tasks {:>9.1} ms  shuffle {:>7} rec / {:>9} B  \
                 {:>3} spilled  {:>3} steals  {:>7.1} ms queued",
                format!("{:?}", s.kind),
                s.num_tasks,
                s.wall.as_secs_f64() * 1e3,
                s.shuffle_records,
                s.shuffle_bytes,
                s.spilled_blocks,
                s.steals,
                s.queue_wait_ms
            );
        }
    }
    println!(
        "kernel: {} intersections @ {:.0} ∩/s, {} early-aborts, {} repr switches, \
         ~{} B allocated",
        report.kernel.intersections,
        report.kernel.intersections_per_sec(),
        report.kernel.early_aborts,
        report.kernel.repr_switches,
        report.kernel.bytes_allocated
    );
    println!("shuffle: {}", sc.shuffle_manager().spill_summary());
    Ok(())
}

/// Sweep engines × executor backends over one dataset/support point and
/// write the machine-readable `BENCH_fim.json` (the perf-trajectory
/// artifact CI and later PRs diff against). `--executor` restricts the
/// sweep to one backend; the default sweeps every registered backend.
fn run_bench(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let dataset = parse_dataset(args.get_or("dataset", "t10"))?;
    let min_sup_frac: f64 = parsed(args, "min-sup")?.unwrap_or(0.01);
    let out_path = args.get_or("out", "BENCH_fim.json").to_string();
    let engines: Vec<String> = match args.get("engines") {
        None => experiments::registry_roster().iter().map(|s| s.to_string()).collect(),
        Some("all") => experiments::registry_roster().iter().map(|s| s.to_string()).collect(),
        Some(csv) => csv.split(',').map(|s| s.trim().to_string()).collect(),
    };
    // `--executor` (or, absent that, SPARKLET_BACKEND) restricts the
    // sweep to one backend — validated through the conf builder so
    // unknown names fail with the registry's suggestion-bearing error.
    let restrict = args
        .get("executor")
        .map(str::to_string)
        .or_else(|| std::env::var("SPARKLET_BACKEND").ok().filter(|v| !v.is_empty()));
    let backends: Vec<String> = match restrict {
        Some(name) => vec![
            SparkletConf::default()
                .with_executor_backend(&name)?
                .executor_backend,
        ],
        // The default sweep stays in-process: multi-process spawns a
        // worker fleet per context, which would dominate the short
        // bench rows with process startup. Opt in with --executor.
        None => ExecutorRegistry::names()
            .iter()
            .filter(|n| **n != "multi-process")
            .map(|s| s.to_string())
            .collect(),
    };
    // Tidset-representation sweep: on the *first* backend every
    // tidset-sensitive engine (the Eclat family) runs once per concrete
    // representation — those are the BENCH_fim.json rows the kernel
    // perf trajectory tracks (diffset/hybrid vs the seed vec). The
    // remaining backends and the representation-blind engines
    // (apriori/fpgrowth) run vec-only. `--tidset R` restricts the whole
    // sweep to R.
    let repr_restrict = match args.get("tidset") {
        Some(r) => Some(TidsetRepr::parse(r).map_err(anyhow::Error::msg)?),
        None => None,
    };
    let txns = dataset.generate_scaled(cfg.seed, cfg.scale);
    let min_sup = abs_min_sup(min_sup_frac, txns.len());
    println!(
        "bench: {} ({} txns, scale {}) at min_sup {} ({} abs), {} engines x {} backends, {} cores",
        dataset.name(),
        txns.len(),
        cfg.scale,
        min_sup_frac,
        min_sup,
        engines.len(),
        backends.len(),
        cfg.cores
    );
    let mut rows: Vec<String> = Vec::new();
    for (backend_idx, backend) in backends.iter().enumerate() {
        for name in &engines {
            // capability-driven, so a newly registered tidset-bearing
            // engine joins the repr sweep without CLI changes
            let tidset_sensitive = EngineRegistry::get(name)
                .map(|e| e.tidset_sensitive())
                .unwrap_or(false);
            let reprs: Vec<TidsetRepr> = match repr_restrict {
                Some(r) => vec![r],
                None if backend_idx == 0 && tidset_sensitive => {
                    TidsetRepr::all_concrete().to_vec()
                }
                None => vec![TidsetRepr::Vec],
            };
            for repr in reprs {
                let conf = conf_from_args(args, cfg)?.with_executor_backend(backend)?;
                let sc = SparkletContext::try_new(conf)?;
                let report = MiningSession::new(name.as_str())
                    .min_sup(min_sup)
                    .tidset(repr)
                    .tri_matrix(dataset.tri_matrix_mode())
                    .p(cfg.p)
                    .run_vec(&sc, &txns)?;
                let steals: usize = report.stages.iter().map(|s| s.steals).sum();
                let queue_wait_ms: f64 = report.stages.iter().map(|s| s.queue_wait_ms).sum();
                // Per-run spill counters (fresh context per row, so the
                // manager totals are this run's totals — exact bytes).
                let spilled = sc.shuffle_manager().spilled_blocks();
                let reloads = sc.shuffle_manager().spill_reloads();
                println!(
                    "  {:<14} {:<14} {:<8} {:>7} itemsets {:>9.1} ms  {:>3} stages  \
                     shuffle {:>8} rec / {:>10} B  {:>4} spilled  {:>4} steals  \
                     {:>9} ∩ / {:>8} aborts",
                    backend,
                    report.label,
                    repr.name(),
                    report.result.len(),
                    report.wall_ms,
                    report.n_stages(),
                    report.shuffle_records(),
                    report.shuffle_bytes(),
                    spilled,
                    steals,
                    report.kernel.intersections,
                    report.kernel.early_aborts,
                );
                rows.push(
                    BenchRow {
                        engine: report.engine,
                        label: report.label,
                        backend,
                        tidset: repr.name(),
                        dataset: dataset.name(),
                        min_sup_frac,
                        min_sup_abs: min_sup,
                        transactions: txns.len(),
                        itemsets: report.result.len(),
                        wall_ms: report.wall_ms,
                        stages: report.n_stages(),
                        shuffle_records: report.shuffle_records(),
                        shuffle_bytes: report.shuffle_bytes(),
                        steals,
                        queue_wait_ms,
                        task_percentiles: report.task_percentiles(),
                        task_skew: report.skew_factor(),
                        kernel: report.kernel,
                        memory_budget: sc.conf().memory_budget,
                        spilled_blocks: spilled,
                        spill_reloads: reloads,
                        bp: None,
                    }
                    .to_json(),
                );
            }
        }
    }
    // Streaming backpressure probe: one incremental-miner row on the
    // first backend, per-batch re-mines driving a live exact-byte
    // signal through the AIMD controller (bp_* fields are real here,
    // zero on the batch rows above).
    let probe_backend = backends.first().map(String::as_str).unwrap_or("fifo");
    rows.push(bench_stream_probe_row(
        args,
        cfg,
        dataset,
        &txns,
        min_sup,
        min_sup_frac,
        probe_backend,
    )?);
    std::fs::write(&out_path, format!("[\n{}\n]\n", rows.join(",\n")))?;
    println!(
        "wrote {out_path} ({} rows: {} engines x {} backends, tidset sweep on {})",
        rows.len(),
        engines.len(),
        backends.len(),
        backends.first().map(String::as_str).unwrap_or("-"),
    );
    Ok(())
}

/// One `BENCH_fim.json` row. Single serialization point for the batch
/// sweep and the streaming probe, so the row schema cannot drift
/// between them (ci.sh asserts every field on every row).
struct BenchRow<'a> {
    engine: &'a str,
    label: &'a str,
    backend: &'a str,
    tidset: &'a str,
    dataset: &'a str,
    min_sup_frac: f64,
    min_sup_abs: u32,
    transactions: usize,
    itemsets: usize,
    wall_ms: f64,
    stages: usize,
    shuffle_records: u64,
    shuffle_bytes: u64,
    steals: usize,
    queue_wait_ms: f64,
    /// Task-duration distribution across every stage of the run:
    /// (p50, p95, p99) in ms plus max/median skew — the load-balance
    /// signal the perf trajectory tracks alongside wall time.
    task_percentiles: (f64, f64, f64),
    task_skew: f64,
    kernel: KernelStats,
    /// Budget in bytes (as configured); emitted as MiB or null.
    memory_budget: Option<usize>,
    spilled_blocks: u64,
    spill_reloads: u64,
    /// `None` for batch rows (fields emitted as zeros/null).
    bp: Option<BackpressureStats>,
}

impl BenchRow<'_> {
    fn to_json(&self) -> String {
        let budget_mb = self
            .memory_budget
            .map(|b| (b / (1024 * 1024)).to_string())
            .unwrap_or_else(|| "null".into());
        let (bp_shrinks, bp_recoveries, bp_watermark) = self
            .bp
            .as_ref()
            .map_or((0, 0, 0), |bp| (bp.shrinks, bp.recoveries, bp.watermark_bytes));
        let bp_effective = self
            .bp
            .as_ref()
            .and_then(|bp| bp.effective_limit)
            .map(|l| l.to_string())
            .unwrap_or_else(|| "null".into());
        format!(
            "  {{\"engine\": \"{}\", \"label\": \"{}\", \"backend\": \"{}\", \
             \"tidset\": \"{}\", \"dataset\": \"{}\", \"min_sup\": {}, \
             \"min_sup_abs\": {}, \"transactions\": {}, \"itemsets\": {}, \
             \"wall_ms\": {:.3}, \"stages\": {}, \"shuffle_records\": {}, \
             \"shuffle_bytes\": {}, \"steals\": {}, \"queue_wait_ms\": {:.3}, \
             \"task_p50_ms\": {:.3}, \"task_p95_ms\": {:.3}, \
             \"task_p99_ms\": {:.3}, \"task_skew\": {:.3}, \
             \"kernel_intersections\": {}, \"kernel_early_aborts\": {}, \
             \"kernel_repr_switches\": {}, \"kernel_bytes_allocated\": {}, \
             \"kernel_nanos\": {}, \"intersections_per_sec\": {:.1}, \
             \"memory_budget_mb\": {}, \"spilled_blocks\": {}, \
             \"spill_reloads\": {}, \"bp_shrinks\": {}, \"bp_recoveries\": {}, \
             \"bp_effective_batch\": {}, \"bp_watermark_bytes\": {}}}",
            self.engine,
            self.label,
            self.backend,
            self.tidset,
            self.dataset,
            self.min_sup_frac,
            self.min_sup_abs,
            self.transactions,
            self.itemsets,
            self.wall_ms,
            self.stages,
            self.shuffle_records,
            self.shuffle_bytes,
            self.steals,
            self.queue_wait_ms,
            self.task_percentiles.0,
            self.task_percentiles.1,
            self.task_percentiles.2,
            self.task_skew,
            self.kernel.intersections,
            self.kernel.early_aborts,
            self.kernel.repr_switches,
            self.kernel.bytes_allocated,
            self.kernel.nanos,
            self.kernel.intersections_per_sec(),
            budget_mb,
            self.spilled_blocks,
            self.spill_reloads,
            bp_shrinks,
            bp_recoveries,
            bp_effective,
            bp_watermark,
        )
    }
}

/// One `BENCH_fim.json` row from a streaming run with backpressure: the
/// dataset is replayed as micro-batches into an `IncrementalEclat`
/// whose AIMD controller watches the context's exact shuffle-byte
/// counter, fed by a per-batch batch re-mine through the session API.
#[allow(clippy::too_many_arguments)]
fn bench_stream_probe_row(
    args: &Args,
    cfg: &ExperimentConfig,
    dataset: Dataset,
    txns: &[rdd_eclat::fim::Transaction],
    min_sup: u32,
    min_sup_frac: f64,
    backend: &str,
) -> Result<String> {
    use rdd_eclat::fim::streaming::{
        BackpressureConfig, IncrementalEclat, StreamingEclatConfig,
    };
    use rdd_eclat::fim::tidset::kernel;

    let conf = conf_from_args(args, cfg)?.with_executor_backend(backend)?;
    let sc = SparkletContext::try_new(conf)?;
    let watermark = 32 * 1024u64;
    let bcfg = StreamingEclatConfig::new(min_sup.max(1), 4, 2)
        .with_backpressure(BackpressureConfig::new(watermark));
    let mut miner = IncrementalEclat::new(bcfg).with_context(sc.clone());
    let session = MiningSession::new("eclat-v3")
        .min_sup(min_sup.max(1))
        .tri_matrix(dataset.tri_matrix_mode())
        .p(cfg.p);

    let kernel_mark = kernel::snapshot();
    let t0 = std::time::Instant::now();
    let chunk_len = (txns.len() / 8).max(1);
    let mut itemsets = 0usize;
    let mut windows = 0usize;
    for (i, chunk) in txns.chunks(chunk_len).enumerate() {
        let _ = miner.push_batch(chunk)?;
        // the per-batch re-mine is the probe's shuffle-byte workload
        let _ = session.run_vec(&sc, chunk)?;
        if (i + 1) % 2 == 0 {
            itemsets = miner.mine_window().len();
            windows += 1;
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let kernel_stats = kernel::snapshot().since(&kernel_mark);
    let report = miner.report();
    let bp = report.backpressure.expect("probe runs with backpressure");
    let stages = sc.metrics().stages();
    let steals: usize = stages.iter().map(|s| s.steals).sum();
    let queue_wait_ms: f64 = stages.iter().map(|s| s.queue_wait_ms).sum();
    use rdd_eclat::sparklet::events::{aggregate_skew, aggregate_task_quantile};
    let task_percentiles = (
        aggregate_task_quantile(&stages, 0.50),
        aggregate_task_quantile(&stages, 0.95),
        aggregate_task_quantile(&stages, 0.99),
    );
    let task_skew = aggregate_skew(&stages);
    println!(
        "  {:<14} {:<14} {:<8} {:>7} itemsets {:>9.1} ms  {windows} windows  \
         bp: {} shrinks / {} recoveries, {} B/batch (watermark {} B)",
        backend,
        "IncrementalBP",
        "vec",
        itemsets,
        wall_ms,
        bp.shrinks,
        bp.recoveries,
        bp.last_bytes_per_batch,
        bp.watermark_bytes,
    );
    Ok(BenchRow {
        engine: "incremental-stream",
        label: "IncrementalBP",
        backend,
        tidset: "vec",
        dataset: dataset.name(),
        min_sup_frac,
        min_sup_abs: min_sup,
        transactions: txns.len(),
        itemsets,
        wall_ms,
        stages: stages.len(),
        shuffle_records: sc.metrics().total_shuffle_records(),
        shuffle_bytes: sc.metrics().total_shuffle_bytes(),
        steals,
        queue_wait_ms,
        task_percentiles,
        task_skew,
        kernel: kernel_stats,
        memory_budget: sc.conf().memory_budget,
        spilled_blocks: sc.shuffle_manager().spilled_blocks(),
        spill_reloads: sc.shuffle_manager().spill_reloads(),
        bp: Some(bp),
    }
    .to_json())
}

/// Write a generated benchmark dataset to disk in FIMI format.
fn run_generate(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let dataset = parse_dataset(args.get_or("dataset", "t10"))?;
    let out = args.get_or("out", "dataset.txt").to_string();
    let txns = dataset.generate_scaled(parsed(args, "seed")?.unwrap_or(cfg.seed), cfg.scale);
    rdd_eclat::data::write_transactions(&out, &txns)?;
    let stats = rdd_eclat::data::DatasetStats::compute(&txns);
    println!("wrote {out}: {stats}");
    Ok(())
}

/// Mine + derive association rules from a dataset (generated or a file
/// via --input) — a session with rule generation attached.
fn run_rules(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let txns = if let Some(path) = args.get("input") {
        rdd_eclat::data::read_transactions(path)?
    } else {
        parse_dataset(args.get_or("dataset", "t10"))?.generate_scaled(cfg.seed, cfg.scale)
    };
    let min_sup_frac: f64 = parsed(args, "min-sup")?.unwrap_or(0.01);
    let min_conf: f64 = parsed(args, "min-conf")?.unwrap_or(0.5);
    let top: usize = parsed(args, "top")?.unwrap_or(20);
    let session = session_from_args(args, cfg, "eclat-v5")?
        .min_sup_frac(min_sup_frac)
        .rules(min_conf);
    let sc = context_from_args(args, cfg)?;
    let report = session.run_vec(&sc, &txns)?;
    let rules = report.rules.as_deref().unwrap_or(&[]);
    println!(
        "{} itemsets, {} rules (min_sup={min_sup_frac}, min_conf={min_conf}); top {top}:",
        report.result.len(),
        rules.len()
    );
    for r in rules.iter().take(top) {
        println!("  {r}");
    }
    Ok(())
}

/// Micro-batch streaming mine: a generator-driven DStream of transaction
/// batches, sliding-window incremental Eclat per window, checked and
/// timed against a from-scratch re-mine (through the unified session,
/// on any registered engine) of the same window.
fn run_stream(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    use rdd_eclat::fim::streaming::{attach_checked_incremental_eclat, StreamingEclatConfig};
    use rdd_eclat::sparklet::StreamContext;

    let dataset = parse_dataset(args.get_or("dataset", "bms2"))?;
    let min_sup_frac: f64 = parsed(args, "min-sup")?.unwrap_or(0.005);
    let window: usize = parsed(args, "window")?.unwrap_or(4);
    let slide: usize = parsed(args, "slide")?.unwrap_or(2);
    let n_batches: usize = parsed(args, "batches")?.unwrap_or(10);
    let batch_size: usize = parsed(args, "batch-size")?.unwrap_or(2_000);

    let min_sup = abs_min_sup(min_sup_frac, window * batch_size);
    let session = session_from_args(args, cfg, "eclat-v5")?
        .min_sup(min_sup)
        .tri_matrix(dataset.tri_matrix_mode());
    let sc = context_from_args(args, cfg)?;
    println!(
        "streaming {}: {} batches x {} txns, window {} slide {} (batches), \
         min_sup {} ({} abs/window), cross-check engine {}, {} cores ({} executor)",
        dataset.name(),
        n_batches,
        batch_size,
        window,
        slide,
        min_sup_frac,
        min_sup,
        session.engine_name(),
        sc.executor().cores(),
        sc.executor().name()
    );
    let ssc = StreamContext::new(sc.clone());
    let batch_scale = batch_size as f64 / dataset.table1_row().0 as f64;
    let seed = cfg.seed;
    let source = ssc.generator_stream(cfg.cores.max(1), move |t| {
        dataset.generate_scaled(seed.wrapping_add(t as u64).wrapping_mul(0x9E37_79B9), batch_scale)
    });

    let miner = attach_checked_incremental_eclat(
        &source,
        StreamingEclatConfig::new(min_sup, window, slide),
        session,
        |w| {
            println!(
                "  window @t={:<3} {:>6} txns  {:>6} itemsets  incremental {:>8.1} ms  \
                 full {:>8.1} ms  ({:.1}x)",
                w.tick,
                w.n_txns,
                w.itemsets.len(),
                w.inc_ms,
                w.full_ms,
                w.full_ms / w.inc_ms.max(0.001)
            );
        },
    );
    ssc.run_batches(n_batches);

    println!("incremental miner: {}", miner.lock().unwrap().report());
    println!("shuffle: {}", sc.shuffle_manager().spill_summary());
    // The incremental miner's border recomputation runs through the
    // executor: show how many tasks each window had in flight.
    let streaming: Vec<_> = sc
        .metrics()
        .stages()
        .into_iter()
        .filter(|s| s.kind == StageKind::Streaming)
        .collect();
    if let Some(max_tasks) = streaming.iter().map(|s| s.num_tasks).max() {
        println!(
            "border recomputation: {} windows through executor '{}', \
             up to {} concurrent tasks/window, {} steals",
            streaming.len(),
            streaming.first().map(|s| s.backend).unwrap_or("?"),
            max_tasks,
            streaming.iter().map(|s| s.steals).sum::<usize>()
        );
    }
    println!("engine: {}", sc.metrics().report());
    Ok(())
}

/// Replay a persisted `--event-log` JSONL offline: per-stage text Gantt
/// with task percentiles, skew, stragglers, queue-wait vs run split, and
/// spill/backpressure annotations. Pure log processing — no mining run.
fn run_timeline(args: &Args) -> Result<()> {
    let path = args
        .get("log")
        .ok_or_else(|| anyhow::anyhow!("--log PATH required (written by --event-log)"))?;
    let width: usize = parsed(args, "width")?.unwrap_or(rdd_eclat::timeline::DEFAULT_WIDTH);
    let rendered = rdd_eclat::timeline::render_file(path, width).map_err(anyhow::Error::msg)?;
    print!("{rendered}");
    Ok(())
}

/// Long-lived mining server: one persistent context, a unix socket, and
/// the serve pipeline (per-tenant shedding, bounded admission against
/// the shuffle memory budget, subsuming result cache). Runs until a
/// `query --shutdown` arrives.
fn run_serve(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    use rdd_eclat::serve::{DatasetResolver, Server};

    let socket = args
        .get("socket")
        .map(str::to_string)
        .or_else(|| std::env::var("SPARKLET_SERVE_SOCKET").ok().filter(|v| !v.is_empty()))
        .ok_or_else(|| anyhow::anyhow!("--socket PATH required (or SPARKLET_SERVE_SOCKET)"))?;
    let mut conf = conf_from_args(args, cfg)?.with_serve_socket(&socket);
    if let Some(n) = parsed::<usize>(args, "queue-depth")? {
        conf = conf.with_serve_queue_depth(n)?;
    }
    if let Some(rate) = parsed::<f64>(args, "tenant-rate")? {
        conf = conf.with_serve_tenant_rate(rate)?;
    }
    if let Some(mb) = parsed::<usize>(args, "cache-budget")? {
        conf = conf.with_serve_cache_budget_mb(mb)?;
    }
    if let Some(ms) = parsed::<u64>(args, "deadline-ms")? {
        conf = conf.with_serve_deadline_ms(ms)?;
    }
    let sc = SparkletContext::try_new(conf)?;
    // Requests name datasets; the server resolves them through the same
    // generators as the batch commands (REPRO_SCALE/--scale applies) and
    // memoizes, so the first query per dataset pays generation once.
    let seed = cfg.seed;
    let scale = cfg.scale;
    let resolver: DatasetResolver = std::sync::Arc::new(move |name: &str| {
        let dataset = parse_dataset(name).map_err(|e| e.to_string())?;
        Ok(dataset.generate_scaled(seed, scale))
    });
    println!(
        "serving on {socket}: {} executor, {} cores, queue depth {}, tenant rate {}/s, \
         cache budget {}, memory budget {}",
        sc.executor().name(),
        sc.executor().cores(),
        sc.conf().serve_queue_depth,
        sc.conf().serve_tenant_rate,
        sc.conf()
            .serve_cache_budget
            .map(|b| format!("{} MiB", b / (1024 * 1024)))
            .unwrap_or_else(|| "unlimited".into()),
        sc.conf()
            .memory_budget
            .map(|b| format!("{} MiB", b / (1024 * 1024)))
            .unwrap_or_else(|| "unlimited".into()),
    );
    let server = std::sync::Arc::new(Server::new(sc, resolver));
    server.run(&socket).map_err(anyhow::Error::msg)?;
    println!("serve: shut down cleanly");
    Ok(())
}

/// One-shot client for a running `serve` instance. Prints the cache
/// disposition and the itemset histogram (same `L{k}` lines as `mine`,
/// so outputs diff directly). Exits 3 on Overloaded/Throttled so shell
/// callers can distinguish load shedding from hard errors.
fn run_query(args: &Args) -> Result<()> {
    use rdd_eclat::serve::{ServeError, ServeRequest, ServeResponse};
    use rdd_eclat::sparklet::transport::{read_frame, write_frame};
    use std::os::unix::net::UnixStream;

    let socket = args
        .get("socket")
        .map(str::to_string)
        .or_else(|| std::env::var("SPARKLET_SERVE_SOCKET").ok().filter(|v| !v.is_empty()))
        .ok_or_else(|| anyhow::anyhow!("--socket PATH required (or SPARKLET_SERVE_SOCKET)"))?;
    let req = ServeRequest {
        tenant: args.get_or("tenant", "cli").to_string(),
        dataset: args.get_or("dataset", "t10").to_string(),
        min_sup_frac: parsed(args, "min-sup")?.unwrap_or(0.01),
        engine: args.get_or("engine", "eclat-v4").to_string(),
        tidset: args.get_or("tidset", "auto").to_string(),
        post: args.get_all("post").iter().map(|s| s.to_string()).collect(),
        min_conf: parsed(args, "min-conf")?.unwrap_or(0.0),
        shutdown: args.flag("shutdown"),
    };
    let mut stream = UnixStream::connect(&socket)
        .map_err(|e| anyhow::anyhow!("cannot connect to {socket}: {e} (is `serve` running?)"))?;
    write_frame(&mut stream, &req.to_message())
        .map_err(|e| anyhow::anyhow!("send request: {e}"))?;
    let msg = read_frame(&mut stream).map_err(|e| anyhow::anyhow!("read response: {e}"))?;
    match ServeResponse::from_message(&msg).map_err(anyhow::Error::msg)? {
        ServeResponse::ShuttingDown => println!("server acknowledged shutdown"),
        ServeResponse::Error(e) => {
            eprintln!("error: {e}");
            // Load shedding (and a blown per-request deadline) is an
            // operational state, not a caller bug.
            let code = match e {
                ServeError::Overloaded { .. }
                | ServeError::Throttled { .. }
                | ServeError::DeadlineExceeded { .. } => 3,
                _ => 1,
            };
            std::process::exit(code);
        }
        ServeResponse::Result(r) => {
            println!(
                "cache: {} ({} itemsets at min_sup {} abs over {} txns, {:.1} ms)",
                r.cache_hit,
                r.itemsets.len(),
                r.min_sup_abs,
                r.n_transactions,
                r.wall_ms
            );
            let hist = MiningResult::new(r.itemsets).histogram();
            for (k, count) in hist.iter().enumerate() {
                println!("  L{}: {count}", k + 1);
            }
            if !r.rules.is_empty() {
                println!("rules ({}):", r.rules.len());
                for rule in &r.rules {
                    println!("  {rule}");
                }
            }
        }
    }
    Ok(())
}

fn xla_smoke() -> Result<()> {
    use rdd_eclat::runtime::{artifacts_dir, XlaFim};
    use rdd_eclat::util::Bitmap;
    let mut fim = XlaFim::load(&artifacts_dir())?;
    println!("PJRT platform: {}", fim.platform());
    let mut a = Bitmap::new(1000);
    let mut b = Bitmap::new(1000);
    for i in (0..1000).step_by(3) {
        a.set(i);
    }
    for i in (0..1000).step_by(5) {
        b.set(i);
    }
    let (inter, sup) = fim.intersect_batch(&[&a], &[&b])?;
    println!(
        "intersect smoke: |a|={} |b|={} |a∩b|={} (expect 67)",
        a.count(),
        b.count(),
        sup[0]
    );
    assert_eq!(sup[0], 67);
    assert_eq!(inter[0].count(), 67);
    println!("xla-smoke OK");
    Ok(())
}
