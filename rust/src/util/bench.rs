//! Minimal bench harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a plain `main()` that builds a
//! [`BenchSuite`], registers measurements, and prints a fixed-width table
//! plus a CSV next to `bench_output.txt`. Repetitions + median/stddev give
//! stable numbers without criterion's statistical machinery.

use std::time::Instant;

use super::stats;

/// One measured series (e.g. one algorithm across a min_sup sweep).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub series: String,
    pub x_label: String,
    pub x: f64,
    pub millis: Vec<f64>,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        stats::median(&self.millis)
    }
}

/// A named collection of measurements that renders paper-style tables.
pub struct BenchSuite {
    pub name: String,
    pub description: String,
    measurements: Vec<Measurement>,
    reps: usize,
    warmup: usize,
}

impl BenchSuite {
    pub fn new(name: &str, description: &str) -> Self {
        // Fast mode for CI/test runs: REPRO_BENCH_REPS=1 REPRO_BENCH_WARMUP=0
        let reps = std::env::var("REPRO_BENCH_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        let warmup = std::env::var("REPRO_BENCH_WARMUP")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1);
        Self {
            name: name.to_string(),
            description: description.to_string(),
            measurements: Vec::new(),
            reps,
            warmup,
        }
    }

    pub fn with_reps(mut self, reps: usize, warmup: usize) -> Self {
        self.reps = reps;
        self.warmup = warmup;
        self
    }

    /// Measure `f` with warmup + repetitions and record the series point.
    pub fn measure<F: FnMut()>(&mut self, series: &str, x_label: &str, x: f64, mut f: F) {
        for _ in 0..self.warmup {
            f();
        }
        let mut millis = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t = Instant::now();
            f();
            millis.push(t.elapsed().as_secs_f64() * 1e3);
        }
        eprintln!(
            "  [{}] {series} @ {x_label}={x}: {:.1} ms",
            self.name,
            stats::median(&millis)
        );
        self.measurements.push(Measurement {
            series: series.to_string(),
            x_label: x_label.to_string(),
            x,
            millis,
        });
    }

    /// Record an externally measured value (e.g. from a run that also
    /// returns data we want to assert on).
    pub fn record(&mut self, series: &str, x_label: &str, x: f64, millis: Vec<f64>) {
        self.measurements.push(Measurement {
            series: series.to_string(),
            x_label: x_label.to_string(),
            x,
            millis,
        });
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Median for a given (series, x) point, if present.
    pub fn median(&self, series: &str, x: f64) -> Option<f64> {
        self.measurements
            .iter()
            .find(|m| m.series == series && (m.x - x).abs() < 1e-12)
            .map(|m| m.median_ms())
    }

    /// Render the paper-style table: rows = x values, columns = series.
    pub fn render_table(&self) -> String {
        let mut series: Vec<String> = Vec::new();
        let mut xs: Vec<f64> = Vec::new();
        for m in &self.measurements {
            if !series.contains(&m.series) {
                series.push(m.series.clone());
            }
            if !xs.iter().any(|&x| (x - m.x).abs() < 1e-12) {
                xs.push(m.x);
            }
        }
        let x_label = self
            .measurements
            .first()
            .map(|m| m.x_label.clone())
            .unwrap_or_else(|| "x".into());
        let mut out = String::new();
        out.push_str(&format!("## {} — {}\n", self.name, self.description));
        out.push_str(&format!("{:>12}", x_label));
        for s in &series {
            out.push_str(&format!("{:>14}", s));
        }
        out.push('\n');
        for &x in &xs {
            out.push_str(&format!("{:>12}", trim_float(x)));
            for s in &series {
                match self.median(s, x) {
                    Some(ms) => out.push_str(&format!("{:>12.1}ms", ms)),
                    None => out.push_str(&format!("{:>14}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// CSV rendering (one row per measurement, all reps).
    pub fn render_csv(&self) -> String {
        let mut out = String::from("bench,series,x_label,x,median_ms,stddev_ms,reps\n");
        for m in &self.measurements {
            out.push_str(&format!(
                "{},{},{},{},{:.3},{:.3},{}\n",
                self.name,
                m.series,
                m.x_label,
                trim_float(m.x),
                m.median_ms(),
                stats::stddev(&m.millis),
                m.millis.len()
            ));
        }
        out
    }

    /// Print the table to stdout and write CSV under `target/bench-results/`.
    pub fn finish(&self) {
        println!("{}", self.render_table());
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.csv", self.name));
        if let Err(e) = std::fs::write(&path, self.render_csv()) {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            eprintln!("wrote {}", path.display());
        }
    }
}

fn trim_float(x: f64) -> String {
    if (x - x.round()).abs() < 1e-9 {
        format!("{}", x.round() as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_and_table() {
        let mut suite = BenchSuite::new("t", "test").with_reps(2, 0);
        suite.measure("a", "n", 1.0, || {});
        suite.measure("b", "n", 1.0, || {});
        suite.measure("a", "n", 2.0, || {});
        let table = suite.render_table();
        assert!(table.contains("a") && table.contains("b"));
        assert!(suite.median("a", 1.0).is_some());
        assert!(suite.median("b", 2.0).is_none());
        let csv = suite.render_csv();
        assert_eq!(csv.lines().count(), 4); // header + 3
    }

    #[test]
    fn record_external() {
        let mut suite = BenchSuite::new("t2", "test").with_reps(1, 0);
        suite.record("x", "k", 5.0, vec![10.0, 20.0, 30.0]);
        assert_eq!(suite.median("x", 5.0).unwrap(), 20.0);
    }
}
