//! RDD-Eclat: the paper's five variants (§4), expressed against the
//! Sparklet operator surface so each phase reads like the pseudo-code.
//!
//! | Variant | Phase structure (paper) |
//! |---------|-------------------------|
//! | V1 | P1: vertical DB via `flatMapToPair`+`groupByKey` on the unpartitioned input; P2: triangular-matrix accumulator over raw transactions; P3: driver builds equivalence classes, `partitionBy(defaultPartitioner(n-1))`, `flatMap(Bottom-Up)` |
//! | V2 | P1: item counts via `reduceByKey`; P2: broadcast frequent-item trie, Borgelt-filter transactions, tri-matrix on filtered; P3: `coalesce(1)` + `flatMapToPair`+`groupByKey` vertical DB; P4 = V1's P3 |
//! | V3 | V2 but P3 builds the vertical DB in a hashmap *accumulator* |
//! | V4 | V3 with `hashPartitioner(p)` in P4 |
//! | V5 | V3 with `reverseHashPartitioner(p)` in P4 |
//!
//! All variants run under the unified [`MiningConfig`]: the tidset
//! representation ([`TidsetRepr`], including density-measured `Auto`)
//! and the class-placement strategy ([`PartitionStrategy`]) are
//! orthogonal axes resolved here, so any variant can be combined with
//! any representation and any placement. All combinations return
//! identical itemsets (asserted against the sequential oracles); they
//! differ in operator/shuffle structure, which is what the paper's
//! figures measure.

use std::sync::Arc;

use crate::sparklet::accumulator::AccumValue;
use crate::sparklet::metrics::StageKind;
use crate::sparklet::{PairRdd, Rdd, SparkletContext};
use crate::util::hash::FxHashMap;

use super::engine::{FimError, MiningConfig, PartitionStrategy, TidsetRepr};
use super::eqclass::{bottom_up, build_classes, EquivalenceClass};
use super::partitioners;
use super::tidset::{BitmapTidset, DiffTidset, HybridTidset, TidOps, VecTidset};
use super::trie::ItemTrie;
use super::trimatrix::TriMatrix;
use super::types::{FrequentItemset, Item, MiningResult, Transaction};

/// Which variant to run. `V1`–`V5` are the paper's five; `V6Fused` is
/// this repo's implementation of the paper's §6 future work: the best
/// modules assembled — transaction filtering + hashmap vertical DB (V3
/// base), **2-length-prefix** equivalence classes, and the LPT
/// weight-balanced class partitioner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EclatVariant {
    V1,
    V2,
    V3,
    V4,
    V5,
    V6Fused,
}

impl EclatVariant {
    /// The paper's five variants (what the figures sweep).
    pub fn all() -> [EclatVariant; 5] {
        [Self::V1, Self::V2, Self::V3, Self::V4, Self::V5]
    }

    /// All variants including the future-work fusion.
    pub fn all_with_fused() -> [EclatVariant; 6] {
        [Self::V1, Self::V2, Self::V3, Self::V4, Self::V5, Self::V6Fused]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::V1 => "EclatV1",
            Self::V2 => "EclatV2",
            Self::V3 => "EclatV3",
            Self::V4 => "EclatV4",
            Self::V5 => "EclatV5",
            Self::V6Fused => "EclatV6-fused",
        }
    }
}

/// Accumulator value for EclatV3's vertical-database hashmap.
impl AccumValue for FxHashMap<Item, Vec<u32>> {
    fn merge(&mut self, other: Self) {
        for (k, mut v) in other {
            self.entry(k).or_default().append(&mut v);
        }
    }
}

/// Parse a dataset line ("item item item") into a normalized transaction.
pub fn parse_line(line: &str) -> Transaction {
    let mut t: Transaction = line
        .split_whitespace()
        .filter_map(|s| s.parse().ok())
        .collect();
    t.sort_unstable();
    t.dedup();
    t
}

/// Lines RDD -> normalized transactions RDD.
pub fn transactions_from_lines(lines: &Rdd<String>) -> Rdd<Transaction> {
    lines
        .map(|l| parse_line(&l))
        .filter(|t| !t.is_empty())
}

// --------------------------------------------------------------- phases

/// V1 Phase-1 (Algorithm 2): vertical dataset from the *unpartitioned*
/// input: `flatMapToPair(t -> (item, tid))` + `groupByKey` + min_sup
/// filter. Returns the (item, tidset) list sorted by ascending support
/// and the transaction count.
fn v1_phase1(txns: &Rdd<Transaction>, min_sup: u32) -> (Vec<(Item, Vec<u32>)>, usize) {
    let single = txns.coalesce(1);
    let n_txns = single.count();
    let item_tids = single
        .zip_with_index()
        .flat_map_to_pair(|(t, tid)| {
            t.into_iter()
                .map(move |item| (item, tid as u32))
                .collect::<Vec<_>>()
        })
        .group_by_key();
    let freq_item_tids = item_tids.filter(move |(_, tids)| tids.len() as u32 >= min_sup);
    let mut list: Vec<(Item, Vec<u32>)> = freq_item_tids
        .collect()
        .into_iter()
        .map(|(item, mut tids)| {
            tids.sort_unstable();
            (item, tids)
        })
        .collect();
    // "sorted in the ascending order of support" (ties by item id).
    list.sort_by_key(|(item, tids)| (tids.len(), *item));
    (list, n_txns)
}

/// V2/V3 Phase-1 (Algorithm 5): frequent items via word-count.
fn v2_phase1(sc: &SparkletContext, txns: &Rdd<Transaction>, min_sup: u32) -> Vec<(Item, u32)> {
    let _ = sc;
    let item_counts = txns
        .flat_map(|t| t)
        .map_to_pair(|item| (item, 1u32))
        .reduce_by_key(|a, b| a + b);
    let mut freq: Vec<(Item, u32)> = item_counts
        .filter(move |(_, c)| *c >= min_sup)
        .collect();
    // "list of frequent items in alphanumeric order"
    freq.sort_by_key(|(item, _)| *item);
    freq
}

/// Phase-2 (Algorithms 3/6): the triangular-matrix accumulator over all
/// 2-item combinations, computed in parallel on `defaultParallelism`
/// partitions. `item_space` is the matrix dimension: V1 indexes by raw
/// item id (the paper's memory blowup on BMS), V2+ index filtered items.
fn phase2_trimatrix(
    sc: &SparkletContext,
    txns: &Rdd<Transaction>,
    item_space: usize,
) -> TriMatrix {
    let acc = sc.accumulator(move || TriMatrix::new(item_space));
    let acc2 = acc.clone();
    let rep = txns.repartition(sc.default_parallelism());
    rep.foreach_partition(move |_, txns| {
        acc2.update_in_place(|m| {
            for t in &txns {
                m.update_transaction(t);
            }
        });
    });
    acc.drain()
}

/// V2 Phase-3 (Algorithm 7): vertical DB from filtered transactions via
/// `coalesce(1)` + `flatMapToPair` + `groupByKey`.
fn v2_phase3(filtered: &Rdd<Transaction>, min_sup: u32) -> (Vec<(Item, Vec<u32>)>, usize) {
    // identical machinery to v1_phase1 but over filtered transactions
    v1_phase1(filtered, min_sup)
}

/// V3 Phase-3: vertical DB accumulated into a shared hashmap.
fn v3_phase3(
    sc: &SparkletContext,
    filtered: &Rdd<Transaction>,
    freq_items: &[(Item, u32)],
) -> (Vec<(Item, Vec<u32>)>, usize) {
    let single = filtered.coalesce(1);
    let n_txns = single.count();
    let acc = sc.accumulator(FxHashMap::<Item, Vec<u32>>::default);
    let acc2 = acc.clone();
    single
        .zip_with_index()
        .foreach_partition(move |_, items| {
            acc2.update_in_place(|map| {
                for (t, tid) in &items {
                    for &item in t {
                        map.entry(item).or_default().push(*tid as u32);
                    }
                }
            });
        });
    let mut map = acc.drain();
    // The updated hashmap is used to sort Phase-1's frequent items by
    // total order of increasing support.
    let mut list: Vec<(Item, Vec<u32>)> = freq_items
        .iter()
        .filter_map(|(item, _)| {
            map.remove(item).map(|mut tids| {
                tids.sort_unstable();
                (*item, tids)
            })
        })
        .collect();
    list.sort_by_key(|(item, tids)| (tids.len(), *item));
    (list, n_txns)
}

/// How Phase-4 places equivalence classes on partitions.
enum Placement {
    /// A fixed rank-based partitioner (default / hash / reverse-hash).
    Fixed(Arc<crate::sparklet::partitioner::FnPartitioner<usize>>),
    /// LPT over actual class weights into `p` partitions.
    Weighted(usize),
}

/// Map the config's partition-strategy axis (plus the variant's paper
/// default) onto a concrete placement. `n` is the frequent-item count —
/// the rank space of `defaultPartitioner(n - 1)`.
fn placement(variant: EclatVariant, cfg: &MiningConfig, n: usize) -> Placement {
    use PartitionStrategy as PS;
    let strategy = match cfg.partitioning {
        PS::EngineDefault => match variant {
            EclatVariant::V4 => PS::Hash,
            EclatVariant::V5 => PS::ReverseHash,
            EclatVariant::V6Fused => PS::Weighted,
            _ => PS::Ranked,
        },
        explicit => explicit,
    };
    match strategy {
        PS::Ranked => Placement::Fixed(partitioners::default_partitioner(n)),
        PS::Hash => Placement::Fixed(partitioners::hash_partitioner(cfg.p)),
        PS::ReverseHash => Placement::Fixed(partitioners::reverse_hash_partitioner(cfg.p)),
        PS::Weighted => Placement::Weighted(cfg.p),
        // EngineDefault was rewritten to a concrete strategy above.
        PS::EngineDefault => unreachable!("EngineDefault resolved to a concrete strategy"),
    }
}

/// Phase-3/4 (Algorithm 4): build equivalence classes on the driver,
/// parallelize + `partitionBy` + `flatMap(Bottom-Up)`. `prefix_len`
/// selects 1-length (paper) or 2-length (§6 future work) class prefixes.
fn phase_classes<TS: TidOps>(
    sc: &SparkletContext,
    vertical: Vec<(Item, TS)>,
    min_sup: u32,
    tri_matrix: Option<&TriMatrix>,
    strategy: Placement,
    prefix_len: usize,
) -> Result<Vec<FrequentItemset>, FimError> {
    let mut out: Vec<FrequentItemset> = Vec::new();
    let mut classes: Vec<(usize, EquivalenceClass<TS>)> =
        build_classes(&vertical, min_sup, tri_matrix, |item| item, &mut out);
    if prefix_len >= 2 {
        let mut threes = Vec::new();
        classes = crate::fim::eqclass::decompose_to_prefix2(classes, min_sup, &mut threes);
        out.extend(threes);
    }
    if classes.is_empty() {
        return Ok(out);
    }
    let partitioner = match strategy {
        Placement::Fixed(p) => p,
        Placement::Weighted(p) => {
            let weights: Vec<usize> = classes.iter().map(|(_, c)| c.weight()).collect();
            // EWMA reweighting hook: per-partition cost feedback from
            // the previous run/window's recorded stages (task times +
            // queue wait), so LPT placement learns instead of trusting
            // static member-count weights alone.
            let costs = sc.metrics().partition_cost_weights(p);
            partitioners::weighted_partitioner_with_costs(&weights, p, costs.as_deref())
        }
    };
    let ecs = sc.parallelize(classes, 1).partition_by(partitioner);
    // Remote-capable backends (multi-process) can't ship the flat_map
    // closure; they run the same Bottom-Up as a registered task
    // descriptor per reduce partition, fetching the shuffled classes
    // over the transport. Results are identical either way.
    let remote = if sc.executor().supports_described() {
        super::distributed::bottom_up_described(sc, &ecs, min_sup)?
    } else {
        None
    };
    match remote {
        Some(found) => out.extend(found),
        None => {
            let ecs = ecs.cache();
            let deeper = ecs.flat_map(move |(_, ec)| {
                let mut acc = Vec::new();
                bottom_up(&ec, min_sup, &mut acc);
                acc
            });
            out.extend(deeper.collect());
        }
    }
    // Feed the Bottom-Up stage's per-partition execution signal back
    // into the EWMA the weighted partitioner reads next run. The stage
    // just recorded by `collect()` is the per-class Result stage.
    if let Some(stage) = sc.metrics().last_stage() {
        if stage.kind == StageKind::Result {
            sc.metrics()
                .observe_partition_costs(&stage.task_millis, stage.queue_wait_ms);
        }
    }
    Ok(out)
}

/// Resolve the tidset-representation axis against the *measured*
/// vertical database (this is where `TidsetRepr::Auto` reads the
/// density), materialize the tidsets, and run the partitioned Bottom-Up
/// phase. Collapses what used to be duplicated `_vec`/bitmap call paths
/// behind one dispatch point.
#[allow(clippy::too_many_arguments)]
fn phase_classes_repr(
    sc: &SparkletContext,
    vertical_tids: Vec<(Item, Vec<u32>)>,
    n_txns: usize,
    cfg: &MiningConfig,
    tri: Option<&TriMatrix>,
    strategy: Placement,
    prefix_len: usize,
    out: &mut Vec<FrequentItemset>,
) -> Result<(), FimError> {
    /// Materialize the vertical database in the resolved representation.
    fn to_repr<TS: TidOps>(vertical_tids: Vec<(Item, Vec<u32>)>, n_txns: usize) -> Vec<(Item, TS)> {
        vertical_tids
            .into_iter()
            .map(|(item, tids)| (item, TS::from_tids(&tids, n_txns)))
            .collect()
    }
    let total_tids: usize = vertical_tids.iter().map(|(_, tids)| tids.len()).sum();
    match cfg.tidset.resolve(total_tids, vertical_tids.len(), n_txns) {
        TidsetRepr::Bitmap => out.extend(phase_classes(
            sc,
            to_repr::<BitmapTidset>(vertical_tids, n_txns),
            cfg.min_sup,
            tri,
            strategy,
            prefix_len,
        )?),
        TidsetRepr::Diffset => out.extend(phase_classes(
            sc,
            to_repr::<DiffTidset>(vertical_tids, n_txns),
            cfg.min_sup,
            tri,
            strategy,
            prefix_len,
        )?),
        TidsetRepr::Hybrid => out.extend(phase_classes(
            sc,
            to_repr::<HybridTidset>(vertical_tids, n_txns),
            cfg.min_sup,
            tri,
            strategy,
            prefix_len,
        )?),
        TidsetRepr::Vec | TidsetRepr::Auto => out.extend(phase_classes(
            sc,
            to_repr::<VecTidset>(vertical_tids, n_txns),
            cfg.min_sup,
            tri,
            strategy,
            prefix_len,
        )?),
    }
    Ok(())
}

// -------------------------------------------------------------- variants

/// Run one RDD-Eclat variant over a transactions RDD under the unified
/// [`MiningConfig`]. This is the single entry point behind the
/// `eclat-v1`..`eclat-v6` engines of the [`super::engine::EngineRegistry`].
pub fn mine_eclat(
    sc: &SparkletContext,
    txns: &Rdd<Transaction>,
    variant: EclatVariant,
    cfg: &MiningConfig,
) -> Result<MiningResult, FimError> {
    match variant {
        EclatVariant::V1 => mine_v1(sc, txns, cfg),
        _ => mine_v2plus(sc, txns, variant, cfg),
    }
}

fn mine_v1(
    sc: &SparkletContext,
    txns: &Rdd<Transaction>,
    cfg: &MiningConfig,
) -> Result<MiningResult, FimError> {
    let txns = txns.cache();
    // Phase-1
    let (vertical_tids, n_txns) = v1_phase1(&txns, cfg.min_sup);
    let mut result: Vec<FrequentItemset> = vertical_tids
        .iter()
        .map(|(item, tids)| FrequentItemset::new(vec![*item], tids.len() as u32))
        .collect();
    let n = vertical_tids.len();
    if n < 2 {
        return Ok(MiningResult::new(result));
    }
    // Phase-2: triangular matrix over *raw* item ids (V1 behaviour).
    let tri = if cfg.tri_matrix {
        let max_item = txns
            .map(|t| t.into_iter().max().unwrap_or(0))
            .reduce(|a, b| a.max(b))
            .unwrap_or(0);
        Some(phase2_trimatrix(sc, &txns, max_item as usize + 1))
    } else {
        None
    };
    // Phase-3
    phase_classes_repr(
        sc,
        vertical_tids,
        n_txns,
        cfg,
        tri.as_ref(),
        placement(EclatVariant::V1, cfg, n),
        cfg.prefix_len,
        &mut result,
    )?;
    Ok(MiningResult::new(result))
}

fn mine_v2plus(
    sc: &SparkletContext,
    txns: &Rdd<Transaction>,
    variant: EclatVariant,
    cfg: &MiningConfig,
) -> Result<MiningResult, FimError> {
    let txns = txns.cache();
    // Phase-1 (Algorithm 5)
    let freq_items = v2_phase1(sc, &txns, cfg.min_sup);
    let mut result: Vec<FrequentItemset> = freq_items
        .iter()
        .map(|(item, c)| FrequentItemset::new(vec![*item], *c))
        .collect();
    let n = freq_items.len();
    if n < 2 {
        return Ok(MiningResult::new(result));
    }
    // Phase-2 (Algorithm 6): broadcast trieL1, filter transactions.
    let trie_l1 = ItemTrie::from_items(freq_items.iter().map(|(i, _)| *i));
    let b_trie = sc.broadcast(trie_l1);
    let filtered = txns
        .map(move |t| b_trie.value().filter_transaction(&t))
        .filter(|t| !t.is_empty())
        .cache();
    let tri = if cfg.tri_matrix {
        let max_item = freq_items.iter().map(|(i, _)| *i).max().unwrap_or(0);
        Some(phase2_trimatrix(sc, &filtered, max_item as usize + 1))
    } else {
        None
    };
    // Phase-3: vertical dataset.
    let (vertical_tids, n_txns) = match variant {
        EclatVariant::V2 => v2_phase3(&filtered, cfg.min_sup),
        _ => v3_phase3(sc, &filtered, &freq_items),
    };
    // Phase-4: equivalence classes with the resolved placement.
    let prefix_len = if variant == EclatVariant::V6Fused {
        2
    } else {
        cfg.prefix_len
    };
    phase_classes_repr(
        sc,
        vertical_tids,
        n_txns,
        cfg,
        tri.as_ref(),
        placement(variant, cfg, n),
        prefix_len,
        &mut result,
    )?;
    Ok(MiningResult::new(result))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::sequential::eclat_sequential;

    /// Parallelize + normalize an in-memory database and mine it (what
    /// `MiningSession::run_vec` does, inlined for unit-test locality).
    fn mine_vec(
        sc: &SparkletContext,
        txns: Vec<Transaction>,
        variant: EclatVariant,
        cfg: &MiningConfig,
    ) -> MiningResult {
        let parts = sc.default_parallelism().max(1);
        let rdd = sc.parallelize(txns, parts).map(|mut t| {
            t.sort_unstable();
            t.dedup();
            t
        });
        mine_eclat(sc, &rdd, variant, cfg).expect("in-process mine cannot fail")
    }

    fn demo_db() -> Vec<Transaction> {
        vec![
            vec![1, 2, 5],
            vec![2, 4],
            vec![2, 3],
            vec![1, 2, 4],
            vec![1, 3],
            vec![2, 3],
            vec![1, 3],
            vec![1, 2, 3, 5],
            vec![1, 2, 3],
        ]
    }

    #[test]
    fn all_variants_match_oracle_on_demo() {
        let sc = SparkletContext::local(4);
        for min_sup in [1u32, 2, 3] {
            let oracle = eclat_sequential(&demo_db(), min_sup);
            for variant in EclatVariant::all_with_fused() {
                let cfg = MiningConfig::new(min_sup).with_p(3);
                let got = mine_vec(&sc, demo_db(), variant, &cfg);
                assert!(
                    got.same_as(&oracle),
                    "{} min_sup={min_sup}: got {} itemsets, want {}",
                    variant.name(),
                    got.len(),
                    oracle.len()
                );
            }
        }
    }

    #[test]
    fn non_vec_reprs_match_oracle() {
        let sc = SparkletContext::local(2);
        let oracle = eclat_sequential(&demo_db(), 2);
        // all_with_fused: V6's 2-prefix decomposition must also hold
        // under the diffset and hybrid kernels
        for variant in EclatVariant::all_with_fused() {
            for repr in [
                TidsetRepr::Bitmap,
                TidsetRepr::Diffset,
                TidsetRepr::Hybrid,
                TidsetRepr::Auto,
            ] {
                let cfg = MiningConfig::new(2).with_tidset(repr);
                let got = mine_vec(&sc, demo_db(), variant, &cfg);
                assert!(got.same_as(&oracle), "{} {}", variant.name(), repr.name());
            }
        }
    }

    #[test]
    fn partition_strategy_override_is_result_invariant() {
        let sc = SparkletContext::local(2);
        let oracle = eclat_sequential(&demo_db(), 2);
        for strategy in [
            PartitionStrategy::Ranked,
            PartitionStrategy::Hash,
            PartitionStrategy::ReverseHash,
            PartitionStrategy::Weighted,
        ] {
            for variant in [EclatVariant::V1, EclatVariant::V3, EclatVariant::V5] {
                let cfg = MiningConfig::new(2).with_partitioning(strategy).with_p(3);
                let got = mine_vec(&sc, demo_db(), variant, &cfg);
                assert!(
                    got.same_as(&oracle),
                    "{} under {}",
                    variant.name(),
                    strategy.name()
                );
            }
        }
    }

    #[test]
    fn weighted_feedback_keeps_results_exact_across_runs() {
        // Consecutive Weighted runs on one context exercise the EWMA
        // reweighting hook (run N+1 places classes using run N's
        // observed per-partition costs); placement must never change
        // results.
        let sc = SparkletContext::local(2);
        let oracle = eclat_sequential(&demo_db(), 2);
        let cfg = MiningConfig::new(2)
            .with_partitioning(PartitionStrategy::Weighted)
            .with_p(3);
        for run in 0..3 {
            for variant in [EclatVariant::V3, EclatVariant::V6Fused] {
                let got = mine_vec(&sc, demo_db(), variant, &cfg);
                assert!(got.same_as(&oracle), "run {run} {}", variant.name());
            }
        }
    }

    #[test]
    fn prefix2_mode_matches_oracle() {
        let sc = SparkletContext::local(2);
        for variant in [EclatVariant::V1, EclatVariant::V3, EclatVariant::V5] {
            let cfg = MiningConfig::new(2).with_prefix_len(2);
            let got = mine_vec(&sc, demo_db(), variant, &cfg);
            assert!(
                got.same_as(&eclat_sequential(&demo_db(), 2)),
                "{} prefix_len=2",
                variant.name()
            );
        }
    }

    #[test]
    fn tri_matrix_mode_equivalent() {
        let sc = SparkletContext::local(2);
        for variant in EclatVariant::all() {
            let with = mine_vec(
                &sc,
                demo_db(),
                variant,
                &MiningConfig::new(2).with_tri_matrix(true),
            );
            let without = mine_vec(
                &sc,
                demo_db(),
                variant,
                &MiningConfig::new(2).with_tri_matrix(false),
            );
            assert!(with.same_as(&without), "{}", variant.name());
        }
    }

    #[test]
    fn parse_line_normalizes() {
        assert_eq!(parse_line("3 1 2 2"), vec![1, 2, 3]);
        assert_eq!(parse_line("  7  "), vec![7]);
        assert_eq!(parse_line(""), Vec::<Item>::new());
        assert_eq!(parse_line("5 x 2"), vec![2, 5]); // non-numeric skipped
    }

    #[test]
    fn p_parameter_respected() {
        let sc = SparkletContext::local(2);
        for p in [1usize, 2, 7] {
            let cfg = MiningConfig::new(1).with_p(p);
            let got = mine_vec(&sc, demo_db(), EclatVariant::V4, &cfg);
            assert!(got.same_as(&eclat_sequential(&demo_db(), 1)), "p={p}");
        }
    }

    #[test]
    fn min_sup_above_all_returns_empty() {
        let sc = SparkletContext::local(2);
        for variant in EclatVariant::all() {
            let cfg = MiningConfig::new(100);
            assert!(mine_vec(&sc, demo_db(), variant, &cfg).is_empty());
        }
    }

    #[test]
    fn single_frequent_item_short_circuits() {
        let sc = SparkletContext::local(2);
        let db = vec![vec![1], vec![1], vec![2]];
        let cfg = MiningConfig::new(2);
        let r = mine_vec(&sc, db, EclatVariant::V1, &cfg);
        assert_eq!(r.canonical().len(), 1);
    }
}
