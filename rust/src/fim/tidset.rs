//! Tidset representations and the intersection kernel.
//!
//! Eclat's inner loop is `tidset(A_i) ∩ tidset(A_j)`. Two representations
//! are provided behind [`TidOps`]:
//!
//! * [`VecTidset`] — sorted `Vec<u32>` of transaction ids, the textbook
//!   (and SPMF) representation the paper uses. Intersection is a linear
//!   merge with a galloping fast path for skewed sizes.
//! * [`BitmapTidset`] — packed `u32` bitmaps (AND + popcount), the
//!   representation the XLA artifact consumes, so the native and
//!   accelerated paths share exact layout semantics.
//!
//! The mining code is generic over `TidOps`; the ablation bench compares
//! the two (EXPERIMENTS.md §Ablations).

use crate::util::Bitmap;

/// Operations a tidset representation must support.
pub trait TidOps: Clone + Send + Sync + 'static {
    /// Build from a sorted, deduplicated tid list; `universe` is the
    /// total transaction count (bitmap capacity).
    fn from_tids(tids: &[u32], universe: usize) -> Self;
    /// Number of transactions containing the itemset.
    fn support(&self) -> usize;
    /// Intersection.
    fn intersect(&self, other: &Self) -> Self;
    /// Support of the intersection without materializing it (used when
    /// the candidate fails min_sup and the tidset would be discarded).
    fn intersect_support(&self, other: &Self) -> usize;
    /// Support with an early abort: returns `None` as soon as the
    /// remaining elements cannot reach `min_sup` (§Perf O6 — the
    /// dominant savings in triMatrixMode=false datasets, where most of
    /// the O(n²) candidate pairs are hopeless).
    fn intersect_support_min(&self, other: &Self, min_sup: u32) -> Option<u32> {
        let s = self.intersect_support(other) as u32;
        (s >= min_sup).then_some(s)
    }
    /// Recover the sorted tid list (tests / output).
    fn to_tids(&self) -> Vec<u32>;
}

// ------------------------------------------------------------- VecTidset

/// Sorted tid-list tidset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VecTidset {
    tids: Vec<u32>,
}

impl VecTidset {
    pub fn tids(&self) -> &[u32] {
        &self.tids
    }

    /// Intersect two sorted, deduplicated tid slices into a fresh vec —
    /// the raw kernel behind [`TidOps::intersect`], exposed for the
    /// incremental streaming miner, which intersects tid-range *slices*
    /// (kept / newly-arrived regions) of window tidsets.
    pub fn intersect_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
        Self::merge_intersect(a, b)
    }

    /// Linear merge intersection into a fresh vec.
    fn merge_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        // Galloping when sizes are very skewed: binary-search the larger.
        if a.len() * 32 < b.len() {
            return Self::gallop_intersect(a, b);
        }
        if b.len() * 32 < a.len() {
            return Self::gallop_intersect(b, a);
        }
        // Branch-light two-pointer merge (§Perf O2): advancing both
        // cursors arithmetically instead of a 3-way branch lets the
        // compiler keep the loop tight; bounds checks are elided by the
        // loop condition.
        let mut out = Vec::with_capacity(a.len().min(b.len()));
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            let (x, y) = (a[i], b[j]);
            if x == y {
                out.push(x);
            }
            i += (x <= y) as usize;
            j += (y <= x) as usize;
        }
        out
    }

    /// Count-only merge (§Perf O3): support of the intersection without
    /// allocating or writing the result — the min_sup-check fast path.
    fn merge_count(a: &[u32], b: &[u32]) -> usize {
        if a.len() * 32 < b.len() {
            return Self::gallop_count(a, b);
        }
        if b.len() * 32 < a.len() {
            return Self::gallop_count(b, a);
        }
        let mut count = 0usize;
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            let (x, y) = (a[i], b[j]);
            count += (x == y) as usize;
            i += (x <= y) as usize;
            j += (y <= x) as usize;
        }
        count
    }

    fn gallop_count(small: &[u32], large: &[u32]) -> usize {
        let mut count = 0usize;
        let mut lo = 0usize;
        for &x in small {
            match large[lo..].binary_search(&x) {
                Ok(pos) => {
                    count += 1;
                    lo += pos + 1;
                }
                Err(pos) => lo += pos,
            }
            if lo >= large.len() {
                break;
            }
        }
        count
    }

    /// For |small| << |large|: binary search each element of the small
    /// side in the remaining suffix of the large side.
    fn gallop_intersect(small: &[u32], large: &[u32]) -> Vec<u32> {
        let mut out = Vec::with_capacity(small.len());
        let mut lo = 0usize;
        for &x in small {
            match large[lo..].binary_search(&x) {
                Ok(pos) => {
                    out.push(x);
                    lo += pos + 1;
                }
                Err(pos) => {
                    lo += pos;
                }
            }
            if lo >= large.len() {
                break;
            }
        }
        out
    }
}

impl TidOps for VecTidset {
    fn from_tids(tids: &[u32], _universe: usize) -> Self {
        debug_assert!(tids.windows(2).all(|w| w[0] < w[1]), "tids must be sorted+unique");
        Self {
            tids: tids.to_vec(),
        }
    }

    fn support(&self) -> usize {
        self.tids.len()
    }

    fn intersect(&self, other: &Self) -> Self {
        Self {
            tids: Self::merge_intersect(&self.tids, &other.tids),
        }
    }

    fn intersect_support(&self, other: &Self) -> usize {
        Self::merge_count(&self.tids, &other.tids)
    }

    fn intersect_support_min(&self, other: &Self, min_sup: u32) -> Option<u32> {
        let (a, b) = (&self.tids[..], &other.tids[..]);
        let need = min_sup as usize;
        if a.len().min(b.len()) < need {
            return None; // can never reach min_sup
        }
        let mut count = 0usize;
        let (mut i, mut j) = (0usize, 0usize);
        while i < a.len() && j < b.len() {
            // infeasibility bound: even matching every remaining element
            // of the shorter side cannot reach min_sup
            if count + (a.len() - i).min(b.len() - j) < need {
                return None;
            }
            let (x, y) = (a[i], b[j]);
            count += (x == y) as usize;
            i += (x <= y) as usize;
            j += (y <= x) as usize;
        }
        (count >= need).then_some(count as u32)
    }

    fn to_tids(&self) -> Vec<u32> {
        self.tids.clone()
    }
}

// ----------------------------------------------------------- BitmapTidset

/// Packed-bitmap tidset over the transaction universe.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitmapTidset {
    bits: Bitmap,
}

impl BitmapTidset {
    pub fn bitmap(&self) -> &Bitmap {
        &self.bits
    }
}

impl TidOps for BitmapTidset {
    fn from_tids(tids: &[u32], universe: usize) -> Self {
        Self {
            bits: Bitmap::from_sorted_tids(tids, universe),
        }
    }

    fn support(&self) -> usize {
        self.bits.count()
    }

    fn intersect(&self, other: &Self) -> Self {
        Self {
            bits: self.bits.and(&other.bits),
        }
    }

    fn intersect_support(&self, other: &Self) -> usize {
        self.bits.and_count(&other.bits)
    }

    fn to_tids(&self) -> Vec<u32> {
        self.bits.to_tids()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn random_sorted(rng: &mut SplitMix64, universe: usize, density: f64) -> Vec<u32> {
        (0..universe as u32)
            .filter(|_| rng.gen_bool(density))
            .collect()
    }

    #[test]
    fn vec_and_bitmap_agree_with_set_oracle() {
        let mut rng = SplitMix64::new(0xFACE);
        for _ in 0..100 {
            let universe = 1 + rng.gen_range(600);
            let a = random_sorted(&mut rng, universe, 0.3);
            let b = random_sorted(&mut rng, universe, 0.3);
            let oracle: Vec<u32> = a.iter().filter(|x| b.binary_search(x).is_ok()).copied().collect();

            let va = VecTidset::from_tids(&a, universe);
            let vb = VecTidset::from_tids(&b, universe);
            assert_eq!(va.intersect(&vb).to_tids(), oracle);
            assert_eq!(va.intersect_support(&vb), oracle.len());

            let ba = BitmapTidset::from_tids(&a, universe);
            let bb = BitmapTidset::from_tids(&b, universe);
            assert_eq!(ba.intersect(&bb).to_tids(), oracle);
            assert_eq!(ba.intersect_support(&bb), oracle.len());
        }
    }

    #[test]
    fn galloping_path_correct() {
        let mut rng = SplitMix64::new(0xBEEF);
        let universe = 100_000;
        let big = random_sorted(&mut rng, universe, 0.5);
        let small: Vec<u32> = vec![3, 77, 500, 9999, 50_000, 99_999];
        let oracle: Vec<u32> = small
            .iter()
            .filter(|x| big.binary_search(x).is_ok())
            .copied()
            .collect();
        let vs = VecTidset::from_tids(&small, universe);
        let vb = VecTidset::from_tids(&big, universe);
        assert_eq!(vs.intersect(&vb).to_tids(), oracle);
        assert_eq!(vb.intersect(&vs).to_tids(), oracle);
    }

    #[test]
    fn supports_match_lengths() {
        let tids = vec![1u32, 5, 9, 200];
        let v = VecTidset::from_tids(&tids, 256);
        let b = BitmapTidset::from_tids(&tids, 256);
        assert_eq!(v.support(), 4);
        assert_eq!(b.support(), 4);
        assert_eq!(v.to_tids(), tids);
        assert_eq!(b.to_tids(), tids);
    }

    #[test]
    fn empty_intersection() {
        let a = VecTidset::from_tids(&[1, 3, 5], 10);
        let b = VecTidset::from_tids(&[0, 2, 4], 10);
        assert_eq!(a.intersect(&b).support(), 0);
        let ba = BitmapTidset::from_tids(&[1, 3, 5], 10);
        let bb = BitmapTidset::from_tids(&[0, 2, 4], 10);
        assert_eq!(ba.intersect(&bb).support(), 0);
    }
}
