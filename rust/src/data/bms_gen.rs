//! BMS_WebView-like clickstream generator.
//!
//! The real BMS_WebView_1/2 datasets (KDD Cup 2000, Blue Martini) are
//! click-stream sessions over a product catalogue and cannot be
//! redistributed; this generator reproduces the properties that drive
//! FIM runtime behaviour (DESIGN.md §3):
//!
//!  * Table-1 scale: 59 602 / 77 512 sessions, 497 / 3 340 products,
//!    average widths 2.5 / 5.
//!  * Zipf-like product popularity (web traffic is heavy-tailed).
//!  * Session locality: items within a session cluster around a
//!    "category" neighbourhood, so frequent 2/3-itemsets exist.
//!  * Sparse item-id space: raw product ids are spread over a large
//!    range (the paper's reason `triMatrixMode=false` on BMS — a
//!    triangular matrix over the id space would blow memory).

use crate::fim::Transaction;
use crate::util::SplitMix64;

#[derive(Debug, Clone)]
pub struct BmsSpec {
    pub n_sessions: usize,
    pub n_products: usize,
    pub avg_width: f64,
    /// Zipf skew of product popularity.
    pub skew: f64,
    /// Probability the next click stays in the same category cluster.
    pub locality: f64,
    /// Raw ids are `id_stride * k` — spreads the id space like the real
    /// data's product codes (BMS ids go up to ~89k).
    pub id_stride: u32,
}

impl BmsSpec {
    pub fn bms1() -> Self {
        Self {
            n_sessions: 59_602,
            n_products: 497,
            avg_width: 2.5,
            skew: 0.9,
            locality: 0.55,
            id_stride: 180, // ids up to ~89.5k, like the real BMS codes
        }
    }

    pub fn bms2() -> Self {
        Self {
            n_sessions: 77_512,
            n_products: 3_340,
            avg_width: 5.0,
            skew: 0.85,
            locality: 0.5,
            id_stride: 27, // ids up to ~90k
        }
    }

    pub fn scaled(mut self, factor: f64) -> Self {
        self.n_sessions = ((self.n_sessions as f64 * factor) as usize).max(1);
        self
    }

    /// Generate the sessions.
    pub fn generate(&self, seed: u64) -> Vec<Transaction> {
        let mut rng = SplitMix64::new(seed ^ 0xB517_C11C);
        // Zipf cumulative over product *ranks*.
        let cum = zipf_cumulative(self.n_products, self.skew);
        // Category neighbourhoods: products are grouped in blocks of ~20
        // ranks; a local step picks within the current block.
        let block = 20usize;
        let mut sessions = Vec::with_capacity(self.n_sessions);
        while sessions.len() < self.n_sessions {
            // Session length: 1 + Poisson(avg-1) keeps the mean at
            // avg_width with the observed mode at small sizes.
            let len = 1 + rng.poisson(self.avg_width - 1.0);
            let mut session: Vec<u32> = Vec::with_capacity(len);
            let mut current = pick_zipf(&mut rng, &cum);
            session.push(self.rank_to_id(current));
            while session.len() < len {
                current = if rng.gen_bool(self.locality) {
                    // stay in the category block
                    let base = (current / block) * block;
                    let width = block.min(self.n_products - base);
                    base + rng.gen_range(width)
                } else {
                    pick_zipf(&mut rng, &cum)
                };
                let id = self.rank_to_id(current);
                if !session.contains(&id) {
                    session.push(id);
                }
            }
            session.sort_unstable();
            sessions.push(session);
        }
        sessions
    }

    #[inline]
    fn rank_to_id(&self, rank: usize) -> u32 {
        // popular products get scattered ids too: permute by multiplying
        // in a fixed odd stride modulo the catalogue, then stretch.
        let perm = (rank as u64 * 2654435761 % self.n_products as u64) as u32;
        perm * self.id_stride + 3
    }
}

fn zipf_cumulative(n: usize, s: f64) -> Vec<f64> {
    let raw: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
    let total: f64 = raw.iter().sum();
    let mut cum = Vec::with_capacity(n);
    let mut acc = 0.0;
    for w in raw {
        acc += w / total;
        cum.push(acc);
    }
    if let Some(last) = cum.last_mut() {
        *last = 1.0;
    }
    cum
}

fn pick_zipf(rng: &mut SplitMix64, cum: &[f64]) -> usize {
    let u = rng.next_f64();
    cum.partition_point(|&c| c < u).min(cum.len() - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let s = BmsSpec::bms1().scaled(0.01);
        assert_eq!(s.generate(3), s.generate(3));
    }

    #[test]
    fn bms1_statistics_near_table1() {
        let s = BmsSpec::bms1().scaled(0.2); // ~12K sessions
        let txns = s.generate(42);
        let avg = txns.iter().map(|t| t.len()).sum::<usize>() as f64 / txns.len() as f64;
        assert!((1.8..3.4).contains(&avg), "avg width {avg} vs paper 2.5");
        let distinct: std::collections::HashSet<u32> = txns.iter().flatten().copied().collect();
        assert!(
            distinct.len() <= 497,
            "more products than catalogue: {}",
            distinct.len()
        );
        assert!(distinct.len() > 300, "catalogue under-used: {}", distinct.len());
    }

    #[test]
    fn item_id_space_is_large() {
        // the property that forces triMatrixMode=false in the paper
        let txns = BmsSpec::bms1().scaled(0.05).generate(1);
        let max_id = txns.iter().flatten().max().copied().unwrap();
        assert!(max_id > 50_000, "ids too dense: max {max_id}");
    }

    #[test]
    fn popularity_is_skewed() {
        let txns = BmsSpec::bms2().scaled(0.1).generate(9);
        let mut counts: std::collections::HashMap<u32, usize> = Default::default();
        for t in &txns {
            for &i in t {
                *counts.entry(i).or_default() += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = freqs.iter().sum();
        let top10: usize = freqs.iter().take(freqs.len() / 10).sum();
        assert!(
            top10 as f64 / total as f64 > 0.4,
            "top-10% items only {}%",
            100 * top10 / total
        );
    }

    #[test]
    fn sessions_sorted_unique_nonempty() {
        let txns = BmsSpec::bms2().scaled(0.02).generate(4);
        for t in &txns {
            assert!(!t.is_empty());
            assert!(t.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn locality_produces_frequent_pairs() {
        let txns = BmsSpec::bms2().scaled(0.2).generate(2);
        let min_sup = (0.003 * txns.len() as f64).ceil() as u32;
        let r = crate::fim::sequential::eclat_sequential(&txns, min_sup);
        assert!(r.max_length() >= 2, "no frequent pairs at 0.3% support");
    }
}
