//! # Sparklet Streaming — micro-batch DStreams over the RDD engine
//!
//! A Spark-Streaming-style layer on top of [`super::SparkletContext`]:
//! a [`StreamContext`] drives *discrete* batches (logical ticks, no wall
//! clock — deterministic and testable), and a [`DStream<T>`] is the
//! recipe that produces one [`super::Rdd<T>`] per tick. Transformations
//! lower batch-wise onto the existing RDD operators, so the DAG
//! scheduler, shuffle, cache, and lineage recovery are reused as-is.
//!
//! Pieces, mirroring Spark Streaming's surface:
//!
//! * **Sources** — [`StreamContext::queue_stream`] (a pre-built queue of
//!   batches, Spark's `queueStream`) and
//!   [`StreamContext::generator_stream`] (a deterministic
//!   batch-index → records function, used to drive the repo's dataset
//!   generators as live feeds).
//! * **Per-batch transformations** — `map` / `flat_map` / `filter` /
//!   `transform`, each delegating to the same-named RDD operator.
//! * **Windows** — [`DStream::window`] (sliding) and
//!   [`DStream::tumbling`]: the window RDD is the union of the parent's
//!   last `size` batch RDDs; output fires every `slide` ticks. Parents
//!   remember (and cache) enough batches for the largest window over
//!   them.
//! * **State** — [`StatefulDStream::update_state_by_key`], built on
//!   [`super::PairRdd::cogroup`] plus the existing
//!   [`super::HashPartitioner`], with per-batch driver-side
//!   checkpointing so state lineage stays O(1) deep.
//!
//! Batch indices are monotone `0, 1, 2, …`; a stream with slide `s` is
//! *active* (produces output) at ticks where `(t + 1) % s == 0`. All
//! generated RDDs are memoized per batch and `cache()`d, then unpersisted
//! once they fall behind the stream's remember horizon.
//!
//! The FIM layer builds on this in `fim::streaming`: an incremental
//! sliding-window RDD-Eclat that re-mines only the parts of the itemset
//! lattice a window slide can actually change.

pub mod context;
pub mod dstream;
pub mod state;

pub use context::StreamContext;
pub use dstream::DStream;
pub use state::StatefulDStream;
