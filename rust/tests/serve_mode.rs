//! Serve-mode integration tests: the subsumption property (a cached
//! mine filtered to a higher threshold IS the fresh mine, across every
//! tidset representation), concurrent-client agreement with the
//! sequential oracle, shuffle-artifact hygiene across many requests on
//! the one persistent context, and typed Overloaded rejection under a
//! tiny memory budget. Everything drives the public socket-free
//! `Server::handle` — the wire framing has its own tests in
//! `serve::protocol` and `serve::server`.

use std::net::Shutdown;
use std::os::unix::net::UnixStream;
use std::sync::Arc;
use std::time::Duration;

use rdd_eclat::fim::sequential::eclat_sequential;
use rdd_eclat::fim::types::{abs_min_sup, MiningResult, Transaction};
use rdd_eclat::serve::{DatasetResolver, ServeError, ServeRequest, ServeResponse, ServeResult, Server};
use rdd_eclat::sparklet::transport::{read_frame, write_frame};
use rdd_eclat::sparklet::{FaultSite, SparkletConf, SparkletContext};

/// Deterministic pseudo-random database derived purely from `name`, so
/// the test-side oracle and the server-side resolver agree exactly.
fn dataset_for(name: &str) -> Vec<Transaction> {
    let (n, width) = if name == "huge" { (20_000, 10) } else { (48, 10) };
    let mut state = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
        })
        .max(1);
    let mut next = || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    (0..n)
        .map(|_| {
            let mut t: Vec<u32> = (0..width).filter(|_| next() % 100 < 40).collect();
            if t.is_empty() {
                t.push(0);
            }
            t
        })
        .collect()
}

fn resolver() -> DatasetResolver {
    Arc::new(|name: &str| {
        if name == "absent" {
            return Err(format!("unknown dataset {name:?}"));
        }
        Ok(dataset_for(name))
    })
}

fn req(dataset: &str, frac: f64, tidset: &str) -> ServeRequest {
    ServeRequest {
        tenant: "test".into(),
        dataset: dataset.into(),
        min_sup_frac: frac,
        engine: "eclat-v4".into(),
        tidset: tidset.into(),
        post: Vec::new(),
        min_conf: 0.0,
        shutdown: false,
    }
}

fn result(resp: ServeResponse) -> ServeResult {
    match resp {
        ServeResponse::Result(r) => r,
        other => panic!("expected a result, got {other:?}"),
    }
}

/// The tentpole property: seed the cache with one mine at a low
/// threshold, then every query at `s' >= s` answered by *filtering the
/// cached result* must equal a fresh sequential mine at `s'` — for every
/// tidset representation the engines speak.
#[test]
fn prop_subsumed_answers_equal_fresh_oracle_across_reprs() {
    let server = Server::new(SparkletContext::local(2), resolver());
    for repr in ["vec", "bitmap", "diffset", "hybrid"] {
        for tag in ["a", "b"] {
            // Distinct dataset per (repr, tag): every low-threshold mine
            // is a genuine miss mined with that representation.
            let name = format!("db-{repr}-{tag}");
            let txns = dataset_for(&name);
            let n = txns.len();

            let low = result(server.handle(&req(&name, 0.05, repr)));
            assert_eq!(low.cache_hit, "miss", "{name} first mine");
            assert_eq!(low.min_sup_abs, abs_min_sup(0.05, n));
            let oracle = eclat_sequential(&txns, low.min_sup_abs);
            assert!(
                MiningResult::new(low.itemsets).same_as(&oracle),
                "{name} ({repr}): fresh mine disagrees with the oracle"
            );

            for hi in [0.1, 0.2, 0.4] {
                let got = result(server.handle(&req(&name, hi, repr)));
                assert_eq!(got.cache_hit, "subsumed", "{name} at {hi}");
                let s_abs = abs_min_sup(hi, n);
                assert_eq!(got.min_sup_abs, s_abs);
                let oracle = eclat_sequential(&txns, s_abs);
                assert!(
                    MiningResult::new(got.itemsets).same_as(&oracle),
                    "{name} ({repr}): subsumed answer at {hi} != fresh mine"
                );
            }
        }
    }
}

/// N client threads firing a mix of repeat thresholds at one server:
/// every response (cache hit or fresh mine, in whatever interleaving the
/// scheduler picks) must equal the sequential oracle, and afterwards the
/// cache answers every threshold exactly.
#[test]
fn concurrent_clients_all_agree_with_the_oracle() {
    let server = Arc::new(Server::new(SparkletContext::local(4), resolver()));
    let name = "shared";
    let txns = dataset_for(name);
    let n = txns.len();
    let fracs = [0.05, 0.1, 0.2, 0.05, 0.1, 0.2, 0.05, 0.1];
    std::thread::scope(|s| {
        for (i, frac) in fracs.iter().enumerate() {
            let server = Arc::clone(&server);
            let txns = &txns;
            s.spawn(move || {
                let r = result(server.handle(&req(name, *frac, "auto")));
                let oracle = eclat_sequential(txns, abs_min_sup(*frac, n));
                assert!(
                    MiningResult::new(r.itemsets).same_as(&oracle),
                    "client {i} at {frac}: served result != oracle (hit: {})",
                    r.cache_hit
                );
            });
        }
    });
    // All three thresholds are cached now (racing duplicate mines are
    // allowed — same key, same result); repeats must be exact hits.
    for frac in [0.05, 0.1, 0.2] {
        let r = result(server.handle(&req(name, frac, "auto")));
        assert_eq!(r.cache_hit, "exact", "post-race repeat at {frac}");
    }
}

/// The persistent context must not accumulate shuffle artifacts across
/// requests: after every served request, the spill directory is at its
/// baseline and the block store holds nothing but the result cache's
/// external charges.
#[test]
fn many_requests_leave_no_shuffle_artifacts() {
    let conf = SparkletConf::new("serve-leak")
        .with_cores(2)
        .unwrap()
        .with_memory_budget_mb(1)
        .unwrap();
    let server = Server::new(SparkletContext::new(conf), resolver());
    let baseline = server.context().shuffle_manager().spill_file_count();
    for i in 0..40 {
        let frac = 0.04 + (i % 8) as f64 * 0.03;
        let name = format!("leak-{}", i % 3);
        let _ = result(server.handle(&req(&name, frac, "vec")));
        let sm = server.context().shuffle_manager();
        assert_eq!(
            sm.spill_file_count(),
            baseline,
            "request {i} left spill files behind"
        );
        assert_eq!(
            sm.used_bytes(),
            server.cache_bytes(),
            "request {i} leaked shuffle block memory"
        );
    }
    assert!(server.cache_len() > 0, "the sweep populated the cache");
}

// ------------------------------------------------- client disconnects

/// Spawn `server.run` on a fresh unix socket and wait until it accepts.
fn serve_on_socket(server: &Arc<Server>, name: &str) -> (String, std::thread::JoinHandle<()>) {
    let sock = std::env::temp_dir()
        .join(format!("sparklet-serve-{name}-{}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let srv = Arc::clone(server);
    let path = sock.clone();
    let handle = std::thread::spawn(move || {
        srv.run(&path).expect("serve loop failed");
    });
    for _ in 0..200 {
        if UnixStream::connect(&sock).is_ok() {
            return (sock, handle);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("server never bound {sock}");
}

/// Ask the server to shut down and join its accept loop.
fn shutdown_server(sock: &str, handle: std::thread::JoinHandle<()>) {
    let mut bye = req("small", 0.5, "vec");
    bye.shutdown = true;
    let mut s = UnixStream::connect(sock).expect("connect for shutdown");
    write_frame(&mut s, &bye.to_message()).expect("send shutdown");
    let _ = read_frame(&mut s); // ShuttingDown (or the stream closing)
    handle.join().expect("serve thread panicked");
    let _ = std::fs::remove_file(sock);
}

fn roundtrip(sock: &str, request: &ServeRequest) -> ServeResponse {
    let mut s = UnixStream::connect(sock).expect("connect");
    write_frame(&mut s, &request.to_message()).expect("send request");
    let msg = read_frame(&mut s).expect("read response");
    ServeResponse::from_message(&msg).expect("decode response")
}

/// A client that vanishes while its request is QUEUED behind a slow
/// mine (or mid-mine — the race is the point): the server's response
/// write fails, which must release the admission slot, wedge no other
/// waiter, and leak nothing.
#[test]
fn queued_client_disconnect_releases_slot_and_leaks_nothing() {
    let server = Arc::new(Server::new(SparkletContext::local(2), resolver()));
    let baseline = server.context().shuffle_manager().spill_file_count();
    let (sock, handle) = serve_on_socket(&server, "dc-queued");

    // C1 starts a slow mine ("huge" is ~20k transactions) that holds
    // the single admission slot for a while.
    let mut c1 = UnixStream::connect(&sock).expect("c1 connect");
    write_frame(&mut c1, &req("huge", 0.2, "vec").to_message()).expect("c1 send");
    std::thread::sleep(Duration::from_millis(20));

    // C2 sends a request that queues behind C1, then hangs up without
    // reading its answer.
    let mut c2 = UnixStream::connect(&sock).expect("c2 connect");
    write_frame(&mut c2, &req("dropped", 0.05, "vec").to_message()).expect("c2 send");
    c2.shutdown(Shutdown::Both).expect("c2 disconnect");
    drop(c2);

    // C3 must still be served exactly (the gate is not wedged by C2's
    // abandoned ticket) and agree with the oracle.
    let txns = dataset_for("alive");
    let r = result(roundtrip(&sock, &req("alive", 0.1, "vec")));
    let oracle = eclat_sequential(&txns, abs_min_sup(0.1, txns.len()));
    assert!(
        MiningResult::new(r.itemsets).same_as(&oracle),
        "post-disconnect client served a wrong answer"
    );

    // C1's slow mine also completes normally.
    let msg = read_frame(&mut c1).expect("c1 response");
    let c1_result = result(ServeResponse::from_message(&msg).expect("c1 decode"));
    let huge = dataset_for("huge");
    let oracle = eclat_sequential(&huge, c1_result.min_sup_abs);
    assert!(MiningResult::new(c1_result.itemsets).same_as(&oracle));
    drop(c1);

    shutdown_server(&sock, handle);
    // Hygiene: the block store holds only the result cache's charges,
    // and the spill directory is back at its baseline.
    let sm = server.context().shuffle_manager();
    assert_eq!(sm.used_bytes(), server.cache_bytes(), "leaked shuffle bytes");
    assert_eq!(sm.spill_file_count(), baseline, "orphaned spill files");
}

/// The injected variant: `serve_disconnect:nth=1` severs the connection
/// AFTER the request is fully handled (admitted, mined, ticket
/// released) but before the response bytes are written — the client
/// sees a dead socket, the server keeps serving, and the completed work
/// is already cached.
#[test]
fn admitted_client_disconnect_is_injected_and_recovered() {
    let conf = SparkletConf::new("serve-dc-inject")
        .with_cores(2)
        .unwrap()
        .with_fault_plan("serve_disconnect:nth=1")
        .unwrap();
    let server = Arc::new(Server::new(SparkletContext::new(conf), resolver()));
    let baseline = server.context().shuffle_manager().spill_file_count();
    let (sock, handle) = serve_on_socket(&server, "dc-admitted");

    // C1's request is handled, then the plane drops the connection
    // instead of writing the response.
    let mut c1 = UnixStream::connect(&sock).expect("c1 connect");
    write_frame(&mut c1, &req("inject", 0.05, "vec").to_message()).expect("c1 send");
    assert!(
        read_frame(&mut c1).is_err(),
        "the injected disconnect should close the stream before any response"
    );
    assert_eq!(
        server.context().faults().injected(FaultSite::ServeDisconnect),
        1,
        "the schedule must actually have fired"
    );

    // The request WAS admitted and completed: the same query from a
    // live client is answered from cache, exactly, with no re-mine.
    let r = result(roundtrip(&sock, &req("inject", 0.05, "vec")));
    assert_eq!(r.cache_hit, "exact", "the dropped client's mine was lost");
    let txns = dataset_for("inject");
    let oracle = eclat_sequential(&txns, abs_min_sup(0.05, txns.len()));
    assert!(MiningResult::new(r.itemsets).same_as(&oracle));

    // nth=1 is spent: later requests are served over intact streams.
    let r = result(roundtrip(&sock, &req("inject", 0.1, "vec")));
    assert_eq!(r.cache_hit, "subsumed");

    shutdown_server(&sock, handle);
    let sm = server.context().shuffle_manager();
    assert_eq!(sm.used_bytes(), server.cache_bytes(), "leaked shuffle bytes");
    assert_eq!(sm.spill_file_count(), baseline, "orphaned spill files");
}

/// A mine whose estimated working set exceeds the memory budget is
/// rejected with a typed Overloaded before any work happens.
#[test]
fn oversized_request_rejects_overloaded_under_tiny_budget() {
    let conf = SparkletConf::new("serve-overload")
        .with_cores(2)
        .unwrap()
        .with_memory_budget_mb(1)
        .unwrap();
    let server = Server::new(SparkletContext::new(conf), resolver());
    // "huge" resolves to ~20k transactions: estimated cost > 1 MiB.
    let resp = server.handle(&req("huge", 0.5, "vec"));
    match resp {
        ServeResponse::Error(ServeError::Overloaded { reason }) => {
            assert!(reason.contains("memory budget"), "{reason}");
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    // A small dataset still serves on the same server.
    let ok = result(server.handle(&req("small", 0.1, "vec")));
    assert_eq!(ok.cache_hit, "miss");
    // And an unresolvable dataset is a BadRequest, not a crash.
    assert!(matches!(
        server.handle(&req("absent", 0.1, "vec")),
        ServeResponse::Error(ServeError::BadRequest { .. })
    ));
}
