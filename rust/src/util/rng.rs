//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 (Steele et al., "Fast splittable pseudorandom number
//! generators", OOPSLA 2014): tiny state, passes BigCrush when used as a
//! 64-bit generator, and — crucially for reproducible experiments — every
//! dataset generator in this repo seeds one of these from the CLI seed, so
//! figure regeneration is bit-stable across runs.

/// SplitMix64 PRNG. `Clone` is intentional: generators are cheap to fork.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Fork a statistically independent child stream (used to give each
    /// partition / transaction its own generator without sharing state).
    pub fn fork(&mut self, tag: u64) -> Self {
        Self::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be > 0");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Poisson-distributed sample (Knuth's method; fine for small means,
    /// which is all the IBM Quest generator needs).
    pub fn poisson(&mut self, mean: f64) -> usize {
        if mean <= 0.0 {
            return 0;
        }
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // numeric guard; unreachable for sane means
            }
        }
    }

    /// Exponential sample with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn poisson_mean_roughly_right() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let mean = 10.0;
        let total: usize = (0..n).map(|_| r.poisson(mean)).sum();
        let got = total as f64 / n as f64;
        assert!((got - mean).abs() < 0.2, "poisson mean {got} vs {mean}");
    }

    #[test]
    fn exponential_mean_roughly_right() {
        let mut r = SplitMix64::new(13);
        let n = 20_000;
        let total: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        let got = total / n as f64;
        assert!((got - 4.0).abs() < 0.2, "exp mean {got}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = SplitMix64::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = SplitMix64::new(99);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
