//! Accumulators — Spark's write-only shared variables.
//!
//! The paper leans on two non-trivial accumulators: the triangular
//! matrix of candidate-2-itemset counts (`accMatrix`, EclatV1/V2) and a
//! hashmap of item→tidset (EclatV3). Both merges are commutative and
//! associative, which is all Spark guarantees for accumulator updates in
//! transformations.
//!
//! Implementation: the value is sharded across `n_shards` mutexes; a
//! task's `add` locks one shard chosen by thread id, so concurrent tasks
//! rarely contend. `value()` folds all shards with the user's `merge`.
//! Like Spark, updates from *re-executed* tasks can double-count; the
//! failure-injection tests assert only on counters that tolerate it.

use std::sync::{Arc, Mutex};

/// Commutative-merge accumulator value.
pub trait AccumValue: Send + 'static {
    fn merge(&mut self, other: Self);
}

impl AccumValue for u64 {
    fn merge(&mut self, other: Self) {
        *self += other;
    }
}

impl AccumValue for i64 {
    fn merge(&mut self, other: Self) {
        *self += other;
    }
}

impl AccumValue for f64 {
    fn merge(&mut self, other: Self) {
        *self += other;
    }
}

impl<T: Send + 'static> AccumValue for Vec<T> {
    fn merge(&mut self, mut other: Self) {
        self.append(&mut other);
    }
}

/// A sharded accumulator. Cloning yields a handle to the same value.
pub struct Accumulator<V: AccumValue> {
    shards: Arc<Vec<Mutex<V>>>,
    zero: Arc<dyn Fn() -> V + Send + Sync>,
}

impl<V: AccumValue> Clone for Accumulator<V> {
    fn clone(&self) -> Self {
        Self {
            shards: Arc::clone(&self.shards),
            zero: Arc::clone(&self.zero),
        }
    }
}

impl<V: AccumValue> Accumulator<V> {
    /// `zero` constructs the identity element (also used to drain shards).
    pub fn new(n_shards: usize, zero: impl Fn() -> V + Send + Sync + 'static) -> Self {
        let shards = (0..n_shards.max(1)).map(|_| Mutex::new(zero())).collect();
        Self {
            shards: Arc::new(shards),
            zero: Arc::new(zero),
        }
    }

    #[inline]
    fn shard_index(&self) -> usize {
        // Cheap per-thread affinity: hash the thread id.
        let tid = std::thread::current().id();
        let mut h = std::collections::hash_map::DefaultHasher::new();
        use std::hash::{Hash, Hasher};
        tid.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }

    /// Merge a delta into the accumulator (task-side `acc.add(..)`).
    pub fn add(&self, delta: V) {
        let idx = self.shard_index();
        self.shards[idx].lock().unwrap().merge(delta);
    }

    /// Apply an in-place update to this thread's shard — the high-rate
    /// path for the triangular-matrix accumulator (no temporary `V`).
    pub fn update_in_place(&self, f: impl FnOnce(&mut V)) {
        let idx = self.shard_index();
        f(&mut self.shards[idx].lock().unwrap());
    }

    /// Driver-side read: folds all shards into a fresh zero (leaving the
    /// shards intact so this can be called repeatedly).
    pub fn value_with(&self, mut fold: impl FnMut(&mut V, &V)) -> V {
        let mut acc = (self.zero)();
        for s in self.shards.iter() {
            fold(&mut acc, &s.lock().unwrap());
        }
        acc
    }

    /// Driver-side read that consumes shard contents (resets to zero).
    /// Cheaper than `value_with` for large values; use once per job.
    pub fn drain(&self) -> V {
        let mut acc = (self.zero)();
        for s in self.shards.iter() {
            let mut guard = s.lock().unwrap();
            let v = std::mem::replace(&mut *guard, (self.zero)());
            acc.merge(v);
        }
        acc
    }
}

impl<V: AccumValue + Clone> Accumulator<V> {
    /// Driver-side read for cloneable values.
    pub fn value(&self) -> V {
        self.value_with(|acc, shard| acc.merge(shard.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ThreadPool;

    #[test]
    fn counts_across_threads() {
        let acc: Accumulator<u64> = Accumulator::new(8, || 0);
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let acc = acc.clone();
                move || acc.add(1)
            })
            .collect();
        pool.run_all(jobs);
        assert_eq!(acc.value(), 100);
    }

    #[test]
    fn vec_accumulator_collects_everything() {
        let acc: Accumulator<Vec<u32>> = Accumulator::new(4, Vec::new);
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..50u32)
            .map(|i| {
                let acc = acc.clone();
                move || acc.add(vec![i])
            })
            .collect();
        pool.run_all(jobs);
        let mut v = acc.drain();
        v.sort_unstable();
        assert_eq!(v, (0..50).collect::<Vec<_>>());
        // drained: now empty
        assert!(acc.drain().is_empty());
    }

    #[test]
    fn update_in_place_accumulates() {
        let acc: Accumulator<Vec<u64>> = Accumulator::new(2, || vec![0; 4]);
        acc.update_in_place(|v| v[2] += 5);
        acc.update_in_place(|v| v[2] += 7);
        let total = acc.value_with(|a, s| {
            for (x, y) in a.iter_mut().zip(s) {
                *x += *y;
            }
        });
        assert_eq!(total[2], 12);
    }

    #[test]
    fn value_is_repeatable() {
        let acc: Accumulator<u64> = Accumulator::new(4, || 0);
        acc.add(3);
        assert_eq!(acc.value(), 3);
        assert_eq!(acc.value(), 3);
    }
}
