//! Property-based tests over randomized databases (in-tree harness —
//! proptest is unavailable offline): distributed == sequential, FIM
//! invariants, RDD semantics vs Vec oracles.

use rdd_eclat::fim::engine::{MiningSession, TidsetRepr};
use rdd_eclat::fim::sequential::{apriori_sequential, eclat_sequential};
use rdd_eclat::sparklet::{PairRdd, SparkletContext};
use rdd_eclat::util::prop::{forall, forall_shrink, gen};

#[test]
fn prop_every_variant_equals_oracle() {
    let sc = SparkletContext::local(2);
    forall_shrink(
        25,
        gen::database(30, 10, 0.35),
        |db| gen::shrink_database(db),
        |db| {
            let oracle = eclat_sequential(db, 2);
            ["eclat-v1", "eclat-v2", "eclat-v3", "eclat-v4", "eclat-v5"]
                .into_iter()
                .all(|engine| {
                    MiningSession::new(engine)
                        .min_sup(2)
                        .p(3)
                        .run_vec(&sc, db)
                        .unwrap()
                        .result
                        .same_as(&oracle)
                })
        },
    );
}

#[test]
fn prop_diffset_and_hybrid_kernels_equal_oracle() {
    // The dEclat subtraction kernel and the per-class adaptive kernel
    // must be invisible at the result level, across variants including
    // the 2-prefix fused V6 (whose decomposition also runs diffsets).
    let sc = SparkletContext::local(2);
    forall(12, gen::database(25, 8, 0.45), |db| {
        let oracle = eclat_sequential(db, 2);
        ["eclat-v2", "eclat-v4", "eclat-v6"].into_iter().all(|engine| {
            [TidsetRepr::Diffset, TidsetRepr::Hybrid]
                .into_iter()
                .all(|repr| {
                    MiningSession::new(engine)
                        .min_sup(2)
                        .tidset(repr)
                        .p(3)
                        .run_vec(&sc, db)
                        .unwrap()
                        .result
                        .same_as(&oracle)
                })
        })
    });
}

#[test]
fn prop_rdd_apriori_equals_sequential() {
    let sc = SparkletContext::local(3);
    forall(25, gen::database(25, 8, 0.4), |db| {
        for min_sup in [1u32, 2, 3] {
            let got = MiningSession::new("apriori")
                .min_sup(min_sup)
                .run_vec(&sc, db)
                .unwrap();
            if !got.result.same_as(&apriori_sequential(db, min_sup)) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_monotone_in_min_sup() {
    // Raising min_sup can only shrink the result set (and it must be a
    // subset).
    forall(30, gen::database(30, 9, 0.35), |db| {
        let lo = eclat_sequential(db, 2).canonical();
        let hi = eclat_sequential(db, 3).canonical();
        hi.iter().all(|x| lo.contains(x)) && hi.len() <= lo.len()
    });
}

#[test]
fn prop_supports_at_least_min_sup() {
    forall(30, gen::database(30, 9, 0.3), |db| {
        let r = eclat_sequential(db, 2);
        r.itemsets.iter().all(|f| f.support >= 2)
    });
}

#[test]
fn prop_transaction_order_irrelevant() {
    // Mining a permuted database yields the same itemsets.
    let sc = SparkletContext::local(2);
    let session = MiningSession::new("eclat-v4").min_sup(2);
    forall(20, gen::database(25, 8, 0.35), |db| {
        let mut shuffled = db.clone();
        shuffled.reverse();
        let a = session.run_vec(&sc, db).unwrap().result;
        let b = session.run_vec(&sc, &shuffled).unwrap().result;
        a.same_as(&b)
    });
}

// ------------------------- RDD semantics vs Vec oracle (randomized) ----

#[test]
fn prop_rdd_map_filter_equals_vec() {
    let sc = SparkletContext::local(3);
    forall(30, gen::vec_of(0, 200, |r| r.next_u32() % 1000), |data| {
        let want: Vec<u32> = data.iter().map(|x| x * 2).filter(|x| x % 3 != 0).collect();
        let got = sc
            .parallelize(data.clone(), 5)
            .map(|x| x * 2)
            .filter(|x| x % 3 != 0)
            .collect();
        got == want
    });
}

#[test]
fn prop_reduce_by_key_equals_hashmap() {
    let sc = SparkletContext::local(2);
    forall(
        25,
        gen::vec_of(0, 300, |r| (r.next_u32() % 20, r.next_u32() % 100)),
        |pairs| {
            let mut want: std::collections::HashMap<u32, u64> = Default::default();
            for (k, v) in pairs {
                *want.entry(*k).or_insert(0) += *v as u64;
            }
            let got = sc
                .parallelize(pairs.clone(), 4)
                .map(|(k, v)| (k, v as u64))
                .reduce_by_key(|a, b| a + b)
                .collect_as_map();
            got == want
        },
    );
}

#[test]
fn prop_group_by_key_partitions_values() {
    let sc = SparkletContext::local(2);
    forall(
        20,
        gen::vec_of(1, 200, |r| (r.next_u32() % 10, r.next_u32())),
        |pairs| {
            let grouped = sc.parallelize(pairs.clone(), 3).group_by_key().collect();
            let total: usize = grouped.iter().map(|(_, vs)| vs.len()).sum();
            // every value lands exactly once, under its own key
            total == pairs.len()
                && grouped.iter().all(|(k, vs)| {
                    vs.iter().all(|v| pairs.contains(&(*k, *v)))
                })
        },
    );
}

#[test]
fn prop_coalesce_preserves_content_order() {
    let sc = SparkletContext::local(2);
    forall(20, gen::vec_of(0, 300, |r| r.next_u32()), |data| {
        let rdd = sc.parallelize(data.clone(), 7).coalesce(2);
        rdd.collect() == *data
    });
}

#[test]
fn prop_zip_with_index_dense() {
    let sc = SparkletContext::local(3);
    forall(20, gen::vec_of(0, 150, |r| r.next_u32()), |data| {
        let indexed = sc.parallelize(data.clone(), 4).zip_with_index().collect();
        indexed
            .iter()
            .enumerate()
            .all(|(i, (x, idx))| *idx == i as u64 && *x == data[i])
    });
}
