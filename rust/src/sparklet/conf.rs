//! Engine configuration — the `SparkConf` analog.

/// Configuration for a [`super::SparkletContext`].
#[derive(Debug, Clone)]
pub struct SparkletConf {
    /// Application name (metrics / logs).
    pub app_name: String,
    /// Worker threads in the executor pool — `spark.executor.cores`.
    /// Also the default parallelism for `parallelize` and shuffles.
    pub executor_cores: usize,
    /// Default number of shuffle partitions (when a partitioner is not
    /// given explicitly). `spark.sql.shuffle.partitions` analog.
    pub shuffle_partitions: usize,
    /// Max attempts per task before the job fails (`spark.task.maxFailures`).
    pub max_task_failures: usize,
    /// Fault injection: probability a task panics on its first attempt.
    /// 0.0 disables. Deterministic per (stage, partition) given the seed.
    pub task_failure_rate: f64,
    /// Seed for failure injection.
    pub failure_seed: u64,
    /// Capture per-stage metrics (cheap; on by default).
    pub collect_metrics: bool,
}

impl Default for SparkletConf {
    fn default() -> Self {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self {
            app_name: "sparklet-app".into(),
            executor_cores: cores,
            shuffle_partitions: cores,
            max_task_failures: 4,
            task_failure_rate: 0.0,
            failure_seed: 0,
            collect_metrics: true,
        }
    }
}

impl SparkletConf {
    pub fn new(app_name: &str) -> Self {
        Self {
            app_name: app_name.into(),
            ..Default::default()
        }
    }

    pub fn with_cores(mut self, cores: usize) -> Self {
        assert!(cores > 0);
        self.executor_cores = cores;
        self.shuffle_partitions = cores;
        self
    }

    pub fn with_shuffle_partitions(mut self, n: usize) -> Self {
        self.shuffle_partitions = n;
        self
    }

    pub fn with_failure_injection(mut self, rate: f64, seed: u64) -> Self {
        self.task_failure_rate = rate;
        self.failure_seed = seed;
        self
    }

    pub fn with_max_task_failures(mut self, n: usize) -> Self {
        self.max_task_failures = n.max(1);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = SparkletConf::default();
        assert!(c.executor_cores >= 1);
        assert_eq!(c.task_failure_rate, 0.0);
        assert!(c.max_task_failures >= 1);
    }

    #[test]
    fn builders_chain() {
        let c = SparkletConf::new("t")
            .with_cores(3)
            .with_shuffle_partitions(7)
            .with_failure_injection(0.5, 9)
            .with_max_task_failures(2);
        assert_eq!(c.executor_cores, 3);
        assert_eq!(c.shuffle_partitions, 7);
        assert_eq!(c.task_failure_rate, 0.5);
        assert_eq!(c.max_task_failures, 2);
    }
}
