//! Distributed Bottom-Up: the FIM reduce phase as registered task
//! descriptors, so the `multi-process` executor can ship it to worker
//! processes.
//!
//! The paper's Phase-3/4 reduce stage — `partitionBy(partitioner)` +
//! `flatMap(Bottom-Up)` — is a closure in the in-process engine, which
//! cannot cross a process boundary. This module registers the same
//! computation under stable string keys (one per tidset
//! representation):
//!
//! | key | tidset kernel |
//! |-----|---------------|
//! | `fim.bottomup.vec`     | [`VecTidset`] |
//! | `fim.bottomup.bitmap`  | [`BitmapTidset`] |
//! | `fim.bottomup.diffset` | [`DiffTidset`] |
//! | `fim.bottomup.hybrid`  | [`HybridTidset`] |
//!
//! The payload is 24 bytes — `(shuffle_id, reduce_part, min_sup)` as
//! little-endian u64s. A worker fetches the reduce partition's shuffled
//! blocks (each a PR-5 record frame of `(rank, EquivalenceClass)`
//! pairs) over the transport, runs the allocation-free Bottom-Up, and
//! returns the frequent itemsets as one encoded record frame. Both
//! driver (local fallback path of `run_described_job`) and every
//! worker process must call [`register_tasks`] before mining — the key
//! string is all that crosses the wire.

use crate::fim::engine::FimError;
use crate::fim::eqclass::{bottom_up, EquivalenceClass};
use crate::fim::tidset::{BitmapTidset, DiffTidset, HybridTidset, TidOps, VecTidset};
use crate::fim::types::FrequentItemset;
use crate::sparklet::scheduler::run_described_job;
use crate::sparklet::serde::{decode_records, encode_records};
use crate::sparklet::transport::{TaskEnv, TaskRegistry};
use crate::sparklet::{Data, Rdd, SparkletContext};

/// Registry key for a tidset representation, or `None` for a type the
/// distributed tier has no kernel for (callers fall back to the
/// in-process closure path).
pub fn task_key<TS: TidOps>() -> Option<&'static str> {
    use std::any::TypeId;
    let t = TypeId::of::<TS>();
    if t == TypeId::of::<VecTidset>() {
        Some("fim.bottomup.vec")
    } else if t == TypeId::of::<BitmapTidset>() {
        Some("fim.bottomup.bitmap")
    } else if t == TypeId::of::<DiffTidset>() {
        Some("fim.bottomup.diffset")
    } else if t == TypeId::of::<HybridTidset>() {
        Some("fim.bottomup.hybrid")
    } else {
        None
    }
}

fn encode_payload(shuffle_id: usize, reduce_part: usize, min_sup: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(24);
    out.extend_from_slice(&(shuffle_id as u64).to_le_bytes());
    out.extend_from_slice(&(reduce_part as u64).to_le_bytes());
    out.extend_from_slice(&(min_sup as u64).to_le_bytes());
    out
}

fn decode_payload(payload: &[u8]) -> Result<(usize, usize, u32), String> {
    if payload.len() != 24 {
        return Err(format!(
            "bottom-up payload must be 24 bytes, got {}",
            payload.len()
        ));
    }
    let word = |i: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&payload[i * 8..(i + 1) * 8]);
        u64::from_le_bytes(b)
    };
    let min_sup = u32::try_from(word(2)).map_err(|_| "min_sup exceeds u32".to_string())?;
    Ok((word(0) as usize, word(1) as usize, min_sup))
}

/// The task body: fetch one reduce partition's equivalence classes,
/// mine them, return the itemsets. Generic over the tidset kernel;
/// monomorphized once per registered key.
fn bottom_up_task<TS: TidOps>(env: &TaskEnv<'_>, payload: &[u8]) -> Result<Vec<u8>, String> {
    let (shuffle_id, reduce_part, min_sup) = decode_payload(payload)?;
    let blocks = env.fetch_blocks(shuffle_id, reduce_part)?;
    let mut out: Vec<FrequentItemset> = Vec::new();
    for (id, bytes, _records) in &blocks {
        let classes: Vec<(usize, EquivalenceClass<TS>)> = decode_records(bytes)
            .map_err(|e| format!("cannot decode shuffle block {id}: {e}"))?;
        for (_, ec) in &classes {
            bottom_up(ec, min_sup, &mut out);
        }
    }
    Ok(encode_records(&out))
}

/// Register the four Bottom-Up kernels in the process-global
/// [`TaskRegistry`]. Idempotent; called at startup by the driver and by
/// every worker process (`main.rs` does both), and lazily by the
/// distributed mining path itself.
pub fn register_tasks() {
    TaskRegistry::register("fim.bottomup.vec", bottom_up_task::<VecTidset>);
    TaskRegistry::register("fim.bottomup.bitmap", bottom_up_task::<BitmapTidset>);
    TaskRegistry::register("fim.bottomup.diffset", bottom_up_task::<DiffTidset>);
    TaskRegistry::register("fim.bottomup.hybrid", bottom_up_task::<HybridTidset>);
}

/// Run the Bottom-Up phase of `ecs` (a class RDD sitting directly on
/// its `partitionBy` shuffle boundary) through the described-task path:
/// one descriptor per reduce partition, dispatched to worker processes
/// when the backend supports it, or run driver-local otherwise.
///
/// `Ok(None)` means the tidset type has no registered kernel and the
/// caller must fall back to the in-process closure path. `Err` carries
/// the scheduler's typed failure (retries exhausted, deadline exceeded)
/// or an undecodable partition result.
pub fn bottom_up_described<TS: TidOps>(
    sc: &SparkletContext,
    ecs: &Rdd<(usize, EquivalenceClass<TS>)>,
    min_sup: u32,
) -> Result<Option<Vec<FrequentItemset>>, FimError>
where
    (usize, EquivalenceClass<TS>): Data,
{
    let Some(key) = task_key::<TS>() else {
        return Ok(None);
    };
    register_tasks();
    let parts = run_described_job(sc, ecs, key, move |shuffle_id, part| {
        encode_payload(shuffle_id, part, min_sup)
    })
    .map_err(|e| FimError::Execution {
        reason: e.to_string(),
    })?;
    let mut out = Vec::new();
    for (part, bytes) in parts.iter().enumerate() {
        let found: Vec<FrequentItemset> =
            decode_records(bytes).map_err(|e| FimError::Execution {
                reason: format!("partition {part} returned an undecodable result: {e}"),
            })?;
        out.extend(found);
    }
    Ok(Some(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparklet::transport::BlockFetcher;

    #[test]
    fn payload_round_trips() {
        let p = encode_payload(7, 3, 42);
        assert_eq!(p.len(), 24);
        assert_eq!(decode_payload(&p).unwrap(), (7, 3, 42));
        assert!(decode_payload(&p[..23]).is_err());
        assert!(decode_payload(&[]).is_err());
    }

    #[test]
    fn task_keys_cover_all_kernels() {
        register_tasks();
        assert_eq!(task_key::<VecTidset>(), Some("fim.bottomup.vec"));
        assert_eq!(task_key::<BitmapTidset>(), Some("fim.bottomup.bitmap"));
        assert_eq!(task_key::<DiffTidset>(), Some("fim.bottomup.diffset"));
        assert_eq!(task_key::<HybridTidset>(), Some("fim.bottomup.hybrid"));
        for key in [
            "fim.bottomup.vec",
            "fim.bottomup.bitmap",
            "fim.bottomup.diffset",
            "fim.bottomup.hybrid",
        ] {
            assert!(TaskRegistry::get(key).is_some(), "{key} not registered");
        }
    }

    /// In-memory fetcher feeding hand-encoded class blocks to the task.
    struct FakeFetcher {
        blocks: Vec<Vec<u8>>,
    }

    impl BlockFetcher for FakeFetcher {
        fn fetch_blocks(
            &self,
            shuffle_id: usize,
            reduce_part: usize,
        ) -> Result<Vec<crate::sparklet::transport::WireBlock>, String> {
            Ok(self
                .blocks
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    (
                        crate::sparklet::BlockId {
                            shuffle_id,
                            reduce_part,
                            map_part: i,
                        },
                        b.clone(),
                        1,
                    )
                })
                .collect())
        }
    }

    #[test]
    fn bottom_up_task_mines_encoded_classes() {
        // One class {1}: members {1,2},{1,3} with tids such that
        // {1,2} has support 2, {1,3} support 2, {1,2,3} support 1.
        let class = EquivalenceClass::<VecTidset> {
            prefix: vec![1],
            members: vec![
                (2, VecTidset::from_tids(&[0, 1], 4)),
                (3, VecTidset::from_tids(&[1, 3], 4)),
            ],
        };
        let block = encode_records(&[(0usize, class)]);
        let fetcher = FakeFetcher {
            blocks: vec![block],
        };
        let env = TaskEnv::new(&fetcher);
        let result = bottom_up_task::<VecTidset>(&env, &encode_payload(0, 0, 2)).unwrap();
        let found: Vec<FrequentItemset> = decode_records(&result).unwrap();
        let mut sets: Vec<Vec<crate::fim::types::Item>> =
            found.iter().map(|f| f.items.clone()).collect();
        sets.sort();
        assert_eq!(sets, vec![vec![1, 2], vec![1, 3]]);
        // min_sup 1 also surfaces the 3-itemset.
        let result = bottom_up_task::<VecTidset>(&env, &encode_payload(0, 0, 1)).unwrap();
        let found: Vec<FrequentItemset> = decode_records(&result).unwrap();
        assert!(found.iter().any(|f| f.items == vec![1, 2, 3]));
    }

    #[test]
    fn corrupt_block_is_a_task_error_not_a_panic() {
        let fetcher = FakeFetcher {
            blocks: vec![vec![0xFF; 9]],
        };
        let env = TaskEnv::new(&fetcher);
        let err = bottom_up_task::<VecTidset>(&env, &encode_payload(0, 0, 2)).unwrap_err();
        assert!(err.contains("cannot decode"), "{err}");
    }
}
