//! # Sparklet — a from-scratch Spark-RDD-like dataflow engine
//!
//! The substrate the RDD-Eclat paper assumes: resilient distributed
//! datasets with lazy transformations, wide/narrow dependencies, a DAG
//! scheduler that splits stages at shuffle boundaries, a serialized
//! block shuffle ([`serde`] codec + [`block`] memory-budgeted store
//! with disk spill), broadcast variables, accumulators, partition
//! caching, and lineage based recomputation. "Executor cores" are worker threads of a
//! pluggable [`executor::ExecutorBackend`] (`fifo` | `work-stealing` |
//! `sequential`), so the paper's Fig. 5 core-scaling sweep maps
//! directly onto `SparkletConf::executor_cores` while the execution
//! substrate itself is a swappable axis
//! (`SparkletConf::with_executor_backend`, CLI `--executor`). The
//! [`streaming`] submodule layers a Spark-Streaming-style micro-batch
//! model (DStreams, windows, state) on top of the same scheduler.
//!
//! Design notes
//! * RDDs are typed (`Rdd<T>`); the scheduler sees the DAG through the
//!   object-safe [`DepNode`] view, and each shuffle boundary carries a
//!   type-erased map-task runner so stages stay monomorphic inside.
//! * Partition `compute` materializes a `Vec<T>` (not a lazy iterator):
//!   simpler lifetimes, identical semantics, and the FIM workloads hold
//!   partitions in memory anyway (Spark would too, with `cache()`).
//! * Failure injection (`SparkletConf::task_failure_rate`) makes tasks
//!   panic on their first attempt with a seeded coin; the scheduler
//!   retries from lineage, which is exactly Spark's recovery story.

pub mod accumulator;
pub mod block;
pub mod broadcast;
pub mod cache;
pub mod conf;
pub mod context;
pub mod events;
pub mod executor;
pub mod faults;
pub mod metrics;
pub mod pair;
pub mod partitioner;
pub mod rdd;
pub mod remote;
pub mod scheduler;
pub mod serde;
pub mod shuffle;
pub mod streaming;
pub mod transforms;
pub mod transport;

pub use accumulator::Accumulator;
pub use block::{BlockId, BlockStore, ShuffleBlock};
pub use broadcast::Broadcast;
pub use conf::{ConfError, SparkletConf};
pub use context::SparkletContext;
pub use events::{
    CollectingListener, EventBus, EventListener, EventLogWriter, MetricsListener, SparkletEvent,
};
pub use faults::{FaultPlan, FaultPlane, FaultSite, RetryError, RetryPolicy};
pub use serde::{SerDe, SerDeError};
pub use shuffle::ShuffleError;
pub use executor::{
    ExecutorBackend, ExecutorError, ExecutorRegistry, JobHandle, TaskSet, TaskSetStats,
};
pub use pair::PairRdd;
pub use partitioner::{HashPartitioner, Partitioner, RangePartitioner};
pub use rdd::{Data, Rdd, TaskContext};
pub use remote::{MultiProcessBackend, THREAD_WORKERS};
pub use streaming::{DStream, StatefulDStream, StreamContext};
pub use transport::{Message, TaskDescriptor, TaskRegistry, TransportError};
