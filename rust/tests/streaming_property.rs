//! Streaming correctness property: for random batch sequences, the
//! incremental sliding-window miner's per-window frequent itemsets
//! exactly match a from-scratch `mine_eclat` on the window's
//! concatenated transactions — across all window/slide combinations
//! (overlapping, tumbling, and gapped windows) and support thresholds.

use rdd_eclat::fim::engine::MiningSession;
use rdd_eclat::fim::sequential::eclat_sequential;
use rdd_eclat::fim::streaming::{BackpressureConfig, IncrementalEclat, StreamingEclatConfig};
use rdd_eclat::fim::Transaction;
use rdd_eclat::sparklet::SparkletContext;
use rdd_eclat::util::prop::forall;
use rdd_eclat::util::SplitMix64;

/// A random stream: (min_sup, batches). Batches may be empty; items are
/// drawn from a small universe so 2+/3+-itemsets actually occur.
fn gen_stream(r: &mut SplitMix64) -> (u32, Vec<Vec<Transaction>>) {
    let min_sup = 1 + r.gen_range(3) as u32;
    let n_batches = 2 + r.gen_range(4); // 2..=5 batches
    let batches = (0..n_batches)
        .map(|_| {
            let n_txn = r.gen_range(10); // 0..=9 transactions (empty ok)
            (0..n_txn)
                .map(|_| {
                    let width = 1 + r.gen_range(5);
                    let mut t: Vec<u32> = (0..width).map(|_| r.gen_range(8) as u32).collect();
                    t.sort_unstable();
                    t.dedup();
                    t
                })
                .collect()
        })
        .collect();
    (min_sup, batches)
}

/// Concatenation of the last `window` batches ending at batch `upto`.
fn window_txns(batches: &[Vec<Transaction>], upto: usize, window: usize) -> Vec<Transaction> {
    let lo = (upto + 1).saturating_sub(window);
    batches[lo..=upto].iter().flatten().cloned().collect()
}

#[test]
fn incremental_matches_full_mine_for_all_window_slide_combos() {
    let sc = SparkletContext::local(2);
    forall(20, gen_stream, |(min_sup, batches)| {
        let n = batches.len();
        for window in 1..=n {
            for slide in 1..=n {
                // Wired to the 2-core context: windows with >= 2
                // frequent items re-mine through the executor (one task
                // per class), the rest on the driver — both paths are
                // held to the from-scratch oracle here.
                let mut inc =
                    IncrementalEclat::new(StreamingEclatConfig::new(*min_sup, window, slide))
                        .with_context(sc.clone());
                let session = MiningSession::new("eclat-v4").min_sup(*min_sup).p(3);
                for (t, b) in batches.iter().enumerate() {
                    inc.push_batch(b).unwrap();
                    if (t + 1) % slide != 0 {
                        continue;
                    }
                    let got = inc.mine_window();
                    let want = session
                        .run_vec(&sc, &window_txns(batches, t, window))
                        .unwrap()
                        .result;
                    if !got.same_as(&want) {
                        eprintln!(
                            "mismatch: min_sup={min_sup} window={window} slide={slide} t={t}\n\
                             got  {:?}\nwant {:?}",
                            got.canonical(),
                            want.canonical()
                        );
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// A small random transaction for the backpressure stream.
fn bp_txn(rng: &mut SplitMix64) -> Transaction {
    let width = 1 + rng.gen_range(4);
    let mut t: Vec<u32> = (0..width).map(|_| rng.gen_range(6) as u32).collect();
    t.sort_unstable();
    t.dedup();
    t
}

#[test]
fn backpressure_property_shrinks_under_inflation_recovers_and_stays_exact() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    // Random offered-batch sizes and a byte-inflation factor: while the
    // synthetic workload inflates shuffle bytes past the watermark the
    // effective batch limit must shrink below the offered batch size;
    // once the signal calms it must recover additively and drain every
    // deferred transaction; and throughout, each window mine must equal
    // the sequential oracle over the *accepted* stream (mirrored here
    // through the same FIFO the miner uses — deferral reorders nothing).
    forall(
        8,
        |r: &mut SplitMix64| {
            let sizes: Vec<usize> = (0..10).map(|_| 4 + r.gen_range(8)).collect();
            let factor = 2_000 + r.gen_range(2_000) as u64;
            (sizes, factor)
        },
        |(sizes, factor)| {
            let bytes = Arc::new(AtomicU64::new(0));
            let probe = Arc::clone(&bytes);
            let (min_sup, window) = (1u32, 3usize);
            let cfg = StreamingEclatConfig::new(min_sup, window, 1).with_backpressure(
                BackpressureConfig::new(4_000)
                    .with_min_batch(2)
                    .with_increase_step(4),
            );
            let mut inc = IncrementalEclat::new(cfg)
                .with_byte_source(move || probe.load(Ordering::Relaxed));

            let mut rng = SplitMix64::new(0xB4C4);
            let mut pending: std::collections::VecDeque<Transaction> = Default::default();
            let mut groups: Vec<Vec<Transaction>> = Vec::new();
            let mut min_limit_seen = usize::MAX;

            // Hot phase: every accepted transaction inflates the byte
            // signal, driving the controller past the watermark.
            for &n in sizes {
                let batch: Vec<Transaction> = (0..n).map(|_| bp_txn(&mut rng)).collect();
                pending.extend(batch.iter().cloned());
                let out = inc.push_batch(&batch).unwrap();
                let group: Vec<Transaction> =
                    (0..out.accepted).map(|_| pending.pop_front().unwrap()).collect();
                groups.push(group);
                if let Some(l) = out.effective_limit {
                    min_limit_seen = min_limit_seen.min(l);
                }
                let got = inc.mine_window();
                let w: Vec<Transaction> = groups[groups.len().saturating_sub(window)..]
                    .iter()
                    .flatten()
                    .cloned()
                    .collect();
                if !got.same_as(&eclat_sequential(&w, min_sup)) {
                    eprintln!("window mismatch during hot phase (n={n})");
                    return false;
                }
                bytes.fetch_add(factor * out.accepted as u64, Ordering::Relaxed);
            }
            let max_batch = *sizes.iter().max().unwrap();
            if min_limit_seen >= max_batch {
                eprintln!("limit never shrank below the offered batch ({min_limit_seen} >= {max_batch})");
                return false;
            }

            // Calm phase: flat byte signal -> additive recovery drains
            // the deferred queue and lifts the limit back up.
            let mut last_deferred = usize::MAX;
            let mut last_limit = 0usize;
            for _ in 0..40 {
                let out = inc.push_batch(&[]).unwrap();
                let group: Vec<Transaction> =
                    (0..out.accepted).map(|_| pending.pop_front().unwrap()).collect();
                groups.push(group);
                last_deferred = out.deferred;
                last_limit = out.effective_limit.unwrap_or(usize::MAX);
            }
            let report = inc.report();
            let bp = report.backpressure.as_ref().unwrap();
            last_deferred == 0
                && pending.is_empty()
                && last_limit > min_limit_seen
                && bp.shrinks >= 1
                && bp.recoveries >= 1
        },
    );
}

#[test]
fn incremental_matches_sequential_oracle_on_long_overlapping_stream() {
    // Longer stream with heavy overlap — the regime where the lattice
    // cache carries most of the work — checked against the sequential
    // oracle every slide.
    let mut rng = SplitMix64::new(0x5EED_57E4);
    let batches: Vec<Vec<Transaction>> = (0..12)
        .map(|_| {
            (0..6)
                .map(|_| {
                    let width = 1 + rng.gen_range(4);
                    let mut t: Vec<u32> =
                        (0..width).map(|_| rng.gen_range(6) as u32).collect();
                    t.sort_unstable();
                    t.dedup();
                    t
                })
                .collect()
        })
        .collect();
    let (window, slide, min_sup) = (6usize, 1usize, 3u32);
    let mut inc = IncrementalEclat::new(StreamingEclatConfig::new(min_sup, window, slide));
    for (t, b) in batches.iter().enumerate() {
        inc.push_batch(b).unwrap();
        let got = inc.mine_window();
        let want = eclat_sequential(&window_txns(&batches, t, window), min_sup);
        assert!(got.same_as(&want), "t={t}: {:?}", got.canonical());
    }
    // With 5/6 of each window shared, the cache must be doing real work.
    let stats = inc.stats();
    assert!(
        stats.cache_hits > 0,
        "overlapping stream never reused the lattice cache: {stats}"
    );
}
