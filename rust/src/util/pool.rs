//! Fixed-size worker thread pool — the "executor cores" of Sparklet.
//!
//! No tokio/rayon offline, so the pool is built on std primitives: a
//! shared `Mutex<VecDeque>` job queue with a `Condvar`, N worker threads,
//! and completion signalled through per-job channels. The Spark analogy:
//! one pool = one executor JVM, `threads` = `spark.executor.cores`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    active: AtomicUsize,
}

/// A fixed pool of worker threads executing queued jobs FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sparklet-exec-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        Self {
            shared,
            workers,
            size,
        }
    }

    /// Number of worker threads ("executor cores").
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(job));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Run a batch of jobs and collect their results in input order,
    /// blocking until all complete. Panics in jobs are converted into
    /// `Err` strings so the scheduler can retry from lineage.
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<Result<T, String>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let receivers: Vec<Receiver<Result<T, String>>> = jobs
            .into_iter()
            .map(|job| {
                let (tx, rx) = channel();
                self.execute(move || {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
                        .map_err(|e| panic_message(e.as_ref()));
                    let _ = tx.send(result);
                });
                rx
            })
            .collect();
        receivers
            .into_iter()
            .map(|rx| {
                rx.recv()
                    .unwrap_or_else(|_| Err("worker dropped result channel".into()))
            })
            .collect()
    }

    /// Number of jobs currently executing — surfaced as the
    /// active-tasks gauge in `MetricsRegistry::report` via the fifo
    /// executor backend.
    pub fn active(&self) -> usize {
        self.shared.active.load(Ordering::Relaxed)
    }
}

/// Extract a human-readable message from a caught panic payload (shared
/// with the executor backends via `sparklet::executor`).
pub(crate) fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        shared.active.fetch_add(1, Ordering::Relaxed);
        job();
        shared.active.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // Store + notify under the queue lock: a worker that just
            // saw shutdown=false holds this lock until it enters
            // `wait`, so the notify cannot slip into that window and
            // leave it asleep forever (join would hang).
            let _queue = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.available.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs_in_order_of_submission_results() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..100)
            .map(|i| move || i * 2)
            .collect();
        let results = pool.run_all(jobs);
        for (i, r) in results.into_iter().enumerate() {
            assert_eq!(r.unwrap(), i * 2);
        }
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let peak = Arc::new(AtomicU64::new(0));
        let jobs: Vec<_> = (0..16)
            .map(|_| {
                let c = Arc::clone(&counter);
                let p = Arc::clone(&peak);
                move || {
                    let now = c.fetch_add(1, Ordering::SeqCst) + 1;
                    p.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    c.fetch_sub(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.run_all(jobs);
        assert!(peak.load(Ordering::SeqCst) >= 2, "no overlap observed");
    }

    #[test]
    fn panic_becomes_err_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let results = pool.run_all(vec![
            Box::new(|| 1usize) as Box<dyn FnOnce() -> usize + Send>,
            Box::new(|| panic!("boom")),
            Box::new(|| 3usize),
        ]);
        assert_eq!(results[0].as_ref().unwrap(), &1);
        assert!(results[1].as_ref().unwrap_err().contains("boom"));
        assert_eq!(results[2].as_ref().unwrap(), &3);
        // pool still works afterwards
        let again = pool.run_all(vec![|| 7usize]);
        assert_eq!(again[0].as_ref().unwrap(), &7);
    }

    #[test]
    fn shutdown_joins_workers() {
        let pool = ThreadPool::new(3);
        let _ = pool.run_all((0..10).map(|i| move || i).collect::<Vec<_>>());
        drop(pool); // must not hang
    }
}
