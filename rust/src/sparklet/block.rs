//! Shuffle block storage with a memory budget and disk spill.
//!
//! A [`ShuffleBlock`] is one map task's serialized output for one reduce
//! partition: owned bytes ([`super::serde::encode_records`] framing) plus
//! the record count. Blocks live in the [`BlockStore`], which enforces a
//! configurable in-memory budget (`SparkletConf::with_memory_budget_mb` /
//! `SPARKLET_MEMORY_MB` / `--memory-budget`): when resident block bytes
//! exceed the budget, the coldest (least-recently-used) blocks are
//! spilled to temp files and transparently reloaded on the next fetch.
//! Spill/reload counters feed `StageMetrics` and the bench rows.
//!
//! Because blocks are self-contained byte buffers, spilling is a
//! verbatim file write — no re-serialization — and the same property is
//! what makes the store a drop-in seam for a future multi-process
//! transport (ship the bytes instead of writing them to disk).

use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::faults::{FaultPlane, FaultSite};

/// Identity of one shuffle block: which shuffle, which reduce partition
/// it is destined for, and which map task produced it. Keying on the
/// full triple makes map-task retries idempotent — a re-run *overwrites*
/// its block instead of appending a duplicate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId {
    pub shuffle_id: usize,
    pub reduce_part: usize,
    pub map_part: usize,
}

impl fmt::Display for BlockId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "shuffle{}/reduce{}/map{}",
            self.shuffle_id, self.reduce_part, self.map_part
        )
    }
}

/// Block ids travel in `BlockData` transport frames, so workers can
/// attribute (and later cache) fetched map output per producing task.
impl super::serde::SerDe for BlockId {
    fn encode(&self, out: &mut Vec<u8>) {
        self.shuffle_id.encode(out);
        self.reduce_part.encode(out);
        self.map_part.encode(out);
    }
    fn decode(r: &mut super::serde::Reader<'_>) -> Result<Self, super::serde::SerDeError> {
        Ok(Self {
            shuffle_id: usize::decode(r)?,
            reduce_part: usize::decode(r)?,
            map_part: usize::decode(r)?,
        })
    }
}

/// One fetched block: the serialized payload plus its record count.
/// Cheap to clone (the bytes are shared with the store).
#[derive(Debug, Clone)]
pub struct ShuffleBlock {
    pub bytes: Arc<Vec<u8>>,
    pub records: usize,
}

impl ShuffleBlock {
    /// Exact serialized size in bytes.
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

/// Typed disk-IO failures on the spill/reload path. A broken (or
/// injected) disk surfaces as a recoverable task error through
/// [`super::shuffle::ShuffleError`], never as a driver panic: the spill
/// file and its entry are left in place, so a retry after a transient
/// fault reloads successfully.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BlockIoError {
    /// Reading a spilled block back from disk failed.
    Read {
        id: BlockId,
        path: String,
        reason: String,
    },
    /// The spill file's size no longer matches the block's recorded
    /// length (truncation or corruption on disk).
    LengthDrift {
        id: BlockId,
        expected: usize,
        got: usize,
    },
}

impl fmt::Display for BlockIoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Read { id, path, reason } => {
                write!(f, "shuffle spill file {path} for block {id} unreadable: {reason}")
            }
            Self::LengthDrift { id, expected, got } => {
                write!(
                    f,
                    "spill file length drift for block {id}: expected {expected} B, got {got} B"
                )
            }
        }
    }
}

impl std::error::Error for BlockIoError {}

enum Slot {
    Mem(Arc<Vec<u8>>),
    Spilled(PathBuf),
}

struct Entry {
    records: usize,
    len: usize,
    last_use: u64,
    slot: Slot,
}

struct Inner {
    blocks: HashMap<BlockId, Entry>,
    /// Bytes currently resident in memory (sum of `Mem` entry lengths).
    mem_bytes: usize,
    /// Monotone access clock driving the LRU spill order.
    clock: u64,
    /// Lazily created spill directory (only once something spills).
    spill_dir: Option<PathBuf>,
}

/// Counter used to give each store in the process a unique spill dir.
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Observer for block IO: `(block, bytes, is_reload)` — `false` for a
/// spill to disk, `true` for a reload on fetch. Fired *after* the store
/// lock is released, so the callback may do arbitrary work (the context
/// routes it onto the event bus).
pub type BlockIoHook = Arc<dyn Fn(BlockId, usize, bool) + Send + Sync>;

/// Memory-budgeted block storage with LRU spill-to-disk.
pub struct BlockStore {
    /// In-memory budget in bytes (`usize::MAX` = unlimited).
    budget: usize,
    seq: u64,
    inner: Mutex<Inner>,
    spilled_blocks: AtomicU64,
    reloaded_blocks: AtomicU64,
    spilled_bytes: AtomicU64,
    /// Bytes charged by co-tenants of the budget that don't live in the
    /// store (the serve-mode result cache). They count against the same
    /// budget — external pressure LRU-spills shuffle blocks — but can't
    /// themselves be spilled, only released.
    external_bytes: AtomicU64,
    hook: Mutex<Option<BlockIoHook>>,
    /// Fault-injection plane for the spill read/write sites. Disarmed
    /// until the owning context installs its armed plane.
    faults: Mutex<Arc<FaultPlane>>,
}

impl BlockStore {
    /// `budget_bytes: None` means unlimited (never spill).
    pub fn new(budget_bytes: Option<usize>) -> Self {
        Self {
            budget: budget_bytes.unwrap_or(usize::MAX),
            seq: STORE_SEQ.fetch_add(1, Ordering::Relaxed),
            inner: Mutex::new(Inner {
                blocks: HashMap::new(),
                mem_bytes: 0,
                clock: 0,
                spill_dir: None,
            }),
            spilled_blocks: AtomicU64::new(0),
            reloaded_blocks: AtomicU64::new(0),
            spilled_bytes: AtomicU64::new(0),
            external_bytes: AtomicU64::new(0),
            hook: Mutex::new(None),
            faults: Mutex::new(Arc::new(FaultPlane::disarmed())),
        }
    }

    /// Install the spill/reload observer (replacing any previous one).
    pub fn set_spill_hook(&self, hook: BlockIoHook) {
        *self.hook.lock().unwrap() = Some(hook);
    }

    /// Arm the spill read/write fault sites with the context's plane.
    pub fn set_fault_plane(&self, plane: Arc<FaultPlane>) {
        *self.faults.lock().unwrap() = plane;
    }

    fn fault_plane(&self) -> Arc<FaultPlane> {
        Arc::clone(&self.faults.lock().unwrap())
    }

    /// Fire collected notifications outside the store lock.
    fn fire_hook(&self, fired: &[(BlockId, usize, bool)]) {
        if fired.is_empty() {
            return;
        }
        let hook = self.hook.lock().unwrap().clone();
        if let Some(hook) = hook {
            for &(id, bytes, reload) in fired {
                hook(id, bytes, reload);
            }
        }
    }

    /// Insert (or overwrite) a block, then enforce the memory budget.
    pub fn put(&self, id: BlockId, bytes: Vec<u8>, records: usize) {
        let len = bytes.len();
        let mut fired = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            inner.clock += 1;
            let entry = Entry {
                records,
                len,
                last_use: inner.clock,
                slot: Slot::Mem(Arc::new(bytes)),
            };
            if let Some(old) = inner.blocks.insert(id, entry) {
                match old.slot {
                    Slot::Mem(_) => inner.mem_bytes -= old.len,
                    Slot::Spilled(path) => {
                        let _ = std::fs::remove_file(path);
                    }
                }
            }
            inner.mem_bytes += len;
            self.enforce_budget(&mut inner, &mut fired);
        }
        self.fire_hook(&fired);
    }

    /// Fetch a block, transparently reloading it from disk if it was
    /// spilled (the reload re-admits it under the budget, which may in
    /// turn spill colder blocks). `Ok(None)` if the id was never
    /// written; `Err` when the spill file cannot be read back — the
    /// entry and its file stay in place, so a retry after a transient
    /// disk fault can still succeed.
    pub fn get(&self, id: &BlockId) -> Result<Option<ShuffleBlock>, BlockIoError> {
        let faults = self.fault_plane();
        let mut fired = Vec::new();
        let block = {
            let mut inner = self.inner.lock().unwrap();
            inner.clock += 1;
            let clock = inner.clock;
            let Some(entry) = inner.blocks.get_mut(id) else {
                return Ok(None);
            };
            entry.last_use = clock;
            let records = entry.records;
            let spilled_path = match &entry.slot {
                Slot::Spilled(p) => Some(p.clone()),
                Slot::Mem(_) => None,
            };
            let (bytes, readmitted) = match spilled_path {
                None => {
                    let Slot::Mem(b) = &entry.slot else {
                        unreachable!("checked above")
                    };
                    (Arc::clone(b), 0)
                }
                Some(path) => {
                    // Inject BEFORE the read: the file is untouched, so
                    // the fault is indistinguishable from a transient
                    // IO error and a retry genuinely recovers.
                    if faults.should_fail(FaultSite::SpillRead) {
                        return Err(BlockIoError::Read {
                            id: *id,
                            path: path.display().to_string(),
                            reason: "injected spill_read fault".into(),
                        });
                    }
                    let data = match std::fs::read(&path) {
                        Ok(data) => data,
                        Err(e) => {
                            return Err(BlockIoError::Read {
                                id: *id,
                                path: path.display().to_string(),
                                reason: e.to_string(),
                            })
                        }
                    };
                    if data.len() != entry.len {
                        return Err(BlockIoError::LengthDrift {
                            id: *id,
                            expected: entry.len,
                            got: data.len(),
                        });
                    }
                    let _ = std::fs::remove_file(&path);
                    let arc = Arc::new(data);
                    entry.slot = Slot::Mem(Arc::clone(&arc));
                    self.reloaded_blocks.fetch_add(1, Ordering::Relaxed);
                    let len = entry.len;
                    fired.push((*id, len, true));
                    (arc, len)
                }
            };
            if readmitted > 0 {
                inner.mem_bytes += readmitted;
                self.enforce_budget(&mut inner, &mut fired);
            }
            Ok(Some(ShuffleBlock { bytes, records }))
        };
        self.fire_hook(&fired);
        block
    }

    /// Drop every block whose id matches `pred`, deleting spill files.
    pub fn remove_where(&self, pred: impl Fn(&BlockId) -> bool) {
        let mut inner = self.inner.lock().unwrap();
        let victims: Vec<BlockId> = inner.blocks.keys().filter(|id| pred(id)).copied().collect();
        for id in victims {
            if let Some(e) = inner.blocks.remove(&id) {
                match e.slot {
                    Slot::Mem(_) => inner.mem_bytes -= e.len,
                    Slot::Spilled(path) => {
                        let _ = std::fs::remove_file(path);
                    }
                }
            }
        }
    }

    /// Drop everything.
    pub fn clear(&self) {
        self.remove_where(|_| true);
    }

    /// Blocks spilled to disk since the store was created.
    pub fn spilled_blocks(&self) -> u64 {
        self.spilled_blocks.load(Ordering::Relaxed)
    }

    /// Spilled blocks reloaded from disk on fetch.
    pub fn reloaded_blocks(&self) -> u64 {
        self.reloaded_blocks.load(Ordering::Relaxed)
    }

    /// Total bytes written to spill files.
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes.load(Ordering::Relaxed)
    }

    /// Bytes currently resident in memory.
    pub fn mem_bytes(&self) -> usize {
        self.inner.lock().unwrap().mem_bytes
    }

    /// The configured budget in bytes (`usize::MAX` = unlimited).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Charge `bytes` of external (non-block) usage against the budget.
    /// Resident shuffle blocks are LRU-spilled if the combined total now
    /// exceeds it — external bytes themselves cannot spill.
    pub fn charge_external(&self, bytes: usize) {
        self.external_bytes
            .fetch_add(bytes as u64, Ordering::Relaxed);
        let mut fired = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            self.enforce_budget(&mut inner, &mut fired);
        }
        self.fire_hook(&fired);
    }

    /// Release previously charged external bytes (saturating — an
    /// over-release clamps to zero rather than wrapping).
    pub fn release_external(&self, bytes: usize) {
        let mut cur = self.external_bytes.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes as u64);
            match self.external_bytes.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Externally charged bytes currently outstanding.
    pub fn external_bytes(&self) -> usize {
        self.external_bytes.load(Ordering::Relaxed) as usize
    }

    /// Total budget consumption: resident block bytes plus external
    /// charges. This is the number serve-mode admission compares
    /// against the budget.
    pub fn used_bytes(&self) -> usize {
        let ext = self.external_bytes.load(Ordering::Relaxed) as usize;
        self.inner.lock().unwrap().mem_bytes.saturating_add(ext)
    }

    /// Files currently present in the spill directory (0 if nothing has
    /// ever spilled). The serve-mode leak test asserts this returns to
    /// its baseline after each request's `clear_shuffle`.
    pub fn spill_file_count(&self) -> usize {
        let dir = self.inner.lock().unwrap().spill_dir.clone();
        match dir {
            None => 0,
            Some(dir) => std::fs::read_dir(dir).map(|it| it.count()).unwrap_or(0),
        }
    }

    /// LRU-spill cold blocks until the resident set fits the budget.
    /// File IO happens under the store lock — acceptable at this
    /// engine's scale, and it keeps the accounting race-free. Spill
    /// notifications are collected into `fired` for the caller to
    /// deliver once the lock is released.
    fn enforce_budget(&self, inner: &mut Inner, fired: &mut Vec<(BlockId, usize, bool)>) {
        let faults = self.fault_plane();
        let external = self.external_bytes.load(Ordering::Relaxed) as usize;
        while inner.mem_bytes.saturating_add(external) > self.budget {
            let victim = inner
                .blocks
                .iter()
                .filter(|(_, e)| matches!(e.slot, Slot::Mem(_)))
                .min_by_key(|(_, e)| e.last_use)
                .map(|(id, _)| *id);
            let Some(id) = victim else { break };
            let Some(dir) = ensure_spill_dir(inner, self.seq) else {
                break; // spill dir unavailable: keep blocks in memory
            };
            let entry = inner.blocks.get_mut(&id).expect("victim exists");
            let Slot::Mem(bytes) = &entry.slot else {
                unreachable!("victim filter keeps only resident blocks")
            };
            let path = dir.join(format!(
                "block-{}-{}-{}.bin",
                id.shuffle_id, id.reduce_part, id.map_part
            ));
            // Inject BEFORE the write: a failed spill degrades exactly
            // like a full disk — the block stays resident over budget
            // and mining proceeds, never losing data to a half-write.
            if faults.should_fail(FaultSite::SpillWrite) {
                log::warn!("spill of block {id} to {}: injected spill_write fault", path.display());
                break;
            }
            match std::fs::write(&path, bytes.as_slice()) {
                Ok(()) => {
                    let len = entry.len;
                    entry.slot = Slot::Spilled(path);
                    inner.mem_bytes -= len;
                    self.spilled_blocks.fetch_add(1, Ordering::Relaxed);
                    self.spilled_bytes.fetch_add(len as u64, Ordering::Relaxed);
                    fired.push((id, len, false));
                }
                Err(e) => {
                    log::warn!("spill of block {id} to {} failed: {e}", path.display());
                    break;
                }
            }
        }
    }
}

fn ensure_spill_dir(inner: &mut Inner, seq: u64) -> Option<PathBuf> {
    if inner.spill_dir.is_none() {
        let dir = std::env::temp_dir().join(format!(
            "sparklet-spill-{}-{}",
            std::process::id(),
            seq
        ));
        if let Err(e) = std::fs::create_dir_all(&dir) {
            log::warn!("cannot create spill dir {}: {e}", dir.display());
            return None;
        }
        inner.spill_dir = Some(dir);
    }
    inner.spill_dir.clone()
}

impl Drop for BlockStore {
    fn drop(&mut self) {
        let inner = match self.inner.get_mut() {
            Ok(i) => i,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(dir) = inner.spill_dir.take() {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(s: usize, r: usize, m: usize) -> BlockId {
        BlockId {
            shuffle_id: s,
            reduce_part: r,
            map_part: m,
        }
    }

    fn payload(tag: u8, len: usize) -> Vec<u8> {
        vec![tag; len]
    }

    #[test]
    fn unlimited_store_never_spills() {
        let store = BlockStore::new(None);
        for i in 0..10 {
            store.put(id(0, i, 0), payload(i as u8, 1000), 1);
        }
        assert_eq!(store.spilled_blocks(), 0);
        assert_eq!(store.mem_bytes(), 10_000);
        let b = store.get(&id(0, 3, 0)).unwrap().unwrap();
        assert_eq!(b.bytes.as_slice(), payload(3, 1000).as_slice());
        assert_eq!(b.records, 1);
        assert!(store.get(&id(9, 9, 9)).unwrap().is_none());
    }

    #[test]
    fn budget_spills_lru_and_reloads_transparently() {
        let store = BlockStore::new(Some(2500));
        store.put(id(0, 0, 0), payload(0, 1000), 10);
        store.put(id(0, 1, 0), payload(1, 1000), 11);
        // touch block 0 so block 1 is the LRU victim
        let _ = store.get(&id(0, 0, 0)).unwrap().unwrap();
        store.put(id(0, 2, 0), payload(2, 1000), 12);
        assert_eq!(store.spilled_blocks(), 1, "one block over budget");
        assert!(store.mem_bytes() <= 2500);
        // the spilled block reloads byte-identically
        let b = store.get(&id(0, 1, 0)).unwrap().unwrap();
        assert_eq!(b.bytes.as_slice(), payload(1, 1000).as_slice());
        assert_eq!(b.records, 11);
        assert_eq!(store.reloaded_blocks(), 1);
        // reload re-admitted it, which must keep the budget enforced
        assert!(store.mem_bytes() <= 2500, "{}", store.mem_bytes());
        assert_eq!(store.spilled_bytes() % 1000, 0);
    }

    #[test]
    fn block_larger_than_budget_still_roundtrips() {
        let store = BlockStore::new(Some(100));
        store.put(id(1, 0, 0), payload(7, 5000), 3);
        // the oversized block cannot stay resident
        assert!(store.mem_bytes() <= 100);
        assert!(store.spilled_blocks() >= 1);
        let b = store.get(&id(1, 0, 0)).unwrap().unwrap();
        assert_eq!(b.len(), 5000);
        assert!(b.bytes.iter().all(|&x| x == 7));
    }

    #[test]
    fn overwrite_replaces_and_adjusts_accounting() {
        let store = BlockStore::new(None);
        store.put(id(0, 0, 0), payload(1, 100), 1);
        store.put(id(0, 0, 0), payload(2, 300), 2);
        assert_eq!(store.mem_bytes(), 300);
        let b = store.get(&id(0, 0, 0)).unwrap().unwrap();
        assert_eq!(b.records, 2);
        assert_eq!(b.len(), 300);
    }

    #[test]
    fn spill_hook_sees_spills_and_reloads() {
        let store = BlockStore::new(Some(1500));
        let seen: Arc<Mutex<Vec<(BlockId, usize, bool)>>> = Arc::default();
        let sink = Arc::clone(&seen);
        store.set_spill_hook(Arc::new(move |id, bytes, reload| {
            sink.lock().unwrap().push((id, bytes, reload));
        }));
        store.put(id(0, 0, 0), payload(0, 1000), 1);
        store.put(id(0, 1, 0), payload(1, 1000), 1); // evicts block 0
        let spills: Vec<_> = seen.lock().unwrap().clone();
        assert_eq!(spills, vec![(id(0, 0, 0), 1000, false)]);
        let _ = store.get(&id(0, 0, 0)).unwrap().unwrap(); // reload (+ evict other)
        let all = seen.lock().unwrap().clone();
        assert!(all.contains(&(id(0, 0, 0), 1000, true)), "{all:?}");
        assert!(all.contains(&(id(0, 1, 0), 1000, false)), "{all:?}");
    }

    #[test]
    fn external_charges_share_the_budget_and_spill_blocks() {
        let store = BlockStore::new(Some(2000));
        store.put(id(0, 0, 0), payload(1, 800), 1);
        store.put(id(0, 1, 0), payload(2, 800), 1);
        assert_eq!(store.spilled_blocks(), 0, "1600 B fits a 2000 B budget");
        assert_eq!(store.used_bytes(), 1600);

        // An external tenant claims 1000 B: combined usage 2600 B blows
        // the budget, so the coldest block must spill even though no
        // block was written.
        store.charge_external(1000);
        assert_eq!(store.external_bytes(), 1000);
        assert!(store.spilled_blocks() >= 1, "external pressure spills");
        assert!(store.mem_bytes() + store.external_bytes() <= 2000);
        assert!(store.spill_file_count() >= 1);

        // Releasing makes headroom again; spilled blocks still reload.
        store.release_external(1000);
        assert_eq!(store.external_bytes(), 0);
        let b = store.get(&id(0, 0, 0)).unwrap().unwrap();
        assert_eq!(b.bytes.as_slice(), payload(1, 800).as_slice());

        // Over-release clamps instead of wrapping.
        store.release_external(usize::MAX);
        assert_eq!(store.external_bytes(), 0);
        assert_eq!(store.used_bytes(), store.mem_bytes());
    }

    #[test]
    fn spill_file_count_returns_to_zero_after_clear() {
        let store = BlockStore::new(Some(1));
        assert_eq!(store.spill_file_count(), 0, "nothing spilled yet");
        store.put(id(0, 0, 0), payload(3, 400), 1);
        store.put(id(0, 1, 0), payload(4, 400), 1);
        assert_eq!(store.spill_file_count(), 2);
        store.clear();
        assert_eq!(store.spill_file_count(), 0, "clear deletes spill files");
    }

    #[test]
    fn injected_spill_read_fault_is_typed_and_recoverable() {
        use crate::sparklet::faults::{FaultPlan, FaultPlane};
        let store = BlockStore::new(Some(1));
        store.set_fault_plane(Arc::new(FaultPlane::new(
            FaultPlan::parse("spill_read:nth=1").unwrap(),
        )));
        store.put(id(0, 0, 0), payload(9, 400), 5);
        assert_eq!(store.spilled_blocks(), 1, "budget of 1 byte spills");
        // First read hits the injected fault, typed.
        let err = store.get(&id(0, 0, 0)).unwrap_err();
        assert!(matches!(err, BlockIoError::Read { .. }), "{err}");
        assert!(err.to_string().contains("injected"), "{err}");
        // The entry and its spill file survived: the retry succeeds.
        let b = store.get(&id(0, 0, 0)).unwrap().unwrap();
        assert_eq!(b.bytes.as_slice(), payload(9, 400).as_slice());
        assert_eq!(b.records, 5);
    }

    #[test]
    fn injected_spill_write_fault_keeps_the_block_resident() {
        use crate::sparklet::faults::{FaultPlan, FaultPlane};
        let store = BlockStore::new(Some(100));
        store.set_fault_plane(Arc::new(FaultPlane::new(
            FaultPlan::parse("spill_write:nth=1").unwrap(),
        )));
        store.put(id(0, 0, 0), payload(1, 500), 1);
        // The spill failed, so the block stays in memory over budget —
        // degraded, never lost.
        assert_eq!(store.spilled_blocks(), 0);
        assert_eq!(store.mem_bytes(), 500);
        let b = store.get(&id(0, 0, 0)).unwrap().unwrap();
        assert_eq!(b.bytes.as_slice(), payload(1, 500).as_slice());
        // The next over-budget put spills normally (nth=1 fired once).
        store.put(id(0, 1, 0), payload(2, 500), 1);
        assert!(store.spilled_blocks() >= 1);
    }

    #[test]
    fn real_disk_loss_surfaces_as_typed_read_error() {
        let store = BlockStore::new(Some(1));
        store.put(id(0, 0, 0), payload(3, 300), 1);
        assert_eq!(store.spill_file_count(), 1);
        // Delete the spill file behind the store's back.
        let dir = store.inner.lock().unwrap().spill_dir.clone().unwrap();
        for f in std::fs::read_dir(&dir).unwrap() {
            std::fs::remove_file(f.unwrap().path()).unwrap();
        }
        let err = store.get(&id(0, 0, 0)).unwrap_err();
        assert!(matches!(err, BlockIoError::Read { .. }), "{err}");
        assert!(err.to_string().contains("block shuffle0/reduce0/map0"), "{err}");
    }

    #[test]
    fn truncated_spill_file_surfaces_as_length_drift() {
        let store = BlockStore::new(Some(1));
        store.put(id(0, 0, 0), payload(3, 300), 1);
        let dir = store.inner.lock().unwrap().spill_dir.clone().unwrap();
        for f in std::fs::read_dir(&dir).unwrap() {
            std::fs::write(f.unwrap().path(), b"short").unwrap();
        }
        let err = store.get(&id(0, 0, 0)).unwrap_err();
        assert!(
            matches!(err, BlockIoError::LengthDrift { expected: 300, got: 5, .. }),
            "{err}"
        );
    }

    #[test]
    fn remove_where_scopes_and_deletes_spill_files() {
        let store = BlockStore::new(Some(1));
        store.put(id(5, 0, 0), payload(1, 500), 1);
        store.put(id(6, 0, 0), payload(2, 500), 1);
        assert_eq!(store.spilled_blocks(), 2, "budget of 1 byte spills all");
        store.remove_where(|b| b.shuffle_id == 5);
        assert!(store.get(&id(5, 0, 0)).unwrap().is_none());
        let b = store.get(&id(6, 0, 0)).unwrap().unwrap();
        assert_eq!(b.bytes.as_slice(), payload(2, 500).as_slice());
        store.clear();
        assert!(store.get(&id(6, 0, 0)).unwrap().is_none());
        assert_eq!(store.mem_bytes(), 0);
    }
}
