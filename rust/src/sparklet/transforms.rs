//! Narrow transformations: concrete RDD operator types.
//!
//! Each operator is a struct holding its parent(s) and closure, plus
//! `DepNode` + `RddBase` impls. All are narrow dependencies — they
//! compute a partition purely from parent partitions of the same index
//! (or a contiguous group, for `coalesce`).

use std::sync::Arc;

use super::context::SparkletContext;
use super::rdd::{materialize, Data, Dep, DepNode, Rdd, RddBase, TaskContext};
use super::serde::SerDe;
use crate::util::SplitMix64;

// ------------------------------------------------------------------ sources

/// `parallelize`: a pre-partitioned in-memory collection.
pub struct ParallelCollection<T: Data> {
    id: usize,
    ctx: SparkletContext,
    parts: Arc<Vec<Vec<T>>>,
}

impl<T: Data> ParallelCollection<T> {
    pub fn new(ctx: SparkletContext, data: Vec<T>, num_parts: usize) -> Self {
        let num_parts = num_parts.max(1);
        let n = data.len();
        let mut parts: Vec<Vec<T>> = (0..num_parts).map(|_| Vec::new()).collect();
        // Contiguous split (Spark's slice semantics): partition i gets
        // range [i*n/p, (i+1)*n/p).
        for (i, part) in parts.iter_mut().enumerate() {
            let lo = i * n / num_parts;
            let hi = (i + 1) * n / num_parts;
            part.extend_from_slice(&data[lo..hi]);
        }
        Self {
            id: ctx.new_rdd_id(),
            ctx,
            parts: Arc::new(parts),
        }
    }
}

impl<T: Data> DepNode for ParallelCollection<T> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        Vec::new()
    }
    fn node_label(&self) -> &'static str {
        "parallelize"
    }
}

impl<T: Data> RddBase<T> for ParallelCollection<T> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.ctx.clone()
    }
    fn num_partitions(&self) -> usize {
        self.parts.len()
    }
    fn compute(&self, part: usize, _ctx: &TaskContext) -> Vec<T> {
        self.parts[part].clone()
    }
}

// --------------------------------------------------------------------- map

pub struct MapRdd<T: Data, U: Data> {
    id: usize,
    parent: Arc<dyn RddBase<T>>,
    f: Arc<dyn Fn(T) -> U + Send + Sync>,
}

impl<T: Data, U: Data> DepNode for MapRdd<T, U> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        vec![Dep::Narrow(Arc::clone(&self.parent) as Arc<dyn DepNode>)]
    }
    fn node_label(&self) -> &'static str {
        "map"
    }
}

impl<T: Data, U: Data> RddBase<U> for MapRdd<T, U> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.parent.context()
    }
    fn num_partitions(&self) -> usize {
        self.parent.num_partitions()
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<U> {
        materialize(&self.parent, part, ctx)
            .into_iter()
            .map(|x| (self.f)(x))
            .collect()
    }
}

pub fn map<T: Data, U: Data>(
    rdd: &Rdd<T>,
    f: impl Fn(T) -> U + Send + Sync + 'static,
) -> Rdd<U> {
    Rdd::from_base(Arc::new(MapRdd {
        id: rdd.context().new_rdd_id(),
        parent: Arc::clone(&rdd.base),
        f: Arc::new(f),
    }))
}

// ----------------------------------------------------------------- flat_map

pub struct FlatMapRdd<T: Data, U: Data> {
    id: usize,
    parent: Arc<dyn RddBase<T>>,
    f: Arc<dyn Fn(T) -> Vec<U> + Send + Sync>,
}

impl<T: Data, U: Data> DepNode for FlatMapRdd<T, U> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        vec![Dep::Narrow(Arc::clone(&self.parent) as Arc<dyn DepNode>)]
    }
    fn node_label(&self) -> &'static str {
        "flatMap"
    }
}

impl<T: Data, U: Data> RddBase<U> for FlatMapRdd<T, U> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.parent.context()
    }
    fn num_partitions(&self) -> usize {
        self.parent.num_partitions()
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<U> {
        materialize(&self.parent, part, ctx)
            .into_iter()
            .flat_map(|x| (self.f)(x))
            .collect()
    }
}

pub fn flat_map<T: Data, U: Data, I: IntoIterator<Item = U>>(
    rdd: &Rdd<T>,
    f: impl Fn(T) -> I + Send + Sync + 'static,
) -> Rdd<U> {
    Rdd::from_base(Arc::new(FlatMapRdd {
        id: rdd.context().new_rdd_id(),
        parent: Arc::clone(&rdd.base),
        f: Arc::new(move |x| f(x).into_iter().collect()),
    }))
}

// ------------------------------------------------------------------- filter

pub struct FilterRdd<T: Data> {
    id: usize,
    parent: Arc<dyn RddBase<T>>,
    f: Arc<dyn Fn(&T) -> bool + Send + Sync>,
}

impl<T: Data> DepNode for FilterRdd<T> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        vec![Dep::Narrow(Arc::clone(&self.parent) as Arc<dyn DepNode>)]
    }
    fn node_label(&self) -> &'static str {
        "filter"
    }
}

impl<T: Data> RddBase<T> for FilterRdd<T> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.parent.context()
    }
    fn num_partitions(&self) -> usize {
        self.parent.num_partitions()
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<T> {
        materialize(&self.parent, part, ctx)
            .into_iter()
            .filter(|x| (self.f)(x))
            .collect()
    }
}

pub fn filter<T: Data>(
    rdd: &Rdd<T>,
    f: impl Fn(&T) -> bool + Send + Sync + 'static,
) -> Rdd<T> {
    Rdd::from_base(Arc::new(FilterRdd {
        id: rdd.context().new_rdd_id(),
        parent: Arc::clone(&rdd.base),
        f: Arc::new(f),
    }))
}

// ----------------------------------------------------------- map_partitions

pub struct MapPartitionsRdd<T: Data, U: Data> {
    id: usize,
    parent: Arc<dyn RddBase<T>>,
    f: Arc<dyn Fn(usize, Vec<T>) -> Vec<U> + Send + Sync>,
}

impl<T: Data, U: Data> DepNode for MapPartitionsRdd<T, U> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        vec![Dep::Narrow(Arc::clone(&self.parent) as Arc<dyn DepNode>)]
    }
    fn node_label(&self) -> &'static str {
        "mapPartitions"
    }
}

impl<T: Data, U: Data> RddBase<U> for MapPartitionsRdd<T, U> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.parent.context()
    }
    fn num_partitions(&self) -> usize {
        self.parent.num_partitions()
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<U> {
        (self.f)(part, materialize(&self.parent, part, ctx))
    }
}

pub fn map_partitions<T: Data, U: Data>(
    rdd: &Rdd<T>,
    f: impl Fn(usize, Vec<T>) -> Vec<U> + Send + Sync + 'static,
) -> Rdd<U> {
    Rdd::from_base(Arc::new(MapPartitionsRdd {
        id: rdd.context().new_rdd_id(),
        parent: Arc::clone(&rdd.base),
        f: Arc::new(f),
    }))
}

// -------------------------------------------------------------------- union

pub struct UnionRdd<T: Data> {
    id: usize,
    parents: Vec<Arc<dyn RddBase<T>>>,
}

impl<T: Data> DepNode for UnionRdd<T> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        self.parents
            .iter()
            .map(|p| Dep::Narrow(Arc::clone(p) as Arc<dyn DepNode>))
            .collect()
    }
    fn node_label(&self) -> &'static str {
        "union"
    }
}

impl<T: Data> RddBase<T> for UnionRdd<T> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.parents[0].context()
    }
    fn num_partitions(&self) -> usize {
        self.parents.iter().map(|p| p.num_partitions()).sum()
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<T> {
        let mut offset = part;
        for p in &self.parents {
            if offset < p.num_partitions() {
                return materialize(p, offset, ctx);
            }
            offset -= p.num_partitions();
        }
        panic!("union partition {part} out of range");
    }
}

pub fn union<T: Data>(a: &Rdd<T>, b: &Rdd<T>) -> Rdd<T> {
    Rdd::from_base(Arc::new(UnionRdd {
        id: a.context().new_rdd_id(),
        parents: vec![Arc::clone(&a.base), Arc::clone(&b.base)],
    }))
}

// ------------------------------------------------------------------ coalesce

/// Narrow coalesce: child partition i reads a contiguous group of parent
/// partitions, preserving order — which is what EclatV2's
/// `coalesce(1)` relies on for stable transaction-id assignment.
pub struct CoalesceRdd<T: Data> {
    id: usize,
    parent: Arc<dyn RddBase<T>>,
    num_parts: usize,
}

impl<T: Data> CoalesceRdd<T> {
    fn group(&self, part: usize) -> std::ops::Range<usize> {
        let np = self.parent.num_partitions();
        let lo = part * np / self.num_parts;
        let hi = (part + 1) * np / self.num_parts;
        lo..hi
    }
}

impl<T: Data> DepNode for CoalesceRdd<T> {
    fn node_id(&self) -> usize {
        self.id
    }
    fn node_deps(&self) -> Vec<Dep> {
        vec![Dep::Narrow(Arc::clone(&self.parent) as Arc<dyn DepNode>)]
    }
    fn node_label(&self) -> &'static str {
        "coalesce"
    }
}

impl<T: Data> RddBase<T> for CoalesceRdd<T> {
    fn id(&self) -> usize {
        self.id
    }
    fn context(&self) -> SparkletContext {
        self.parent.context()
    }
    fn num_partitions(&self) -> usize {
        self.num_parts
    }
    fn compute(&self, part: usize, ctx: &TaskContext) -> Vec<T> {
        let mut out = Vec::new();
        for p in self.group(part) {
            out.extend(materialize(&self.parent, p, ctx));
        }
        out
    }
}

pub fn coalesce<T: Data>(rdd: &Rdd<T>, n: usize) -> Rdd<T> {
    let n = n.max(1).min(rdd.num_partitions().max(1));
    Rdd::from_base(Arc::new(CoalesceRdd {
        id: rdd.context().new_rdd_id(),
        parent: Arc::clone(&rdd.base),
        num_parts: n,
    }))
}

/// Round-robin repartition (wide): tag with a rotating key, hash-shuffle,
/// strip the tag.
pub fn repartition<T: Data + std::hash::Hash + Eq + SerDe>(rdd: &Rdd<T>, n: usize) -> Rdd<T> {
    use super::pair::PairRdd;
    let n = n.max(1);
    let tagged = rdd.map_partitions(move |part, items| {
        items
            .into_iter()
            .enumerate()
            .map(|(i, x)| ((part + i) % n, x))
            .collect::<Vec<(usize, T)>>()
    });
    tagged
        .partition_by(Arc::new(super::partitioner::FnPartitioner::new(
            n,
            move |k: &usize| *k % n,
        )))
        .values()
}

// ------------------------------------------------------------------- sample

pub fn sample<T: Data>(rdd: &Rdd<T>, fraction: f64, seed: u64) -> Rdd<T> {
    rdd.map_partitions(move |part, items| {
        let mut rng = SplitMix64::new(seed ^ (part as u64).wrapping_mul(0x9E3779B97F4A7C15));
        items
            .into_iter()
            .filter(|_| rng.gen_bool(fraction))
            .collect()
    })
}
