//! Packed bitmap over u32 words — the tidset representation that feeds
//! both the native SIMD-friendly intersection loop and the XLA artifact
//! (whose operands are `s32[rows, words]` with identical bit layout:
//! tid `t` lives at bit `t % 32` of word `t / 32`).

/// A fixed-capacity bitmap of transaction ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitmap {
    words: Vec<u32>,
    /// Number of addressable bits (tids); words.len() == ceil(nbits/32).
    nbits: usize,
}

impl Bitmap {
    pub fn new(nbits: usize) -> Self {
        Self {
            words: vec![0; nbits.div_ceil(32)],
            nbits,
        }
    }

    /// Build from a sorted tid list. Fills word-by-word — the bits of
    /// one 32-tid word accumulate in a register and are stored once —
    /// instead of paying the div/mod + read-modify-write of [`set`]
    /// per tid. (`|=` on word changes keeps unsorted input correct
    /// too; sorted input touches each word exactly once.)
    ///
    /// [`set`]: Self::set
    pub fn from_sorted_tids(tids: &[u32], nbits: usize) -> Self {
        debug_assert!(tids.iter().all(|&t| (t as usize) < nbits));
        let mut words = vec![0u32; nbits.div_ceil(32)];
        let mut wi = 0usize;
        let mut acc = 0u32;
        for &t in tids {
            let w = t as usize / 32;
            if w != wi {
                words[wi] |= acc;
                wi = w;
                acc = 0;
            }
            acc |= 1u32 << (t % 32);
        }
        if acc != 0 {
            words[wi] |= acc;
        }
        Self { words, nbits }
    }

    /// Rebuild from raw parts (the shuffle SerDe decode path). `None`
    /// when the word count does not match `nbits` — corrupt input.
    pub fn try_from_raw(words: Vec<u32>, nbits: usize) -> Option<Self> {
        (words.len() == nbits.div_ceil(32)).then_some(Self { words, nbits })
    }

    #[inline]
    pub fn nbits(&self) -> usize {
        self.nbits
    }

    #[inline]
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.nbits, "bit {i} out of range {}", self.nbits);
        self.words[i / 32] |= 1u32 << (i % 32);
    }

    #[inline]
    pub fn clear(&mut self, i: usize) {
        self.words[i / 32] &= !(1u32 << (i % 32));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        (self.words[i / 32] >> (i % 32)) & 1 == 1
    }

    /// Number of set bits (the tidset's support).
    #[inline]
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// `self & other` into a fresh bitmap. The FIM hot path uses
    /// [`and_into`](Self::and_into) to avoid the allocation.
    pub fn and(&self, other: &Self) -> Self {
        let mut out = self.clone();
        out.and_assign(other);
        out
    }

    /// `self &= other`.
    pub fn and_assign(&mut self, other: &Self) {
        debug_assert_eq!(self.words.len(), other.words.len());
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= *b;
        }
    }

    /// Intersect into a caller-provided buffer, returning the popcount.
    /// This is the native hot path: one pass, no allocation.
    #[inline]
    pub fn and_into(&self, other: &Self, out: &mut Self) -> usize {
        debug_assert_eq!(self.words.len(), other.words.len());
        debug_assert_eq!(self.words.len(), out.words.len());
        let mut count = 0usize;
        for ((o, &a), &b) in out.words.iter_mut().zip(&self.words).zip(&other.words) {
            let w = a & b;
            *o = w;
            count += w.count_ones() as usize;
        }
        out.nbits = self.nbits;
        count
    }

    /// `self & other` into `out` (resized to match) with popcount,
    /// aborting — returning `None` — as soon as the remaining words,
    /// even all-ones, cannot lift the count to `need`. `Some(count)`
    /// means the AND *completed*; the count may still fall short of
    /// `need` (callers decide). The bound is probed every 8 words so
    /// the hot loop stays branch-light. On `None`, `out` holds a
    /// partial result but its storage stays reusable.
    pub fn and_into_min(&self, other: &Self, need: usize, out: &mut Self) -> Option<usize> {
        debug_assert_eq!(self.words.len(), other.words.len());
        let n = self.words.len().min(other.words.len());
        out.nbits = self.nbits;
        out.words.clear();
        out.words.reserve(n);
        let mut count = 0usize;
        for (i, (&a, &b)) in self.words.iter().zip(&other.words).enumerate() {
            let w = a & b;
            count += w.count_ones() as usize;
            out.words.push(w);
            if i & 7 == 7 && count + (n - i - 1) * 32 < need {
                return None;
            }
        }
        Some(count)
    }

    /// Popcount of the intersection without materializing it — used when
    /// only the support survives the min_sup test.
    #[inline]
    pub fn and_count(&self, other: &Self) -> usize {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(&a, &b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// Iterate set bit indices in ascending order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 32 + b)
                }
            })
        })
    }

    pub fn to_tids(&self) -> Vec<u32> {
        self.iter_ones().map(|i| i as u32).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// View the words as i32 (bit-identical) for the XLA operand path.
    pub fn words_i32(&self) -> Vec<i32> {
        self.words.iter().map(|&w| w as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_clear() {
        let mut b = Bitmap::new(100);
        assert!(!b.get(37));
        b.set(37);
        assert!(b.get(37));
        b.clear(37);
        assert!(!b.get(37));
    }

    #[test]
    fn count_and_iter() {
        let mut b = Bitmap::new(200);
        let tids = [0usize, 31, 32, 63, 64, 128, 199];
        for &t in &tids {
            b.set(t);
        }
        assert_eq!(b.count(), tids.len());
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, tids);
    }

    #[test]
    fn intersection_matches_sets() {
        use std::collections::BTreeSet;
        let mut rng = crate::util::SplitMix64::new(77);
        for _ in 0..50 {
            let n = 500;
            let a: BTreeSet<usize> = (0..n).filter(|_| rng.gen_bool(0.2)).collect();
            let b: BTreeSet<usize> = (0..n).filter(|_| rng.gen_bool(0.2)).collect();
            let ba = {
                let mut x = Bitmap::new(n);
                a.iter().for_each(|&i| x.set(i));
                x
            };
            let bb = {
                let mut x = Bitmap::new(n);
                b.iter().for_each(|&i| x.set(i));
                x
            };
            let want: Vec<usize> = a.intersection(&b).copied().collect();
            let inter = ba.and(&bb);
            assert_eq!(inter.iter_ones().collect::<Vec<_>>(), want);
            assert_eq!(inter.count(), want.len());
            assert_eq!(ba.and_count(&bb), want.len());
            let mut buf = Bitmap::new(n);
            assert_eq!(ba.and_into(&bb, &mut buf), want.len());
            assert_eq!(buf, inter);
        }
    }

    #[test]
    fn from_sorted_tids_roundtrip() {
        let tids = vec![1u32, 5, 31, 32, 99];
        let b = Bitmap::from_sorted_tids(&tids, 128);
        assert_eq!(b.to_tids(), tids);
        // word-boundary edges: first/last bit of a word, last bit overall
        let edges = vec![0u32, 31, 32, 63, 64, 95, 127];
        let be = Bitmap::from_sorted_tids(&edges, 128);
        assert_eq!(be.to_tids(), edges);
        // matches the set()-built bitmap exactly
        let mut by_set = Bitmap::new(128);
        edges.iter().for_each(|&t| by_set.set(t as usize));
        assert_eq!(be, by_set);
        // empty input
        assert!(Bitmap::from_sorted_tids(&[], 77).is_empty());
    }

    #[test]
    fn and_into_min_bound_and_completion() {
        let n = 1024; // 32 words: enough for the every-8-words probe
        let mut rng = crate::util::SplitMix64::new(0xAB);
        let a_tids: Vec<u32> = (0..n as u32).filter(|_| rng.gen_bool(0.1)).collect();
        let b_tids: Vec<u32> = (0..n as u32).filter(|_| rng.gen_bool(0.1)).collect();
        let a = Bitmap::from_sorted_tids(&a_tids, n);
        let b = Bitmap::from_sorted_tids(&b_tids, n);
        let want = a.and_count(&b);
        let mut out = Bitmap::new(0);
        // generous need: completes with the exact count and bitmap
        assert_eq!(a.and_into_min(&b, want, &mut out), Some(want));
        assert_eq!(out, a.and(&b));
        // impossible need on sparse maps: the remaining-popcount bound
        // fires at the first probe (word 7: count + 24*32 < 1000)
        assert_eq!(a.and_into_min(&b, 1000, &mut out), None);
        // small maps (< 8 words) never probe but still complete
        let s1 = Bitmap::from_sorted_tids(&[1, 2, 3], 64);
        let s2 = Bitmap::from_sorted_tids(&[2, 3, 4], 64);
        let mut sout = Bitmap::new(0);
        assert_eq!(s1.and_into_min(&s2, 60, &mut sout), Some(2));
    }

    #[test]
    fn words_i32_bit_identical() {
        let mut b = Bitmap::new(32);
        b.set(31);
        assert_eq!(b.words()[0], 0x8000_0000);
        assert_eq!(b.words_i32()[0], i32::MIN);
    }

    #[test]
    fn empty_and_full() {
        let b = Bitmap::new(64);
        assert!(b.is_empty());
        assert_eq!(b.count(), 0);
        let mut f = Bitmap::new(64);
        (0..64).for_each(|i| f.set(i));
        assert_eq!(f.count(), 64);
        assert!(!f.is_empty());
    }
}
