//! Subsuming result cache for the serve mode.
//!
//! Keyed `(dataset, min_sup_abs)` and storing the **full**
//! un-post-processed itemsets, the cache answers two kinds of queries:
//!
//! * **exact** — the same dataset was mined at the same threshold;
//! * **subsumed** — the dataset was mined at some threshold `s <=` the
//!   query's `s'`. By anti-monotonicity the cached result filtered to
//!   `support >= s'` *is* the exact result at `s'`
//!   ([`MiningResult::filter_min_sup`]), at interactive latency instead
//!   of a re-mine. When several cached thresholds qualify, the largest
//!   wins (fewest itemsets to filter).
//!
//! The key is engine-agnostic on purpose: every engine produces the same
//! itemset set (the cross-engine agreement suite guarantees it), so a
//! result mined by `eclat-v4` answers an `apriori` query.
//!
//! Entry bytes are charged as *external* usage against the shuffle
//! [`BlockStore`](crate::sparklet::BlockStore) accounting
//! (`charge_external`), so admission control and shuffle spill both see
//! cache pressure; eviction is LRU against the cache's own byte budget.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::fim::types::MiningResult;
use crate::sparklet::shuffle::ShuffleManager;

/// How a lookup was answered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheHit {
    Exact,
    Subsumed,
    Miss,
}

impl CacheHit {
    /// The label that rides on `RequestCompleted` events and responses.
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Subsumed => "subsumed",
            Self::Miss => "miss",
        }
    }
}

struct CacheEntry {
    result: MiningResult,
    n_transactions: u64,
    bytes: usize,
    last_use: u64,
}

struct CacheInner {
    /// dataset -> (min_sup_abs -> entry); the ordered inner map makes
    /// the "largest cached threshold <= query" subsumption scan a
    /// `range(..=s').next_back()`.
    entries: HashMap<String, BTreeMap<u32, CacheEntry>>,
    clock: u64,
    bytes: usize,
}

/// LRU result cache with byte-budget eviction and external-charge
/// accounting through the shuffle's `BlockStore`.
pub struct ResultCache {
    /// Byte budget (`usize::MAX` = unlimited).
    budget: usize,
    shuffle: Arc<ShuffleManager>,
    inner: Mutex<CacheInner>,
}

/// Approximate heap bytes of a cached result: items plus per-itemset and
/// per-entry bookkeeping. An estimate is fine — eviction needs relative
/// sizes, and the admission check only needs the right order of
/// magnitude.
fn result_bytes(result: &MiningResult) -> usize {
    64 + result
        .itemsets
        .iter()
        .map(|f| f.items.len() * 4 + 32)
        .sum::<usize>()
}

impl ResultCache {
    /// `budget: None` = unlimited. `shuffle` receives the external byte
    /// charges.
    pub fn new(budget: Option<usize>, shuffle: Arc<ShuffleManager>) -> Self {
        Self {
            budget: budget.unwrap_or(usize::MAX),
            shuffle,
            inner: Mutex::new(CacheInner {
                entries: HashMap::new(),
                clock: 0,
                bytes: 0,
            }),
        }
    }

    /// Answer a query at `min_sup` from cache if possible. The returned
    /// result is already filtered to the query's threshold (identity for
    /// exact hits); post-stages are the caller's business.
    pub fn lookup(&self, dataset: &str, min_sup: u32) -> Option<(MiningResult, u64, CacheHit)> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        let by_sup = inner.entries.get_mut(dataset)?;
        // Any cached threshold <= the query's subsumes it; the largest
        // such is the cheapest to filter (and exact when equal).
        let (&cached_sup, entry) = by_sup.range_mut(..=min_sup).next_back()?;
        entry.last_use = clock;
        let n = entry.n_transactions;
        if cached_sup == min_sup {
            Some((entry.result.clone(), n, CacheHit::Exact))
        } else {
            Some((entry.result.filter_min_sup(min_sup), n, CacheHit::Subsumed))
        }
    }

    /// Insert a freshly mined **full** result (no post-stages applied),
    /// then LRU-evict until the cache fits its budget. Overwrites any
    /// entry at the same key.
    pub fn insert(
        &self,
        dataset: &str,
        min_sup: u32,
        result: MiningResult,
        n_transactions: u64,
    ) {
        let bytes = result_bytes(&result);
        let mut freed = 0usize;
        {
            let mut inner = self.inner.lock().unwrap();
            inner.clock += 1;
            let clock = inner.clock;
            let entry = CacheEntry {
                result,
                n_transactions,
                bytes,
                last_use: clock,
            };
            if let Some(old) = inner
                .entries
                .entry(dataset.to_string())
                .or_default()
                .insert(min_sup, entry)
            {
                inner.bytes -= old.bytes;
                freed += old.bytes;
            }
            inner.bytes += bytes;
            // LRU eviction down to the budget. The just-inserted entry
            // has the newest clock, so it only evicts itself when it
            // alone exceeds the budget — in which case caching it would
            // be a lie anyway.
            while inner.bytes > self.budget {
                let victim = inner
                    .entries
                    .iter()
                    .flat_map(|(ds, by_sup)| {
                        by_sup.iter().map(move |(&s, e)| (e.last_use, ds.clone(), s))
                    })
                    .min()
                    .map(|(_, ds, s)| (ds, s));
                let Some((ds, s)) = victim else { break };
                let by_sup = inner.entries.get_mut(&ds).expect("victim dataset exists");
                if let Some(old) = by_sup.remove(&s) {
                    inner.bytes -= old.bytes;
                    freed += old.bytes;
                }
                if inner
                    .entries
                    .get(&ds)
                    .is_some_and(|by_sup| by_sup.is_empty())
                {
                    inner.entries.remove(&ds);
                }
            }
        }
        // Charge/release outside the cache lock; the store takes its own.
        self.shuffle.charge_external(bytes);
        if freed > 0 {
            self.shuffle.release_external(freed);
        }
    }

    /// Cached entries across all datasets.
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.entries.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently cached (the amount charged externally).
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }
}

impl Drop for ResultCache {
    fn drop(&mut self) {
        let inner = match self.inner.get_mut() {
            Ok(i) => i,
            Err(poisoned) => poisoned.into_inner(),
        };
        if inner.bytes > 0 {
            self.shuffle.release_external(inner.bytes);
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::fim::types::FrequentItemset;

    use super::*;

    fn result(supports: &[u32]) -> MiningResult {
        MiningResult::new(
            supports
                .iter()
                .enumerate()
                .map(|(i, &s)| FrequentItemset::new(vec![i as u32], s))
                .collect(),
        )
    }

    fn unlimited_cache() -> ResultCache {
        ResultCache::new(None, Arc::new(ShuffleManager::new()))
    }

    #[test]
    fn exact_and_subsumed_lookups() {
        let cache = unlimited_cache();
        assert!(cache.lookup("t10", 5).is_none(), "cold cache misses");
        cache.insert("t10", 3, result(&[3, 4, 5, 9]), 100);

        let (got, n, hit) = cache.lookup("t10", 3).unwrap();
        assert_eq!(hit, CacheHit::Exact);
        assert_eq!(n, 100);
        assert_eq!(got.len(), 4, "exact hit returns the full result");

        let (got, _, hit) = cache.lookup("t10", 5).unwrap();
        assert_eq!(hit, CacheHit::Subsumed);
        assert!(got.same_as(&result(&[3, 4, 5, 9]).filter_min_sup(5)));
        assert_eq!(got.len(), 2);

        // A *lower* threshold is NOT subsumed — the cached mine at 3
        // knows nothing about itemsets with support 2.
        assert!(cache.lookup("t10", 2).is_none());
        // Other datasets don't cross-talk.
        assert!(cache.lookup("t40", 3).is_none());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn largest_qualifying_threshold_wins() {
        let cache = unlimited_cache();
        cache.insert("d", 2, result(&[2, 3, 4, 5, 6]), 10);
        cache.insert("d", 5, result(&[5, 6]), 10);
        // Query at 6: both entries subsume it; the s=5 one is picked and
        // filtered, giving the same answer with less work.
        let (got, _, hit) = cache.lookup("d", 6).unwrap();
        assert_eq!(hit, CacheHit::Subsumed);
        assert!(got.same_as(&result(&[2, 3, 4, 5, 6]).filter_min_sup(6)));
        // Query at 5 is exact on the second entry.
        let (_, _, hit) = cache.lookup("d", 5).unwrap();
        assert_eq!(hit, CacheHit::Exact);
        // Query at 3 only the s=2 entry subsumes.
        let (got, _, hit) = cache.lookup("d", 3).unwrap();
        assert_eq!(hit, CacheHit::Subsumed);
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn lru_eviction_respects_budget_and_external_accounting() {
        let shuffle = Arc::new(ShuffleManager::new());
        let one_entry = result_bytes(&result(&[1; 50]));
        // Budget fits two entries but not three.
        let cache = ResultCache::new(Some(2 * one_entry + 10), Arc::clone(&shuffle));
        cache.insert("a", 1, result(&[1; 50]), 10);
        cache.insert("b", 1, result(&[1; 50]), 10);
        assert_eq!(cache.len(), 2);
        assert_eq!(shuffle.used_bytes(), cache.bytes(), "charges track bytes");
        // Touch "a" so "b" is the LRU victim.
        let _ = cache.lookup("a", 1).unwrap();
        cache.insert("c", 1, result(&[1; 50]), 10);
        assert_eq!(cache.len(), 2, "third entry evicted one");
        assert!(cache.lookup("b", 1).is_none(), "the cold entry went");
        assert!(cache.lookup("a", 1).is_some());
        assert!(cache.lookup("c", 1).is_some());
        assert!(cache.bytes() <= 2 * one_entry + 10);
        assert_eq!(shuffle.used_bytes(), cache.bytes());
        // Overwriting a key releases the old entry's bytes.
        cache.insert("a", 1, result(&[2, 2]), 10);
        assert_eq!(shuffle.used_bytes(), cache.bytes());
        // Dropping the cache releases everything.
        drop(cache);
        assert_eq!(shuffle.used_bytes(), 0);
    }

    #[test]
    fn oversized_single_entry_does_not_wedge_the_cache() {
        let shuffle = Arc::new(ShuffleManager::new());
        let cache = ResultCache::new(Some(10), Arc::clone(&shuffle));
        cache.insert("big", 1, result(&[1; 100]), 10);
        // It evicted itself: nothing cached, nothing charged.
        assert!(cache.is_empty());
        assert_eq!(cache.bytes(), 0);
        assert_eq!(shuffle.used_bytes(), 0);
        // And the cache still works afterwards for entries that fit...
        // (none do under a 10-byte budget, so a miss is correct)
        assert!(cache.lookup("big", 1).is_none());
    }
}
