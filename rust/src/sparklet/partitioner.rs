//! Partitioners — how keys map to shuffle partitions.
//!
//! The paper's equivalence-class placement heuristics (EclatV4/V5) are
//! implemented as custom partitioners on top of this trait; the engine
//! itself ships the Spark built-ins (hash, range) plus a closure adapter.

use std::hash::Hash;
use std::sync::Arc;

use crate::util::hash::fx_hash;

/// Maps a key to a partition id in `[0, num_partitions)`.
pub trait Partitioner<K>: Send + Sync {
    fn num_partitions(&self) -> usize;
    fn partition(&self, key: &K) -> usize;
}

/// Spark's default: `hash(key) mod p`.
#[derive(Debug, Clone)]
pub struct HashPartitioner {
    partitions: usize,
}

impl HashPartitioner {
    pub fn new(partitions: usize) -> Self {
        assert!(partitions > 0, "partitioner needs >= 1 partition");
        Self { partitions }
    }
}

impl<K: Hash> Partitioner<K> for HashPartitioner {
    fn num_partitions(&self) -> usize {
        self.partitions
    }

    fn partition(&self, key: &K) -> usize {
        (fx_hash(key) % self.partitions as u64) as usize
    }
}

/// Range partitioner over ordered keys (used by `sort_by_key`). Bounds
/// are upper bounds of each partition except the last.
pub struct RangePartitioner<K: Ord> {
    bounds: Vec<K>,
}

impl<K: Ord + Clone> RangePartitioner<K> {
    /// Build from a sample of keys, aiming for `partitions` near-equal
    /// ranges.
    pub fn from_sample(mut sample: Vec<K>, partitions: usize) -> Self {
        assert!(partitions > 0);
        sample.sort();
        sample.dedup();
        let mut bounds = Vec::new();
        if !sample.is_empty() && partitions > 1 {
            for i in 1..partitions {
                let idx = i * sample.len() / partitions;
                if idx < sample.len() {
                    let b = sample[idx].clone();
                    if bounds.last() != Some(&b) {
                        bounds.push(b);
                    }
                }
            }
        }
        Self { bounds }
    }
}

impl<K: Ord + Send + Sync> Partitioner<K> for RangePartitioner<K> {
    fn num_partitions(&self) -> usize {
        self.bounds.len() + 1
    }

    fn partition(&self, key: &K) -> usize {
        self.bounds.partition_point(|b| b <= key)
    }
}

/// Closure-based partitioner — the adapter the FIM layer uses for the
/// paper's `defaultPartitioner`, `hashPartitioner`, and
/// `reverseHashPartitioner` heuristics.
pub struct FnPartitioner<K> {
    partitions: usize,
    f: Arc<dyn Fn(&K) -> usize + Send + Sync>,
}

impl<K> FnPartitioner<K> {
    pub fn new(partitions: usize, f: impl Fn(&K) -> usize + Send + Sync + 'static) -> Self {
        assert!(partitions > 0);
        Self {
            partitions,
            f: Arc::new(f),
        }
    }
}

impl<K: Send + Sync> Partitioner<K> for FnPartitioner<K> {
    fn num_partitions(&self) -> usize {
        self.partitions
    }

    fn partition(&self, key: &K) -> usize {
        // Clamp out-of-range ids rather than assert: the paper's custom
        // partitioners return raw ranks that the engine must keep in range.
        (self.f)(key).min(self.partitions - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_partitioner_in_range_and_deterministic() {
        let p = HashPartitioner::new(7);
        for k in 0..1000u32 {
            let a = p.partition(&k);
            assert!(a < 7);
            assert_eq!(a, p.partition(&k));
        }
    }

    #[test]
    fn hash_partitioner_spreads() {
        let p = HashPartitioner::new(8);
        let mut counts = [0usize; 8];
        for k in 0..8000u32 {
            counts[p.partition(&k)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 500), "skew: {counts:?}");
    }

    #[test]
    fn range_partitioner_orders() {
        let keys: Vec<u32> = (0..100).collect();
        let rp = RangePartitioner::from_sample(keys, 4);
        assert_eq!(Partitioner::<u32>::num_partitions(&rp), 4);
        let mut last = 0;
        for k in 0..100u32 {
            let p = rp.partition(&k);
            assert!(p >= last, "non-monotone at {k}");
            last = p;
        }
        assert_eq!(rp.partition(&0), 0);
        assert_eq!(rp.partition(&99), 3);
    }

    #[test]
    fn range_partitioner_single_partition() {
        let rp = RangePartitioner::from_sample(vec![5u32, 1, 9], 1);
        assert_eq!(Partitioner::<u32>::num_partitions(&rp), 1);
        assert_eq!(rp.partition(&123), 0);
    }

    #[test]
    fn fn_partitioner_clamps() {
        let p = FnPartitioner::new(3, |k: &u32| *k as usize);
        assert_eq!(p.partition(&0), 0);
        assert_eq!(p.partition(&2), 2);
        assert_eq!(p.partition(&99), 2); // clamped
    }
}
