//! Bench target: design-choice ablations called out in DESIGN.md —
//! A1: triangular matrix on/off (Phase-2 pruning value)
//! A2: equivalence-class partitioner (default / hash / reverse-hash) and
//!     the p sweep, plus the balance-ratio metric the paper's §4.4
//!     motivates
//! A3: tidset representation (sorted tid lists vs packed bitmaps)

use rdd_eclat::coordinator::ExperimentConfig;
use rdd_eclat::data::Dataset;
use rdd_eclat::fim::engine::{MiningSession, TidsetRepr};
use rdd_eclat::fim::partitioners::{
    balance_ratio, default_partitioner, hash_partitioner, reverse_hash_partitioner,
};
use rdd_eclat::fim::sequential::eclat_sequential_with;
use rdd_eclat::fim::tidset::{BitmapTidset, VecTidset};
use rdd_eclat::fim::types::abs_min_sup;
use rdd_eclat::sparklet::{Partitioner, SparkletContext};
use rdd_eclat::util::bench::BenchSuite;

fn main() {
    let cfg = ExperimentConfig::default();
    tri_matrix_ablation(&cfg);
    partitioner_ablation(&cfg);
    tidset_repr_ablation(&cfg);
    prefix_len_ablation(&cfg);
    rdd_eclat::coordinator::experiments::extended_comparison(&cfg).finish();
}

/// §6 future work: 1-length vs 2-length prefix classes, and the fused V6.
fn prefix_len_ablation(cfg: &ExperimentConfig) {
    let mut suite = BenchSuite::new(
        "ablation_prefix_len",
        "V5 with k=1 vs k=2 prefix classes vs V6-fused (LPT-balanced)",
    );
    let txns = Dataset::T10I4D100K.generate_scaled(cfg.seed, cfg.scale);
    for &frac in &[0.003f64, 0.002, 0.001] {
        let min_sup = abs_min_sup(frac, txns.len());
        for (label, engine, k) in [
            ("V5-k1", "eclat-v5", 1usize),
            ("V5-k2", "eclat-v5", 2),
            ("V6-fused", "eclat-v6", 2),
        ] {
            suite.measure(label, "min_sup", frac, || {
                let sc = SparkletContext::local(cfg.cores);
                let _ = MiningSession::new(engine)
                    .min_sup(min_sup)
                    .p(cfg.p)
                    .prefix_len(k)
                    .run_vec(&sc, &txns)
                    .unwrap();
            });
        }
    }
    suite.finish();
}

fn tri_matrix_ablation(cfg: &ExperimentConfig) {
    let mut suite = BenchSuite::new(
        "ablation_trimatrix",
        "EclatV1 on T10 with/without the triangular-matrix Phase-2",
    );
    let txns = Dataset::T10I4D100K.generate_scaled(cfg.seed, cfg.scale);
    for &frac in &[0.005f64, 0.003, 0.001] {
        let min_sup = abs_min_sup(frac, txns.len());
        for (label, mode) in [("triMatrix=on", true), ("triMatrix=off", false)] {
            suite.measure(label, "min_sup", frac, || {
                let sc = SparkletContext::local(cfg.cores);
                let _ = MiningSession::new("eclat-v1")
                    .min_sup(min_sup)
                    .tri_matrix(mode)
                    .run_vec(&sc, &txns)
                    .unwrap();
            });
        }
    }
    suite.finish();
}

fn partitioner_ablation(cfg: &ExperimentConfig) {
    // (a) wall-clock across partitioners at p=10 and a p sweep for V4/V5
    let mut suite = BenchSuite::new(
        "ablation_partitioner",
        "V3 (default) vs V4 (hash) vs V5 (reverse-hash) across p",
    );
    let txns = Dataset::T10I4D100K.generate_scaled(cfg.seed, cfg.scale);
    let min_sup = abs_min_sup(0.002, txns.len());
    for &p in &[2usize, 5, 10, 20] {
        for (label, engine) in [
            ("EclatV3", "eclat-v3"),
            ("EclatV4", "eclat-v4"),
            ("EclatV5", "eclat-v5"),
        ] {
            suite.measure(label, "p", p as f64, || {
                let sc = SparkletContext::local(cfg.cores);
                let _ = MiningSession::new(engine)
                    .min_sup(min_sup)
                    .p(p)
                    .run_vec(&sc, &txns)
                    .unwrap();
            });
        }
    }
    suite.finish();

    // (b) static balance-ratio of the three partitioners on the Eclat
    // class-weight shape (weights decay with rank)
    let n = 200usize;
    let weights: Vec<usize> = (0..n).map(|r| n - r).collect();
    println!("## partitioner balance ratio (max/mean summed class weights; 1.0 = perfect)");
    for p in [4usize, 10, 16] {
        let d = default_partitioner(n + 1);
        let h = hash_partitioner(p);
        let r = reverse_hash_partitioner(p);
        println!(
            "  p={p:<3} default(n-1)={:.3}  hash={:.3}  reverseHash={:.3}",
            balance_ratio(&weights, |rank| d.partition(&rank), n),
            balance_ratio(&weights, |rank| h.partition(&rank), p),
            balance_ratio(&weights, |rank| r.partition(&rank), p),
        );
    }
}

fn tidset_repr_ablation(cfg: &ExperimentConfig) {
    let mut suite = BenchSuite::new(
        "ablation_tidset_repr",
        "sequential Eclat: sorted tid lists vs packed bitmaps",
    );
    for (name, d) in [
        ("T10", Dataset::T10I4D100K),
        ("BMS2", Dataset::Bms2),
    ] {
        let txns = d.generate_scaled(cfg.seed, cfg.scale);
        let frac = if d.tri_matrix_mode() { 0.002 } else { 0.001 };
        let min_sup = abs_min_sup(frac, txns.len());
        suite.measure(&format!("{name}-veclist"), "dataset", 0.0, || {
            let _ = eclat_sequential_with::<VecTidset>(&txns, min_sup);
        });
        suite.measure(&format!("{name}-bitmap"), "dataset", 0.0, || {
            let _ = eclat_sequential_with::<BitmapTidset>(&txns, min_sup);
        });
        // The same axis through the distributed engine: Auto resolves
        // per run against the measured vertical-database density.
        suite.measure(&format!("{name}-rdd-auto"), "dataset", 0.0, || {
            let sc = SparkletContext::local(cfg.cores);
            let _ = MiningSession::new("eclat-v5")
                .min_sup(min_sup)
                .tidset(TidsetRepr::Auto)
                .tri_matrix(d.tri_matrix_mode())
                .run_vec(&sc, &txns)
                .unwrap();
        });
    }
    suite.finish();
}
