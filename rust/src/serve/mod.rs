//! Mining-as-a-service: a long-lived serve mode over one persistent
//! [`SparkletContext`](crate::sparklet::SparkletContext).
//!
//! The `sparklet serve` command binds a unix socket and multiplexes
//! concurrent mining requests — length-prefixed
//! [`transport`](crate::sparklet::transport) frames whose
//! `Request`/`Response` bodies speak the [`protocol`] vocabulary —
//! onto the registered executor backends, one [`MiningSession`]
//! (crate::fim::MiningSession) per admitted request. Three layers keep a
//! heavily-loaded server healthy:
//!
//! * [`admission`] — a bounded FIFO gate serializes mining against the
//!   shuffle memory budget (typed `Overloaded` rejections instead of
//!   unbounded queueing) and a per-tenant token bucket sheds tenants
//!   over their request rate (`Throttled`);
//! * [`cache`] — a subsuming result cache answers exact repeats and any
//!   query at a *higher* threshold than a cached mine by
//!   anti-monotonic filtering, with LRU eviction charged against the
//!   same byte budget as the shuffle `BlockStore`;
//! * [`server`] — the accept loop, per-connection threads, and the
//!   socket-free [`Server::handle`] pipeline that emits the
//!   `RequestReceived` → `RequestAdmitted`/`RequestRejected` →
//!   `RequestCompleted` span for every request.

pub mod admission;
pub mod cache;
pub mod protocol;
pub mod server;

pub use admission::{AdmissionGate, TenantShedder, Ticket};
pub use cache::{CacheHit, ResultCache};
pub use protocol::{ServeError, ServeRequest, ServeResponse, ServeResult};
pub use server::{DatasetResolver, Server};
