//! Quickstart: mine frequent itemsets from a small inline basket
//! database with RDD-Eclat (variant V4) and print the result.
//!
//! Run: `cargo run --release --example quickstart`

use rdd_eclat::fim::eclat::{mine_eclat_vec, EclatConfig, EclatVariant};
use rdd_eclat::sparklet::SparkletContext;

fn main() {
    // A tiny market-basket database: items are integer-coded products.
    let baskets: Vec<Vec<u32>> = vec![
        vec![1, 2, 5],    // bread, milk, beer
        vec![2, 4],       // milk, eggs
        vec![2, 3],       // milk, butter
        vec![1, 2, 4],    // bread, milk, eggs
        vec![1, 3],       // bread, butter
        vec![2, 3],       // milk, butter
        vec![1, 3],       // bread, butter
        vec![1, 2, 3, 5], // bread, milk, butter, beer
        vec![1, 2, 3],    // bread, milk, butter
    ];
    let names = ["", "bread", "milk", "butter", "eggs", "beer"];

    // An in-process Sparklet "cluster" with 4 executor cores.
    let sc = SparkletContext::local(4);

    // Mine with EclatV4 (hash-partitioned equivalence classes, p=4),
    // requiring an itemset to appear in at least 2 baskets.
    let cfg = EclatConfig::new(EclatVariant::V4, 2).with_p(4);
    let result = mine_eclat_vec(&sc, baskets, &cfg);

    println!("frequent itemsets (min_sup = 2):");
    let mut itemsets = result.itemsets.clone();
    itemsets.sort_by_key(|f| (f.items.len(), std::cmp::Reverse(f.support)));
    for f in &itemsets {
        let labels: Vec<&str> = f.items.iter().map(|&i| names[i as usize]).collect();
        println!("  {{{}}} x{}", labels.join(", "), f.support);
    }
    println!("total: {} itemsets", result.len());
    assert!(result.len() >= 10, "demo db should yield >= 10 itemsets");
}
