//! Tiny property-based testing harness (proptest is unavailable offline).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` generated inputs and,
//! on failure, reports the seed + a best-effort shrink so failures are
//! reproducible. Generators are plain `Fn(&mut SplitMix64) -> T`.

use super::rng::SplitMix64;

/// Run `prop` on `cases` random inputs from `gen`. Panics with the seed
/// and debug-printed input on the first failure (after shrinking, if a
/// shrinker is provided via [`forall_shrink`]).
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    gen: impl Fn(&mut SplitMix64) -> T,
    prop: impl Fn(&T) -> bool,
) {
    forall_shrink(cases, gen, |_| Vec::new(), prop)
}

/// `forall` with a shrinker: on failure, repeatedly tries the candidate
/// simplifications produced by `shrink` until a local minimum survives.
pub fn forall_shrink<T: std::fmt::Debug>(
    cases: usize,
    gen: impl Fn(&mut SplitMix64) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> bool,
) {
    let base_seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD15EA5Eu64);
    for case in 0..cases {
        let mut rng = SplitMix64::new(base_seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = minimize(input, &shrink, &prop);
            panic!(
                "property failed (seed={}, case={case}):\n{minimal:#?}\n\
                 rerun with PROP_SEED={} to reproduce",
                base_seed, base_seed
            );
        }
    }
}

fn minimize<T: std::fmt::Debug>(
    mut failing: T,
    shrink: &impl Fn(&T) -> Vec<T>,
    prop: &impl Fn(&T) -> bool,
) -> T {
    // Greedy descent: take the first shrunk candidate that still fails.
    'outer: loop {
        for cand in shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        return failing;
    }
}

/// Generator helpers.
pub mod gen {
    use super::SplitMix64;

    pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut SplitMix64) -> usize {
        move |r| lo + r.gen_range(hi - lo + 1)
    }

    pub fn vec_of<T>(
        len_lo: usize,
        len_hi: usize,
        elem: impl Fn(&mut SplitMix64) -> T,
    ) -> impl Fn(&mut SplitMix64) -> Vec<T> {
        move |r| {
            let n = len_lo + r.gen_range(len_hi - len_lo + 1);
            (0..n).map(|_| elem(r)).collect()
        }
    }

    /// A random transaction database: `n_txn` transactions over
    /// `n_items` items with the given density.
    pub fn database(
        n_txn_hi: usize,
        n_items_hi: usize,
        density: f64,
    ) -> impl Fn(&mut SplitMix64) -> Vec<Vec<u32>> {
        move |r| {
            let n_txn = 1 + r.gen_range(n_txn_hi);
            let n_items = 2 + r.gen_range(n_items_hi.max(2));
            (0..n_txn)
                .map(|_| {
                    let mut t: Vec<u32> = (0..n_items as u32)
                        .filter(|_| r.gen_bool(density))
                        .collect();
                    if t.is_empty() {
                        t.push(r.gen_range(n_items) as u32);
                    }
                    t
                })
                .collect()
        }
    }

    /// Shrinker for databases: drop transactions / drop items.
    pub fn shrink_database(db: &[Vec<u32>]) -> Vec<Vec<Vec<u32>>> {
        let mut out = Vec::new();
        if db.len() > 1 {
            out.push(db[..db.len() / 2].to_vec());
            out.push(db[db.len() / 2..].to_vec());
            let mut one_less = db.to_vec();
            one_less.pop();
            out.push(one_less);
        }
        if db.iter().any(|t| t.len() > 1) {
            out.push(
                db.iter()
                    .map(|t| t[..t.len().div_ceil(2)].to_vec())
                    .collect(),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(100, |r| r.gen_range(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(100, |r| r.gen_range(100), |&x| x < 50);
    }

    #[test]
    fn shrinker_minimizes() {
        // Shrink a failing vec (contains 7) down; minimal should still
        // contain 7 but be shorter than typical.
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                50,
                gen::vec_of(0, 20, |r| r.gen_range(10) as u32),
                |v: &Vec<u32>| {
                    let mut outs = Vec::new();
                    if v.len() > 1 {
                        outs.push(v[..v.len() / 2].to_vec());
                        outs.push(v[v.len() / 2..].to_vec());
                    }
                    outs
                },
                |v| !v.contains(&7),
            );
        });
        assert!(result.is_err());
    }

    #[test]
    fn database_gen_wellformed() {
        forall(50, gen::database(20, 10, 0.3), |db| {
            !db.is_empty() && db.iter().all(|t| !t.is_empty())
        });
    }
}
