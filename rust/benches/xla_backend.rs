//! Bench target A3: XLA/PJRT artifact backend vs native rust for the two
//! support-count primitives (Phase-2 co-occurrence matrix; batched
//! tidset intersection). Requires `make artifacts`.

use rdd_eclat::coordinator::ExperimentConfig;
use rdd_eclat::data::Dataset;
use rdd_eclat::fim::trimatrix::TriMatrix;
use rdd_eclat::runtime::{artifacts_available, artifacts_dir, XlaFim};
use rdd_eclat::util::bench::BenchSuite;
use rdd_eclat::util::Bitmap;

fn main() {
    if !artifacts_available() {
        eprintln!("SKIP xla_backend bench: run `make artifacts` first");
        return;
    }
    let cfg = ExperimentConfig::default();
    let mut fim = XlaFim::load(&artifacts_dir()).expect("load artifacts");
    println!("platform: {}", fim.platform());

    cooc_bench(&cfg, &mut fim);
    intersect_bench(&mut fim);
}

fn cooc_bench(cfg: &ExperimentConfig, fim: &mut XlaFim) {
    let mut suite = BenchSuite::new(
        "xla_cooc",
        "Phase-2 candidate-2-itemset counts: native loop vs XLA matmul artifact",
    );
    let txns = Dataset::T10I4D100K.generate_scaled(cfg.seed, (cfg.scale * 0.2).max(0.01));
    let n_txns = txns.len();
    // dense-rank the items
    let mut items: Vec<u32> = txns.iter().flatten().copied().collect();
    items.sort_unstable();
    items.dedup();
    let rank: std::collections::HashMap<u32, u32> = items
        .iter()
        .enumerate()
        .map(|(r, &i)| (i, r as u32))
        .collect();
    let ranked: Vec<Vec<u32>> = txns
        .iter()
        .map(|t| {
            let mut v: Vec<u32> = t.iter().map(|i| rank[i]).collect();
            v.sort_unstable();
            v
        })
        .collect();
    let n_items = items.len();

    suite.measure("native", "items", n_items as f64, || {
        let mut m = TriMatrix::new(n_items);
        for t in &ranked {
            m.update_transaction(t);
        }
        std::hint::black_box(&m);
    });

    // per-item bitmaps for the XLA path
    let mut bitmaps: Vec<Bitmap> = (0..n_items).map(|_| Bitmap::new(n_txns)).collect();
    for (tid, t) in ranked.iter().enumerate() {
        for &r in t {
            bitmaps[r as usize].set(tid);
        }
    }
    let refs: Vec<&Bitmap> = bitmaps.iter().collect();
    suite.measure("xla", "items", n_items as f64, || {
        let m = fim.cooc_tri_matrix(&refs).unwrap();
        std::hint::black_box(&m);
    });
    suite.finish();
}

fn intersect_bench(fim: &mut XlaFim) {
    let mut suite = BenchSuite::new(
        "xla_intersect",
        "batched tidset intersection: native AND+popcount vs XLA artifact",
    );
    let mut rng = rdd_eclat::util::SplitMix64::new(0xBE9C);
    for &(rows, universe) in &[(256usize, 32_768usize), (1024, 32_768), (256, 131_072)] {
        let make = |rng: &mut rdd_eclat::util::SplitMix64| {
            let mut b = Bitmap::new(universe);
            for i in 0..universe {
                if rng.gen_bool(0.05) {
                    b.set(i);
                }
            }
            b
        };
        let xs: Vec<Bitmap> = (0..rows).map(|_| make(&mut rng)).collect();
        let ys: Vec<Bitmap> = (0..rows).map(|_| make(&mut rng)).collect();
        let label = format!("{rows}x{}w", universe / 32);
        suite.measure("native", "case", rows as f64, || {
            let mut total = 0usize;
            for (x, y) in xs.iter().zip(&ys) {
                total += x.and_count(y);
            }
            std::hint::black_box(total);
        });
        let xr: Vec<&Bitmap> = xs.iter().collect();
        let yr: Vec<&Bitmap> = ys.iter().collect();
        suite.measure("xla", "case", rows as f64, || {
            let (_, sup) = fim.intersect_batch(&xr, &yr).unwrap();
            std::hint::black_box(sup);
        });
        eprintln!("  case {label} done");
    }
    suite.finish();
}
