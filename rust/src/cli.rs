//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `repro <command> [--flag value]...`. Flags may appear in any
//! order; `--flag=value` and `--flag value` both parse.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(format!("unexpected positional argument: {arg}"));
            };
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                flags.insert(name.to_string(), it.next().unwrap());
            } else {
                bools.push(name.to_string());
            }
        }
        Ok(Self {
            command,
            flags,
            bools,
        })
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse {v:?}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.get(name) == Some("true")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("mine --dataset t10 --min-sup 0.01 --tri-matrix");
        assert_eq!(a.command, "mine");
        assert_eq!(a.get("dataset"), Some("t10"));
        assert_eq!(a.get("min-sup"), Some("0.01"));
        assert!(a.flag("tri-matrix"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn equals_form() {
        let a = parse("fig --id=3 --scale=0.5");
        assert_eq!(a.get_parse::<usize>("id").unwrap(), Some(3));
        assert_eq!(a.get_parse::<f64>("scale").unwrap(), Some(0.5));
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse("fig --id notanumber");
        assert!(a.get_parse::<usize>("id").is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(vec!["mine".into(), "stray".into()]).is_err());
    }

    #[test]
    fn empty_means_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
