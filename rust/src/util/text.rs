//! Small text helpers for CLI/registry diagnostics.

/// Levenshtein edit distance (insert/delete/substitute, unit costs).
/// Used for "did you mean ...?" suggestions on unknown engine names and
/// misspelled CLI flags.
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    // One-row DP.
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest candidate to `input` by edit distance, if any is within
/// `max_distance`. Ties resolve to the earliest candidate.
pub fn closest<'a>(
    input: &str,
    candidates: impl IntoIterator<Item = &'a str>,
    max_distance: usize,
) -> Option<&'a str> {
    let mut best: Option<(&'a str, usize)> = None;
    for c in candidates {
        let d = edit_distance(input, c);
        let better = match best {
            None => true,
            Some((_, best_d)) => d < best_d,
        };
        if d <= max_distance && better {
            best = Some((c, d));
        }
    }
    best.map(|(c, _)| c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_basics() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", ""), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("min-supp", "min-sup"), 1);
    }

    #[test]
    fn closest_respects_threshold() {
        let cands = ["min-sup", "dataset", "engine"];
        assert_eq!(closest("min-supp", cands, 2), Some("min-sup"));
        assert_eq!(closest("engin", cands, 2), Some("engine"));
        assert_eq!(closest("zzzzzz", cands, 2), None);
    }

    #[test]
    fn closest_prefers_smaller_distance() {
        let cands = ["vec", "bitmap", "auto"];
        assert_eq!(closest("vecc", cands, 3), Some("vec"));
    }
}
