//! Core FIM types: items, transactions, mining results.

use std::collections::BTreeSet;

use crate::sparklet::serde::{Reader, SerDe, SerDeError};

/// An item is an integer token (all four benchmark datasets are
/// integer-coded; BMS item ids reach into the tens of thousands, which is
/// exactly why the paper disables the triangular matrix there).
pub type Item = u32;

/// A transaction: the items bought/clicked together. Kept sorted+deduped
/// by the readers/generators.
pub type Transaction = Vec<Item>;

/// One mined itemset with its absolute support count.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct FrequentItemset {
    pub items: Vec<Item>,
    pub support: u32,
}

impl FrequentItemset {
    pub fn new(mut items: Vec<Item>, support: u32) -> Self {
        // §Perf O4: emission-path fast path — inputs are usually already
        // sorted (class prefixes follow the processing order), so check
        // in O(k) before paying the sort.
        if !items.is_sorted() {
            items.sort_unstable();
        }
        Self { items, support }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Itemsets may ride through shuffles (e.g. distributed post-stages), so
/// they speak the shuffle codec. Decode re-checks the sorted invariant
/// through [`FrequentItemset::new`].
impl SerDe for FrequentItemset {
    fn encode(&self, out: &mut Vec<u8>) {
        self.items.encode(out);
        self.support.encode(out);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, SerDeError> {
        let items = Vec::decode(r)?;
        let support = u32::decode(r)?;
        Ok(Self::new(items, support))
    }
}

impl std::fmt::Display for FrequentItemset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let items: Vec<String> = self.items.iter().map(|i| i.to_string()).collect();
        write!(f, "{} #SUP: {}", items.join(" "), self.support)
    }
}

/// The result of a mining run, with comparison helpers for oracle tests.
#[derive(Debug, Clone, Default)]
pub struct MiningResult {
    pub itemsets: Vec<FrequentItemset>,
}

impl MiningResult {
    pub fn new(itemsets: Vec<FrequentItemset>) -> Self {
        Self { itemsets }
    }

    pub fn len(&self) -> usize {
        self.itemsets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.itemsets.is_empty()
    }

    /// Canonical form: sorted set of (items, support) — order-insensitive
    /// equality across algorithms and partitionings.
    pub fn canonical(&self) -> BTreeSet<(Vec<Item>, u32)> {
        self.itemsets
            .iter()
            .map(|f| (f.items.clone(), f.support))
            .collect()
    }

    pub fn same_as(&self, other: &MiningResult) -> bool {
        self.canonical() == other.canonical()
    }

    /// Count of itemsets of each length (1-itemsets, 2-itemsets, ...).
    pub fn histogram(&self) -> Vec<usize> {
        let mut h = Vec::new();
        for f in &self.itemsets {
            let k = f.len();
            if h.len() < k {
                h.resize(k, 0);
            }
            h[k - 1] += 1;
        }
        h
    }

    pub fn max_length(&self) -> usize {
        self.itemsets.iter().map(|f| f.len()).max().unwrap_or(0)
    }

    /// Restrict to itemsets with support >= `min_sup`. By
    /// anti-monotonicity this turns a result mined at threshold `s` into
    /// the exact result for any `s' >= s` — the subsumption rule the
    /// serve-mode cache exploits (and the property tests verify against
    /// a fresh mine).
    pub fn filter_min_sup(&self, min_sup: u32) -> MiningResult {
        MiningResult::new(
            self.itemsets
                .iter()
                .filter(|f| f.support >= min_sup)
                .cloned()
                .collect(),
        )
    }
}

/// Convert a relative minimum support (fraction of |D|) into an absolute
/// count, matching the paper's "min_sup = 0.001" notation. Rounds up so
/// an itemset must appear in at least `ceil(frac * n)` transactions.
pub fn abs_min_sup(frac: f64, n_transactions: usize) -> u32 {
    ((frac * n_transactions as f64).ceil() as u32).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn itemset_sorts_items() {
        let f = FrequentItemset::new(vec![3, 1, 2], 5);
        assert_eq!(f.items, vec![1, 2, 3]);
        assert_eq!(f.support, 5);
    }

    #[test]
    fn display_spmf_style() {
        let f = FrequentItemset::new(vec![2, 7], 11);
        assert_eq!(f.to_string(), "2 7 #SUP: 11");
    }

    #[test]
    fn canonical_ignores_order() {
        let a = MiningResult::new(vec![
            FrequentItemset::new(vec![1], 3),
            FrequentItemset::new(vec![2], 2),
        ]);
        let b = MiningResult::new(vec![
            FrequentItemset::new(vec![2], 2),
            FrequentItemset::new(vec![1], 3),
        ]);
        assert!(a.same_as(&b));
    }

    #[test]
    fn histogram_counts_lengths() {
        let r = MiningResult::new(vec![
            FrequentItemset::new(vec![1], 3),
            FrequentItemset::new(vec![2], 2),
            FrequentItemset::new(vec![1, 2], 2),
        ]);
        assert_eq!(r.histogram(), vec![2, 1]);
        assert_eq!(r.max_length(), 2);
    }

    #[test]
    fn filter_min_sup_keeps_only_supported() {
        let r = MiningResult::new(vec![
            FrequentItemset::new(vec![1], 5),
            FrequentItemset::new(vec![2], 3),
            FrequentItemset::new(vec![1, 2], 3),
            FrequentItemset::new(vec![3], 2),
        ]);
        let f = r.filter_min_sup(3);
        assert_eq!(f.len(), 3);
        assert!(f.itemsets.iter().all(|i| i.support >= 3));
        // At the original threshold it's the identity.
        assert!(r.filter_min_sup(1).same_as(&r));
        assert!(r.filter_min_sup(100).is_empty());
    }

    #[test]
    fn abs_min_sup_rounds_up() {
        assert_eq!(abs_min_sup(0.5, 10), 5);
        assert_eq!(abs_min_sup(0.001, 59602), 60);
        assert_eq!(abs_min_sup(0.0, 100), 1); // floor at 1
        assert_eq!(abs_min_sup(0.015, 1000), 15);
    }
}
