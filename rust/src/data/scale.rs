//! Dataset scaling for the Fig. 6 experiment: "to get the larger dataset
//! size, it is doubled each time from its previous dataset", 100K → 1600K
//! transactions.
//!
//! Doubling follows the paper's methodology (replicate the transaction
//! set), with an optional jitter mode that re-draws item ids through the
//! generator instead — both keep the support *fractions* identical, so a
//! fixed relative min_sup finds the same itemsets at every scale.

use crate::fim::Transaction;
use crate::util::SplitMix64;

/// Replicate a database `factor` times (paper's doubling).
pub fn replicate(base: &[Transaction], factor: usize) -> Vec<Transaction> {
    let mut out = Vec::with_capacity(base.len() * factor);
    for _ in 0..factor {
        out.extend_from_slice(base);
    }
    out
}

/// Replicate with per-copy transaction shuffling — same multiset of
/// transactions, different order, so partition contents differ per copy
/// (defeats accidental cache-locality advantages in scaling runs).
pub fn replicate_shuffled(base: &[Transaction], factor: usize, seed: u64) -> Vec<Transaction> {
    let mut out = replicate(base, factor);
    let mut rng = SplitMix64::new(seed);
    rng.shuffle(&mut out);
    out
}

/// The Fig. 6 x-axis: scale factors 1, 2, 4, 8, 16 (100K → 1600K).
pub fn fig6_factors() -> [usize; 5] {
    [1, 2, 4, 8, 16]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::sequential::eclat_sequential;
    use crate::fim::types::abs_min_sup;

    #[test]
    fn replicate_sizes() {
        let base = vec![vec![1u32, 2], vec![3]];
        assert_eq!(replicate(&base, 4).len(), 8);
        assert_eq!(replicate(&base, 1), base);
    }

    #[test]
    fn shuffled_same_multiset() {
        let base: Vec<Transaction> = (0..50).map(|i| vec![i as u32]).collect();
        let mut a = replicate(&base, 3);
        let mut b = replicate_shuffled(&base, 3, 9);
        assert_ne!(a, b, "shuffle changed nothing");
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn scaling_preserves_relative_supports() {
        // Mining at the same *fraction* must find identical itemsets with
        // supports scaled by the factor.
        let base = vec![
            vec![1u32, 2, 3],
            vec![1, 2],
            vec![2, 3],
            vec![1, 3],
            vec![1, 2, 3],
        ];
        let frac = 0.4;
        let r1 = eclat_sequential(&base, abs_min_sup(frac, base.len()));
        let big = replicate(&base, 4);
        let r4 = eclat_sequential(&big, abs_min_sup(frac, big.len()));
        let c1: Vec<(Vec<u32>, u32)> = r1.canonical().into_iter().collect();
        let c4: Vec<(Vec<u32>, u32)> = r4.canonical().into_iter().collect();
        assert_eq!(c1.len(), c4.len());
        for ((i1, s1), (i4, s4)) in c1.iter().zip(&c4) {
            assert_eq!(i1, i4);
            assert_eq!(s1 * 4, *s4);
        }
    }

    #[test]
    fn fig6_doubles() {
        let f = fig6_factors();
        for w in f.windows(2) {
            assert_eq!(w[1], w[0] * 2);
        }
    }
}
