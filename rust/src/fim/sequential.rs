//! Sequential reference algorithms — the correctness oracles.
//!
//! `eclat_sequential` is plain single-threaded Eclat (vertical layout,
//! equivalence classes, Bottom-Up); `apriori_sequential` is textbook
//! Agrawal–Srikant with trie-based candidate counting. Every distributed
//! variant is asserted identical to these on randomized databases.

use crate::util::hash::FxHashMap;

use super::eqclass::{bottom_up, build_classes};
use super::tidset::{TidOps, VecTidset};
use super::trie::ItemTrie;
use super::types::{FrequentItemset, Item, MiningResult, Transaction};

/// Sequential Eclat, generic over the tidset representation.
pub fn eclat_sequential_with<TS: TidOps>(txns: &[Transaction], min_sup: u32) -> MiningResult {
    let n = txns.len();
    // Vertical conversion.
    let mut tidsets: FxHashMap<Item, Vec<u32>> = FxHashMap::default();
    for (tid, txn) in txns.iter().enumerate() {
        let mut seen = txn.clone();
        seen.sort_unstable();
        seen.dedup();
        for item in seen {
            tidsets.entry(item).or_default().push(tid as u32);
        }
    }
    // Frequent items, sorted by (support asc, item asc) — the paper's
    // total order of increasing support.
    let mut vertical: Vec<(Item, VecTidset)> = tidsets
        .into_iter()
        .filter(|(_, tids)| tids.len() as u32 >= min_sup)
        .map(|(item, tids)| (item, VecTidset::from_tids(&tids, n)))
        .collect();
    vertical.sort_by_key(|(item, ts)| (ts.support(), *item));

    let mut out: Vec<FrequentItemset> = vertical
        .iter()
        .map(|(item, ts)| FrequentItemset::new(vec![*item], ts.support() as u32))
        .collect();

    // Re-materialize in the requested representation if needed.
    let vertical_ts: Vec<(Item, TS)> = vertical
        .iter()
        .map(|(item, ts)| (*item, TS::from_tids(&ts.to_tids(), n)))
        .collect();

    let mut twos = Vec::new();
    let classes = build_classes(&vertical_ts, min_sup, None, |i| i, &mut twos);
    out.extend(twos);
    for (_, class) in &classes {
        bottom_up(class, min_sup, &mut out);
    }
    MiningResult::new(out)
}

/// Sequential Eclat with the default (tid-list) representation.
pub fn eclat_sequential(txns: &[Transaction], min_sup: u32) -> MiningResult {
    eclat_sequential_with::<VecTidset>(txns, min_sup)
}

/// Apriori candidate generation: join L_{k-1} with itself on the first
/// k-2 items, then prune candidates with an infrequent (k-1)-subset.
pub fn apriori_gen(prev: &[Vec<Item>]) -> Vec<Vec<Item>> {
    let prev_set: std::collections::HashSet<&[Item]> =
        prev.iter().map(|v| v.as_slice()).collect();
    let mut out = Vec::new();
    for (a_idx, a) in prev.iter().enumerate() {
        for b in &prev[a_idx + 1..] {
            let k1 = a.len();
            if a[..k1 - 1] != b[..k1 - 1] {
                continue;
            }
            let (last_a, last_b) = (a[k1 - 1], b[k1 - 1]);
            let mut cand = a.clone();
            cand.push(last_a.max(last_b));
            cand[k1 - 1] = last_a.min(last_b);
            // prune: every (k-1)-subset must be frequent
            let mut ok = true;
            let mut sub = Vec::with_capacity(k1);
            for drop in 0..cand.len() {
                sub.clear();
                sub.extend(cand.iter().enumerate().filter(|(i, _)| *i != drop).map(|(_, &x)| x));
                if !prev_set.contains(sub.as_slice()) {
                    ok = false;
                    break;
                }
            }
            if ok {
                out.push(cand);
            }
        }
    }
    out
}

/// Sequential Apriori with trie-based subset counting.
pub fn apriori_sequential(txns: &[Transaction], min_sup: u32) -> MiningResult {
    // Normalize transactions: sorted, deduped.
    let norm: Vec<Transaction> = txns
        .iter()
        .map(|t| {
            let mut t = t.clone();
            t.sort_unstable();
            t.dedup();
            t
        })
        .collect();

    // L1.
    let mut counts: FxHashMap<Item, u32> = FxHashMap::default();
    for t in &norm {
        for &i in t {
            *counts.entry(i).or_insert(0) += 1;
        }
    }
    let mut frequent: Vec<FrequentItemset> = counts
        .iter()
        .filter(|(_, &c)| c >= min_sup)
        .map(|(&i, &c)| FrequentItemset::new(vec![i], c))
        .collect();
    let mut level: Vec<Vec<Item>> = frequent.iter().map(|f| f.items.clone()).collect();
    level.sort();

    // Lk for k >= 2.
    while !level.is_empty() {
        let candidates = apriori_gen(&level);
        if candidates.is_empty() {
            break;
        }
        let mut trie = ItemTrie::new();
        for c in &candidates {
            trie.insert(c);
        }
        for t in &norm {
            trie.count_subsets(t);
        }
        let mut next: Vec<Vec<Item>> = Vec::new();
        for (items, count) in trie.counts() {
            if count >= min_sup {
                frequent.push(FrequentItemset::new(items.clone(), count));
                next.push(items);
            }
        }
        next.sort();
        level = next;
    }
    MiningResult::new(frequent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::tidset::{BitmapTidset, DiffTidset, HybridTidset};
    use crate::util::prop::{forall, gen};

    fn demo_db() -> Vec<Transaction> {
        vec![
            vec![1, 2, 5],
            vec![2, 4],
            vec![2, 3],
            vec![1, 2, 4],
            vec![1, 3],
            vec![2, 3],
            vec![1, 3],
            vec![1, 2, 3, 5],
            vec![1, 2, 3],
        ]
    }

    #[test]
    fn eclat_matches_apriori_on_demo() {
        for min_sup in 1..=5u32 {
            let e = eclat_sequential(&demo_db(), min_sup);
            let a = apriori_sequential(&demo_db(), min_sup);
            assert!(
                e.same_as(&a),
                "min_sup={min_sup}: eclat={:?} apriori={:?}",
                e.canonical(),
                a.canonical()
            );
        }
    }

    #[test]
    fn textbook_example_level_counts() {
        // Agrawal's classic: with min_sup=2 the demo db has known L sizes.
        let r = apriori_sequential(&demo_db(), 2);
        let hist = r.histogram();
        // 1-itemsets: 1,2,3,4,5 all appear >= 2 times
        assert_eq!(hist[0], 5);
        // no 4-itemset is frequent
        assert!(r.max_length() <= 3);
    }

    #[test]
    fn all_representations_identical() {
        for min_sup in 1..=4u32 {
            let v = eclat_sequential_with::<VecTidset>(&demo_db(), min_sup);
            let b = eclat_sequential_with::<BitmapTidset>(&demo_db(), min_sup);
            let d = eclat_sequential_with::<DiffTidset>(&demo_db(), min_sup);
            let h = eclat_sequential_with::<HybridTidset>(&demo_db(), min_sup);
            assert!(v.same_as(&b), "bitmap min_sup={min_sup}");
            assert!(v.same_as(&d), "diffset min_sup={min_sup}");
            assert!(v.same_as(&h), "hybrid min_sup={min_sup}");
        }
    }

    #[test]
    fn property_diffset_supports_equal_tidset_supports() {
        // ISSUE-4 property: on random databases every diffset-computed
        // support equals the tidset-computed one — same_as compares the
        // full (itemset, support) sets, so one disagreeing support fails.
        forall(30, gen::database(25, 8, 0.5), |db| {
            for min_sup in [1u32, 2, 3] {
                let v = eclat_sequential_with::<VecTidset>(db, min_sup);
                if !v.same_as(&eclat_sequential_with::<DiffTidset>(db, min_sup)) {
                    return false;
                }
                if !v.same_as(&eclat_sequential_with::<HybridTidset>(db, min_sup)) {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn diffset_edges_universe_dense_and_empty_diffsets() {
        // universe-dense: identical transactions ⇒ every tidset is the
        // whole universe and every diffset is empty (support survives
        // purely through the dEclat subtraction bookkeeping)
        let dense: Vec<Transaction> = vec![vec![1, 2, 3, 4]; 6];
        for min_sup in [1u32, 3, 6, 7] {
            let v = eclat_sequential_with::<VecTidset>(&dense, min_sup);
            let d = eclat_sequential_with::<DiffTidset>(&dense, min_sup);
            let h = eclat_sequential_with::<HybridTidset>(&dense, min_sup);
            assert!(v.same_as(&d), "dense min_sup={min_sup}");
            assert!(v.same_as(&h), "dense min_sup={min_sup}");
            if min_sup <= 6 {
                // 4 items: 2^4 - 1 itemsets, all with support 6
                assert_eq!(v.len(), 15, "min_sup={min_sup}");
            } else {
                assert!(v.is_empty());
            }
        }
        // one divergent transaction: diffsets of size exactly 1 at the
        // border, empty elsewhere
        let mut nearly = dense.clone();
        nearly.push(vec![1, 2]);
        for min_sup in [1u32, 6, 7] {
            let v = eclat_sequential_with::<VecTidset>(&nearly, min_sup);
            let d = eclat_sequential_with::<DiffTidset>(&nearly, min_sup);
            assert!(v.same_as(&d), "nearly-dense min_sup={min_sup}");
        }
    }

    #[test]
    fn apriori_gen_joins_and_prunes() {
        let prev = vec![vec![1, 2], vec![1, 3], vec![2, 3], vec![2, 4]];
        let mut cands = apriori_gen(&prev);
        cands.sort();
        // {1,2,3} joinable and all subsets frequent; {2,3,4} requires
        // {3,4} which is absent -> pruned; {1,2}+{2,4} don't share prefix... wait
        // join on first item: {1,2}x{1,3} -> {1,2,3}; {2,3}x{2,4} -> {2,3,4} pruned.
        assert_eq!(cands, vec![vec![1, 2, 3]]);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let empty: Vec<Transaction> = Vec::new();
        assert!(eclat_sequential(&empty, 1).is_empty());
        assert!(apriori_sequential(&empty, 1).is_empty());
        let single = vec![vec![7u32]];
        let r = eclat_sequential(&single, 1);
        assert_eq!(r.canonical().len(), 1);
        // min_sup above every support -> nothing
        assert!(eclat_sequential(&demo_db(), 100).is_empty());
    }

    #[test]
    fn duplicate_items_in_transaction_counted_once() {
        let db = vec![vec![1, 1, 2], vec![1, 2, 2]];
        let r = eclat_sequential(&db, 2);
        let canon = r.canonical();
        assert!(canon.contains(&(vec![1], 2)));
        assert!(canon.contains(&(vec![2], 2)));
        assert!(canon.contains(&(vec![1, 2], 2)));
    }

    #[test]
    fn property_eclat_equals_apriori_random_dbs() {
        forall(40, gen::database(25, 8, 0.35), |db| {
            for min_sup in [1u32, 2, 3] {
                let e = eclat_sequential(db, min_sup);
                let a = apriori_sequential(db, min_sup);
                if !e.same_as(&a) {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn property_antimonotone_supports() {
        // Every subset of a frequent itemset is frequent with >= support.
        forall(30, gen::database(20, 7, 0.4), |db| {
            let r = eclat_sequential(db, 2);
            let canon: std::collections::HashMap<Vec<Item>, u32> =
                r.canonical().into_iter().collect();
            for (items, sup) in &canon {
                if items.len() < 2 {
                    continue;
                }
                for drop in 0..items.len() {
                    let sub: Vec<Item> = items
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| *i != drop)
                        .map(|(_, &x)| x)
                        .collect();
                    match canon.get(&sub) {
                        Some(&ssup) if ssup >= *sup => {}
                        _ => return false,
                    }
                }
            }
            true
        });
    }
}
