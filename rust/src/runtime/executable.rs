//! Artifact loading: HLO text file → compiled PJRT executable.

use std::collections::HashMap;

use anyhow::{Context, Result};

/// One compiled artifact plus its declared tile shape.
pub struct LoadedArtifact {
    pub name: String,
    /// (rows/items, words/chunk) tile shape parsed from the file name.
    pub shape: (usize, usize),
    pub exe: xla::PjRtLoadedExecutable,
}

/// Loads and caches compiled executables from the artifacts directory.
pub struct ArtifactRegistry {
    client: xla::PjRtClient,
    loaded: HashMap<String, LoadedArtifact>,
}

impl ArtifactRegistry {
    /// Create a CPU PJRT client. This is the expensive step (~100 ms);
    /// do it once per process.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            loaded: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<dir>/<name>.hlo.txt` (cached by name).
    pub fn load(&mut self, dir: &str, name: &str) -> Result<&LoadedArtifact> {
        if !self.loaded.contains_key(name) {
            let path = format!("{dir}/{name}.hlo.txt");
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            let shape = parse_shape(name)
                .with_context(|| format!("artifact name {name} lacks RxC suffix"))?;
            self.loaded.insert(
                name.to_string(),
                LoadedArtifact {
                    name: name.to_string(),
                    shape,
                    exe,
                },
            );
        }
        Ok(&self.loaded[name])
    }

    /// Names listed in the artifacts manifest (without `.hlo.txt`).
    pub fn manifest(dir: &str) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(format!("{dir}/manifest.txt"))
            .with_context(|| format!("read {dir}/manifest.txt — run `make artifacts`"))?;
        Ok(text
            .split_whitespace()
            .filter_map(|n| n.strip_suffix(".hlo.txt").map(|s| s.to_string()))
            .collect())
    }
}

/// Parse the `<base>_{R}x{C}` tile-shape suffix convention.
fn parse_shape(name: &str) -> Option<(usize, usize)> {
    let tail = name.rsplit('_').next()?;
    let (r, c) = tail.split_once('x')?;
    Some((r.parse().ok()?, c.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shape_convention() {
        assert_eq!(parse_shape("intersect_256x1024"), Some((256, 1024)));
        assert_eq!(parse_shape("cooc_pair_128x512"), Some((128, 512)));
        assert_eq!(parse_shape("model"), None);
    }
}
