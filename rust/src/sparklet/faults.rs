//! Deterministic, seeded fault injection and unified retry policy.
//!
//! The "R" in RDD is *resilient*, and resilience claims are only worth
//! what their failure model covers. This module turns the repo's ad-hoc
//! failure knobs (`worker_fault "w0:1"`, `task_failure_rate`) into one
//! systematic plane: a [`FaultPlan`] names *sites* threaded through the
//! real code paths — spill write/read in the block store, frame
//! write/read/corrupt in the transport, task panics in the scheduler,
//! worker kill / heartbeat stall in the remote executor, client
//! disconnect in serve mode — and a seeded trigger per site, so a fault
//! schedule replays bit-exactly from its spec string. The armed runtime
//! form is a [`FaultPlane`], held per [`SparkletContext`] (never
//! process-global: parallel `cargo test` threads must not contaminate
//! each other's schedules).
//!
//! Plan grammar (`SPARKLET_FAULT_PLAN` / `--fault-plan`), clauses split
//! on `;`:
//!
//! ```text
//! seed=42; spill_read:nth=1; frame_corrupt:p=0.05; worker_kill=w0:1
//! ```
//!
//! * `seed=N` — seeds the probabilistic triggers and corruption offsets
//!   (default 0).
//! * `<site>:nth=K` — fire exactly once, on the K-th arming (1-based).
//! * `<site>:every=K` — fire on every K-th arming.
//! * `<site>:p=F` — seeded Bernoulli coin per arming, `0 < F <= 1`.
//! * `<site>:always` — fire on every arming (`every=1`).
//! * `worker_kill=<id>:<n>` — worker `<id>` dies after completing `<n>`
//!   tasks (subsumes the legacy `worker_fault` spec).
//! * `heartbeat_stall=<id>:<n>` — worker `<id>` stops heartbeating after
//!   `<n>` tasks while its socket stays open, so the driver's liveness
//!   watchdog — not an EOF — must declare it lost.
//!
//! Alongside the plane lives [`RetryPolicy`]: max attempts, a
//! deterministic exponential backoff schedule, and an optional per-job
//! deadline, with typed [`RetryError`] outcomes. The DAG scheduler's
//! described-job loop and the worker fetch path both retry through it,
//! so "how many times, how long apart, give up when" is decided in one
//! place instead of per call site.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::rng::SplitMix64;

/// Named injection points. Each variant corresponds to exactly one
/// arming call threaded through the production code path it names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// `BlockStore::enforce_budget`, just before a victim block's bytes
    /// are written to its spill file (fails like a full/broken disk;
    /// the block stays resident, mining proceeds degraded).
    SpillWrite,
    /// `BlockStore::get`, just before reading a spilled block back
    /// (fails like an unreadable disk; surfaces as a typed, retryable
    /// shuffle error).
    SpillRead,
    /// `transport::write_frame_with`, before any bytes hit the wire
    /// (fails like a reset connection; the stream stays unwritten, so a
    /// retry re-sends a whole frame).
    FrameWrite,
    /// `transport::read_frame_with`, before the length prefix is read
    /// (fails like a truncated/reset connection).
    FrameRead,
    /// `transport::write_frame_with`, after encoding: flips one seeded
    /// payload byte (never the length prefix, so framing stays aligned
    /// and the peer sees a typed codec error, not a desynced stream).
    FrameCorrupt,
    /// Scheduler task bodies: the task panics before running, and the
    /// stage retries it from lineage.
    TaskPanic,
    /// `serve::Server::serve_connection`: the client vanishes after its
    /// request is handled, before the response is written — the
    /// admission ticket must already be released and waiters unwedged.
    ServeDisconnect,
}

impl FaultSite {
    /// Every site, for table-driven tests and docs.
    pub const ALL: [FaultSite; 7] = [
        FaultSite::SpillWrite,
        FaultSite::SpillRead,
        FaultSite::FrameWrite,
        FaultSite::FrameRead,
        FaultSite::FrameCorrupt,
        FaultSite::TaskPanic,
        FaultSite::ServeDisconnect,
    ];

    /// The grammar name of this site.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultSite::SpillWrite => "spill_write",
            FaultSite::SpillRead => "spill_read",
            FaultSite::FrameWrite => "frame_write",
            FaultSite::FrameRead => "frame_read",
            FaultSite::FrameCorrupt => "frame_corrupt",
            FaultSite::TaskPanic => "task_panic",
            FaultSite::ServeDisconnect => "serve_disconnect",
        }
    }

    fn parse(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.into_iter().find(|site| site.as_str() == s)
    }

    /// Stable tag for forking the plan seed per site (discriminant
    /// order is append-only, like the wire tags).
    fn tag(self) -> u64 {
        self as u64 + 1
    }
}

/// When an armed site actually fires.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Trigger {
    /// Fire exactly once, on the k-th arming (1-based).
    Nth(u64),
    /// Fire on every k-th arming.
    Every(u64),
    /// Seeded Bernoulli coin per arming.
    Prob(f64),
}

/// A parsed fault schedule. Pure data: arm it into a [`FaultPlane`] to
/// get the stateful, thread-safe runtime form.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    seed: u64,
    sites: Vec<(FaultSite, Trigger)>,
    worker_kill: Vec<(String, u64)>,
    heartbeat_stall: Vec<(String, u64)>,
}

impl FaultPlan {
    /// Parse the plan grammar. Every clause must parse; unknown sites,
    /// malformed triggers, and out-of-range probabilities are errors
    /// (a typo silently injecting nothing would make every chaos test
    /// vacuous).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(v) = clause.strip_prefix("seed=") {
                plan.seed = v
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| format!("clause {clause:?}: seed must be a u64"))?;
            } else if let Some(v) = clause.strip_prefix("worker_kill=") {
                plan.worker_kill.push(parse_worker_clause(clause, v)?);
            } else if let Some(v) = clause.strip_prefix("heartbeat_stall=") {
                plan.heartbeat_stall.push(parse_worker_clause(clause, v)?);
            } else {
                let (site, trigger) = clause.split_once(':').ok_or(format!(
                    "clause {clause:?}: expected <site>:<trigger>, \
                     seed=N, worker_kill=<id>:<n>, or heartbeat_stall=<id>:<n>"
                ))?;
                let site = FaultSite::parse(site.trim()).ok_or_else(|| {
                    format!(
                        "clause {clause:?}: unknown site {:?} (known: {})",
                        site.trim(),
                        FaultSite::ALL.map(|s| s.as_str()).join(", ")
                    )
                })?;
                plan.sites.push((site, parse_trigger(clause, trigger.trim())?));
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing (e.g. parsed from `"seed=7"`).
    pub fn is_empty(&self) -> bool {
        self.sites.is_empty() && self.worker_kill.is_empty() && self.heartbeat_stall.is_empty()
    }
}

fn parse_worker_clause(clause: &str, v: &str) -> Result<(String, u64), String> {
    let (id, n) = v
        .split_once(':')
        .ok_or(format!("clause {clause:?}: expected <worker-id>:<n-tasks>"))?;
    let n = n
        .trim()
        .parse::<u64>()
        .ok()
        .filter(|&n| n >= 1)
        .ok_or(format!("clause {clause:?}: task count must be an integer >= 1"))?;
    Ok((id.trim().to_string(), n))
}

fn parse_trigger(clause: &str, t: &str) -> Result<Trigger, String> {
    if t == "always" {
        return Ok(Trigger::Every(1));
    }
    if let Some(v) = t.strip_prefix("nth=") {
        let k = v
            .parse::<u64>()
            .ok()
            .filter(|&k| k >= 1)
            .ok_or(format!("clause {clause:?}: nth wants an integer >= 1"))?;
        return Ok(Trigger::Nth(k));
    }
    if let Some(v) = t.strip_prefix("every=") {
        let k = v
            .parse::<u64>()
            .ok()
            .filter(|&k| k >= 1)
            .ok_or(format!("clause {clause:?}: every wants an integer >= 1"))?;
        return Ok(Trigger::Every(k));
    }
    if let Some(v) = t.strip_prefix("p=") {
        let p = v
            .parse::<f64>()
            .ok()
            .filter(|p| p.is_finite() && *p > 0.0 && *p <= 1.0)
            .ok_or(format!("clause {clause:?}: p wants a probability in (0, 1]"))?;
        return Ok(Trigger::Prob(p));
    }
    Err(format!(
        "clause {clause:?}: unknown trigger {t:?} (want nth=K, every=K, p=F, or always)"
    ))
}

/// Per-site arming state under the plane's one lock.
#[derive(Default)]
struct SiteState {
    /// Times this site has been armed (reached in the code path).
    hits: u64,
    /// Times the trigger actually fired.
    fired: u64,
    /// A `nth=` trigger that already fired stays quiet forever.
    nth_done: bool,
}

/// The armed, thread-safe runtime form of a [`FaultPlan`]. One per
/// context (and one per worker process, parsed from `--fault`); a
/// disarmed plane is a no-op on every path, so production code arms
/// sites unconditionally.
pub struct FaultPlane {
    plan: Option<FaultPlan>,
    state: Mutex<HashMap<FaultSite, SiteState>>,
}

impl FaultPlane {
    /// A plane that never fires — the default wiring.
    pub fn disarmed() -> FaultPlane {
        FaultPlane {
            plan: None,
            state: Mutex::new(HashMap::new()),
        }
    }

    /// Arm a parsed plan.
    pub fn new(plan: FaultPlan) -> FaultPlane {
        let plan = if plan.is_empty() { None } else { Some(plan) };
        FaultPlane {
            plan,
            state: Mutex::new(HashMap::new()),
        }
    }

    /// True when any clause could fire. Hot paths may skip arming work
    /// (not correctness) when inactive.
    pub fn is_active(&self) -> bool {
        self.plan.is_some()
    }

    /// Arm `site` once: count the hit and decide whether the fault
    /// fires here. The decision depends only on the plan seed, the
    /// site, and this site's own hit ordinal — never on other sites'
    /// traffic — so a schedule replays even when unrelated code paths
    /// change.
    pub fn should_fail(&self, site: FaultSite) -> bool {
        let Some(plan) = &self.plan else {
            return false;
        };
        let triggers: Vec<Trigger> = plan
            .sites
            .iter()
            .filter(|(s, _)| *s == site)
            .map(|(_, t)| *t)
            .collect();
        if triggers.is_empty() {
            return false;
        }
        let mut state = self.state.lock().unwrap();
        let st = state.entry(site).or_default();
        st.hits += 1;
        let hit = st.hits;
        let fire = triggers.iter().any(|t| match *t {
            Trigger::Nth(k) => !st.nth_done && hit == k,
            Trigger::Every(k) => hit % k == 0,
            Trigger::Prob(p) => {
                // Stateless per-(seed, site, hit) derivation: parallel
                // armings of *other* sites cannot perturb this coin.
                let mut base = SplitMix64::new(plan.seed);
                let mut per_site = base.fork(site.tag());
                per_site.fork(hit).gen_bool(p)
            }
        });
        if fire {
            st.fired += 1;
            if triggers.iter().any(|t| matches!(t, Trigger::Nth(k) if *k == hit)) {
                st.nth_done = true;
            }
        }
        fire
    }

    /// How many times `site` has actually fired (test signal: a chaos
    /// test that injected nothing proved nothing).
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.state
            .lock()
            .unwrap()
            .get(&site)
            .map_or(0, |st| st.fired)
    }

    /// Total faults fired across all sites.
    pub fn total_injected(&self) -> u64 {
        self.state.lock().unwrap().values().map(|st| st.fired).sum()
    }

    /// Flip one seeded byte of `payload` in place (the
    /// [`FaultSite::FrameCorrupt`] payload mutation). The offset
    /// derives from the seed and the site's fired count, so corruption
    /// is replayable; the XOR constant is nonzero, so the byte always
    /// actually changes.
    pub fn corrupt_byte(&self, payload: &mut [u8]) {
        if payload.is_empty() {
            return;
        }
        let seed = self.plan.as_ref().map_or(0, |p| p.seed);
        let fired = self.injected(FaultSite::FrameCorrupt);
        let mut base = SplitMix64::new(seed);
        let mut rng = base.fork(FaultSite::FrameCorrupt.tag()).fork(fired);
        let idx = rng.gen_range(payload.len());
        payload[idx] ^= 0xA5;
    }

    /// `worker_kill=<id>:<n>`: the task count after which worker `id`
    /// should die, if the plan names it.
    pub fn worker_kill_after(&self, worker_id: &str) -> Option<u64> {
        self.plan.as_ref().and_then(|p| {
            p.worker_kill
                .iter()
                .find(|(id, _)| id == worker_id)
                .map(|(_, n)| *n)
        })
    }

    /// `heartbeat_stall=<id>:<n>`: the task count after which worker
    /// `id` should stop heartbeating, if the plan names it.
    pub fn heartbeat_stall_after(&self, worker_id: &str) -> Option<u64> {
        self.plan.as_ref().and_then(|p| {
            p.heartbeat_stall
                .iter()
                .find(|(id, _)| id == worker_id)
                .map(|(_, n)| *n)
        })
    }
}

/// Unified retry/backoff/deadline policy. Attempt loops ask
/// [`RetryPolicy::backoff`] how long to sleep between attempts and
/// [`RetryPolicy::check_deadline`] whether the job may continue; both
/// are pure functions of the policy, so a schedule is deterministic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts (first try included). At least 1.
    pub max_attempts: u32,
    /// Backoff before retry `a` is `base << (a-1)`, capped.
    pub backoff_base_ms: u64,
    /// Ceiling on any single backoff sleep.
    pub backoff_cap_ms: u64,
    /// Whole-job wall-clock budget; `None` = unbounded.
    pub deadline_ms: Option<u64>,
}

/// Ceiling on a single backoff sleep: retries are for transient faults,
/// and anything still failing after a second of backoff needs the
/// deadline, not more patience.
pub const BACKOFF_CAP_MS: u64 = 1_000;

impl RetryPolicy {
    pub fn new(max_attempts: u32, backoff_base_ms: u64, deadline_ms: Option<u64>) -> RetryPolicy {
        RetryPolicy {
            max_attempts: max_attempts.max(1),
            backoff_base_ms,
            backoff_cap_ms: BACKOFF_CAP_MS,
            deadline_ms,
        }
    }

    /// How long to sleep before attempt `attempt` (0-based; attempt 0
    /// never waits). Deterministic: `base * 2^(attempt-1)`, capped.
    pub fn backoff(&self, attempt: u32) -> Duration {
        if attempt == 0 || self.backoff_base_ms == 0 {
            return Duration::ZERO;
        }
        let exp = (attempt - 1).min(20); // 2^20 * base already dwarfs any cap
        let ms = self
            .backoff_base_ms
            .saturating_mul(1u64 << exp)
            .min(self.backoff_cap_ms);
        Duration::from_millis(ms)
    }

    /// Typed deadline check against the job's start instant.
    pub fn check_deadline(&self, started: Instant) -> Result<(), RetryError> {
        let Some(deadline_ms) = self.deadline_ms else {
            return Ok(());
        };
        let elapsed_ms = started.elapsed().as_millis() as u64;
        if elapsed_ms > deadline_ms {
            Err(RetryError::DeadlineExceeded {
                elapsed_ms,
                deadline_ms,
            })
        } else {
            Ok(())
        }
    }

    /// `RetriesExhausted` carrying the final attempt's error.
    pub fn exhausted(&self, last_error: impl Into<String>) -> RetryError {
        RetryError::RetriesExhausted {
            attempts: self.max_attempts,
            last_error: last_error.into(),
        }
    }
}

/// Why a retried operation gave up. The typed boundary the property
/// suite checks against: persistent faults must end here, never in a
/// wrong answer or a hang.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RetryError {
    /// Every attempt failed; `last_error` is the final attempt's cause.
    RetriesExhausted { attempts: u32, last_error: String },
    /// The per-job wall-clock budget ran out mid-schedule.
    DeadlineExceeded { elapsed_ms: u64, deadline_ms: u64 },
}

impl std::fmt::Display for RetryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RetryError::RetriesExhausted {
                attempts,
                last_error,
            } => write!(f, "retries exhausted after {attempts} attempts: {last_error}"),
            RetryError::DeadlineExceeded {
                elapsed_ms,
                deadline_ms,
            } => write!(
                f,
                "deadline exceeded: {elapsed_ms} ms elapsed against a {deadline_ms} ms budget"
            ),
        }
    }
}

impl std::error::Error for RetryError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse(
            "seed=42; spill_read:nth=1; frame_corrupt:p=0.25; task_panic:every=3; \
             spill_write:always; worker_kill=w0:1; heartbeat_stall=w1:2;",
        )
        .unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.sites.len(), 4);
        assert_eq!(plan.sites[0], (FaultSite::SpillRead, Trigger::Nth(1)));
        assert_eq!(plan.sites[3], (FaultSite::SpillWrite, Trigger::Every(1)));
        assert_eq!(plan.worker_kill, vec![("w0".to_string(), 1)]);
        assert_eq!(plan.heartbeat_stall, vec![("w1".to_string(), 2)]);
        assert!(!plan.is_empty());
        assert!(FaultPlan::parse("seed=7").unwrap().is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_clauses_typed() {
        for (spec, needle) in [
            ("spillread:nth=1", "unknown site"),
            ("spill_read", "expected <site>:<trigger>"),
            ("spill_read:sometimes", "unknown trigger"),
            ("spill_read:nth=0", "nth wants an integer >= 1"),
            ("spill_read:every=zero", "every wants an integer >= 1"),
            ("spill_read:p=1.5", "probability in (0, 1]"),
            ("spill_read:p=0", "probability in (0, 1]"),
            ("seed=minus-one", "seed must be a u64"),
            ("worker_kill=w0", "expected <worker-id>:<n-tasks>"),
            ("worker_kill=w0:0", "task count must be an integer >= 1"),
            ("heartbeat_stall=w0:x", "task count must be an integer >= 1"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(err.contains(needle), "{spec:?} -> {err}");
            // Every error names the offending clause.
            assert!(err.contains("clause"), "{spec:?} -> {err}");
        }
    }

    #[test]
    fn nth_fires_exactly_once_at_the_named_hit() {
        let plane = FaultPlane::new(FaultPlan::parse("spill_read:nth=3").unwrap());
        let fired: Vec<bool> = (0..6)
            .map(|_| plane.should_fail(FaultSite::SpillRead))
            .collect();
        assert_eq!(fired, vec![false, false, true, false, false, false]);
        assert_eq!(plane.injected(FaultSite::SpillRead), 1);
        // Other sites are untouched.
        assert!(!plane.should_fail(FaultSite::SpillWrite));
        assert_eq!(plane.injected(FaultSite::SpillWrite), 0);
    }

    #[test]
    fn every_fires_periodically_and_always_is_every_one() {
        let plane = FaultPlane::new(FaultPlan::parse("task_panic:every=2").unwrap());
        let fired: Vec<bool> = (0..6)
            .map(|_| plane.should_fail(FaultSite::TaskPanic))
            .collect();
        assert_eq!(fired, vec![false, true, false, true, false, true]);
        let plane = FaultPlane::new(FaultPlan::parse("frame_write:always").unwrap());
        assert!((0..4).all(|_| plane.should_fail(FaultSite::FrameWrite)));
    }

    #[test]
    fn prob_trigger_replays_exactly_for_a_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let plane =
                FaultPlane::new(FaultPlan::parse(&format!("seed={seed}; frame_read:p=0.5")).unwrap());
            (0..64).map(|_| plane.should_fail(FaultSite::FrameRead)).collect()
        };
        let a = run(9);
        assert_eq!(a, run(9), "same seed, same schedule");
        assert_ne!(a, run(10), "different seed, different schedule");
        let fires = a.iter().filter(|&&f| f).count();
        assert!((16..=48).contains(&fires), "p=0.5 over 64 hits fired {fires}");
    }

    #[test]
    fn prob_schedule_is_immune_to_other_sites_traffic() {
        let spec = "seed=5; frame_read:p=0.5; spill_write:always";
        let quiet = FaultPlane::new(FaultPlan::parse(spec).unwrap());
        let noisy = FaultPlane::new(FaultPlan::parse(spec).unwrap());
        let mut a = Vec::new();
        let mut b = Vec::new();
        for i in 0..32 {
            a.push(quiet.should_fail(FaultSite::FrameRead));
            // Interleave unrelated spill traffic on only one plane.
            for _ in 0..i {
                let _ = noisy.should_fail(FaultSite::SpillWrite);
            }
            b.push(noisy.should_fail(FaultSite::FrameRead));
        }
        assert_eq!(a, b, "frame_read coin depends only on its own hit ordinal");
    }

    #[test]
    fn disarmed_plane_never_fires_and_empty_plan_is_disarmed() {
        let plane = FaultPlane::disarmed();
        assert!(!plane.is_active());
        for site in FaultSite::ALL {
            assert!(!plane.should_fail(site));
        }
        assert_eq!(plane.total_injected(), 0);
        assert!(!FaultPlane::new(FaultPlan::parse("seed=3").unwrap()).is_active());
    }

    #[test]
    fn corrupt_byte_changes_payload_deterministically() {
        let plane = FaultPlane::new(FaultPlan::parse("seed=11; frame_corrupt:nth=1").unwrap());
        let original = vec![0u8; 64];
        let mut a = original.clone();
        let mut b = original.clone();
        plane.corrupt_byte(&mut a);
        plane.corrupt_byte(&mut b);
        assert_ne!(a, original, "corruption must actually change a byte");
        assert_eq!(a, b, "same seed + fired count, same flip");
        assert_eq!(a.iter().filter(|&&x| x != 0).count(), 1, "exactly one byte flips");
        plane.corrupt_byte(&mut []); // empty payload is a no-op, not a panic
    }

    #[test]
    fn worker_clauses_answer_only_for_their_id() {
        let plane = FaultPlane::new(
            FaultPlan::parse("worker_kill=w0:1; heartbeat_stall=w2:3").unwrap(),
        );
        assert_eq!(plane.worker_kill_after("w0"), Some(1));
        assert_eq!(plane.worker_kill_after("w1"), None);
        assert_eq!(plane.heartbeat_stall_after("w2"), Some(3));
        assert_eq!(plane.heartbeat_stall_after("w0"), None);
        assert_eq!(FaultPlane::disarmed().worker_kill_after("w0"), None);
    }

    #[test]
    fn backoff_schedule_doubles_from_base_and_caps() {
        let policy = RetryPolicy::new(5, 10, None);
        assert_eq!(policy.backoff(0), Duration::ZERO);
        assert_eq!(policy.backoff(1), Duration::from_millis(10));
        assert_eq!(policy.backoff(2), Duration::from_millis(20));
        assert_eq!(policy.backoff(3), Duration::from_millis(40));
        assert_eq!(policy.backoff(30), Duration::from_millis(BACKOFF_CAP_MS));
        // Zero base disables sleeping entirely (test configs).
        assert_eq!(RetryPolicy::new(5, 0, None).backoff(3), Duration::ZERO);
        // max_attempts is clamped to at least one try.
        assert_eq!(RetryPolicy::new(0, 1, None).max_attempts, 1);
    }

    #[test]
    fn deadline_check_is_typed_and_unbounded_without_one() {
        let policy = RetryPolicy::new(3, 0, Some(0));
        let started = Instant::now() - Duration::from_millis(5);
        match policy.check_deadline(started) {
            Err(RetryError::DeadlineExceeded {
                elapsed_ms,
                deadline_ms,
            }) => {
                assert!(elapsed_ms >= 5);
                assert_eq!(deadline_ms, 0);
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert!(RetryPolicy::new(3, 0, None).check_deadline(started).is_ok());
        assert!(RetryPolicy::new(3, 0, Some(60_000))
            .check_deadline(Instant::now())
            .is_ok());
    }

    #[test]
    fn retry_errors_display_their_numbers() {
        let e = RetryPolicy::new(4, 10, None).exhausted("worker lost");
        assert_eq!(
            e.to_string(),
            "retries exhausted after 4 attempts: worker lost"
        );
        let e = RetryError::DeadlineExceeded {
            elapsed_ms: 120,
            deadline_ms: 100,
        };
        assert!(e.to_string().contains("120 ms"), "{e}");
        assert!(e.to_string().contains("100 ms"), "{e}");
    }
}
