//! Retail association-rule mining: generate an IBM-Quest-style retail
//! basket dataset, mine frequent itemsets with RDD-Eclat, derive
//! association rules, and print the strongest ones — the workload the
//! paper's introduction motivates.
//!
//! Run: `cargo run --release --example retail_rules`

use rdd_eclat::data::QuestSpec;
use rdd_eclat::fim::eclat::{mine_eclat_vec, EclatConfig, EclatVariant};
use rdd_eclat::fim::rules::generate_rules;
use rdd_eclat::fim::types::abs_min_sup;
use rdd_eclat::sparklet::SparkletContext;

fn main() {
    // 10K baskets over an 870-product catalogue (T10-shaped).
    let spec = QuestSpec::t10i4d100k().scaled(0.1);
    let baskets = spec.generate(2026);
    println!(
        "generated {} baskets, avg width {:.1}",
        baskets.len(),
        baskets.iter().map(|b| b.len()).sum::<usize>() as f64 / baskets.len() as f64
    );

    let sc = SparkletContext::local(4);
    let min_sup = abs_min_sup(0.005, baskets.len()); // 0.5% support
    let cfg = EclatConfig::new(EclatVariant::V5, min_sup).with_p(10);
    let t = std::time::Instant::now();
    let result = mine_eclat_vec(&sc, baskets.clone(), &cfg);
    println!(
        "mined {} frequent itemsets (max length {}) in {:.0} ms",
        result.len(),
        result.max_length(),
        t.elapsed().as_secs_f64() * 1e3
    );

    let rules = generate_rules(&result, 0.5, baskets.len());
    println!("\ntop association rules (confidence >= 0.5):");
    for r in rules.iter().take(15) {
        println!("  {r}");
    }
    println!("({} rules total)", rules.len());

    // sanity: every rule's confidence is consistent with its supports
    for r in &rules {
        assert!(r.confidence > 0.0 && r.confidence <= 1.0);
    }
}
